"""Loop-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` visits every computation ONCE —
a ``while`` body (every ``lax.scan``: our layer stack, microbatch
accumulation, attention chunking) is counted a single time regardless of its
trip count, wildly under-reporting FLOPs/bytes/collective traffic for
scanned programs.

This module re-derives the three roofline inputs by walking the optimized
HLO text ourselves:

  * computations are parsed into (name -> [ops]) with a per-computation
    symbol table (%name -> shape),
  * cost(entry) recurses through ``call``/``fusion``/``conditional`` and
    multiplies ``while`` bodies by their trip count (extracted from the
    canonical ``compare(iter, constant)`` loop condition),
  * FLOPs: 2*prod(result_dims)*prod(contracting_dims) per dot (+rough
    elementwise ops are ignored — dot-dominated programs),
  * bytes: operand+result bytes of top-level ops per computation (fusion
    internals are VMEM-resident and excluded),
  * collective bytes: result-shape bytes per collective op (all-reduce
    doubled), accumulated with the same loop multipliers.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HloCost", "analyze_hlo_text"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather-start", "all-reduce-start", "all-gather",
                "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute-start", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(
    r"(calls|to_apply|body|condition|true_computation|false_computation|"
    r"branch_computations)=(?:\{([^}]*)\}|(%[\w\.\-]+))")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        [int(d) for d in dims.split(",") if d] if dims
                        else []))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    raw: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Optional[dict] = None

    def __add__(self, o: "HloCost") -> "HloCost":
        cc = dict(self.collective_counts or {})
        for k, v in (o.collective_counts or {}).items():
            cc[k] = cc.get(k, 0) + v
        return HloCost(self.flops + o.flops, self.bytes + o.bytes,
                       self.collective_bytes + o.collective_bytes, cc)

    def __mul__(self, f: float) -> "HloCost":
        cc = {k: v * f for k, v in (self.collective_counts or {}).items()}
        return HloCost(self.flops * f, self.bytes * f,
                       self.collective_bytes * f, cc)


_OPCODE_RE = re.compile(r"^(?:\(|\s)*(?:[\w\[\],\{\}\s\.\*]*?)\s*"
                        r"([a-z][\w\-]*)\(")


def _parse_computations(text: str) -> tuple[dict, Optional[str]]:
    """name -> list[_Op]; also returns entry computation name."""
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            # computation headers end with '{' and start with the name
            # (possibly prefixed by ENTRY); parameter lists may contain
            # nested parentheses, so just take the first token.
            if stripped.endswith("{") and not stripped.startswith("//"):
                is_entry = stripped.startswith("ENTRY")
                head = stripped[len("ENTRY"):].strip() if is_entry \
                    else stripped
                name = re.split(r"[\s(]", head, maxsplit=1)[0]
                name = name.lstrip("%")
                if name and name not in ("HloModule",):
                    cur = name
                    comps[cur] = []
                    if is_entry:
                        entry = cur
            continue
        if stripped == "}" or stripped.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = leading shapes before the opcode
        om = re.search(r"\b([a-z][a-z0-9\-]*(?:\.\d+)?)\(", rhs)
        opcode = om.group(1) if om else ""
        result_type = rhs[: om.start()] if om else rhs
        operands = re.findall(r"(%[\w\.\-]+)", rhs[om.end():] if om else "")
        comps[cur].append(_Op(name=name.lstrip("%"),
                              result_type=result_type,
                              opcode=opcode,
                              operands=[o.lstrip("%") for o in operands],
                              raw=rhs))
    return comps, entry


def _dot_flops(op: _Op, symtab: dict) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    result = _shape_dims(op.result_type)
    if not result:
        return 0.0
    rdims = result[0][1]
    prod_r = 1
    for d in rdims:
        prod_r *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.raw)
    lhs_shape = None
    if op.operands:
        lhs_shape = symtab.get(op.operands[0])
    if m and lhs_shape:
        cdims = [int(x) for x in m.group(1).split(",") if x]
        prod_c = 1
        for ci in cdims:
            if ci < len(lhs_shape):
                prod_c *= lhs_shape[ci]
        return 2.0 * prod_r * prod_c
    # fall back: assume square-ish contraction of last lhs dim
    if lhs_shape:
        return 2.0 * prod_r * (lhs_shape[-1] if lhs_shape else 1)
    return 0.0


_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond_ops: list[_Op]) -> float:
    """Extract the trip count from a canonical while condition:
    compare(iter, constant(N), direction=LT).  Falls back to the largest
    integer constant in the condition."""
    consts = []
    for op in cond_ops:
        if op.opcode == "constant":
            m = _TRIP_RE.search(op.raw)
            if m:
                consts.append(int(m.group(1)))
        for m in _TRIP_RE.finditer(op.raw):
            consts.append(int(m.group(1)))
    return float(max(consts)) if consts else 1.0


def analyze_hlo_text(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        # fall back to the largest computation
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    if entry is None:
        return HloCost(collective_counts={})

    memo: dict[str, HloCost] = {}

    def called_comps(op: _Op) -> dict:
        """attr -> computation names referenced by this op."""
        out = {}
        for m in _CALL_ATTR_RE.finditer(op.raw):
            attr = m.group(1)
            blob = m.group(2) if m.group(2) is not None else m.group(3)
            names = [n.strip().lstrip("%") for n in blob.split(",")]
            out[attr] = [n for n in names if n in comps]
        return out

    def cost_of(name: str, top_level: bool) -> HloCost:
        key = f"{name}:{top_level}"
        if key in memo:
            return memo[key]
        memo[key] = HloCost(collective_counts={})   # cycle guard
        symtab = {}        # name -> dims of first shape (for dot contraction)
        bytetab = {}       # name -> total result bytes (dtype-aware)
        for op in comps[name]:
            shapes = _shape_dims(op.result_type)
            symtab[op.name] = shapes[0][1] if shapes else []
            bytetab[op.name] = _shape_bytes(op.result_type)
        total = HloCost(collective_counts={})
        for op in comps[name]:
            oc = op.opcode
            if oc in ("dot", "dot-general"):
                total += HloCost(flops=_dot_flops(op, symtab),
                                 collective_counts={})
            if oc == "convolution":
                # rare here; approximate as dot on result x window
                total += HloCost(flops=2.0 * _shape_bytes(op.result_type),
                                 collective_counts={})
            base = oc.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                b = _shape_bytes(op.result_type)
                if base == "all-reduce":
                    b *= 2
                total += HloCost(collective_bytes=b,
                                 collective_counts={base: 1})
            if top_level and oc not in ("parameter", "constant",
                                        "get-tuple-element", "tuple",
                                        "bitcast"):
                b = _shape_bytes(op.result_type)
                for o in op.operands:
                    b += bytetab.get(o, 0)
                total += HloCost(bytes=b, collective_counts={})
            # recurse into called computations
            calls = called_comps(op)
            if oc == "while":
                body = (calls.get("body") or [None])[0]
                cond = (calls.get("condition") or [None])[0]
                # prefer XLA's own annotation when present
                ktc = re.search(r'known_trip_count[\\"\':{ n]+(\d+)', op.raw)
                if ktc:
                    trips = float(ktc.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond else 1.0
                if body:
                    total += cost_of(body, True) * trips
                if cond:
                    total += cost_of(cond, False) * trips
            elif oc == "fusion":
                for c in calls.get("calls", []):
                    total += cost_of(c, False)
            elif oc in ("call", "custom-call", "async-start"):
                for lst in calls.values():
                    for c in lst:
                        total += cost_of(c, False)
            elif oc == "conditional":
                branch_costs = []
                for lst in calls.values():
                    for c in lst:
                        branch_costs.append(cost_of(c, True))
                if branch_costs:
                    # worst-case branch
                    total += max(branch_costs, key=lambda x: x.flops)
            elif oc in ("reduce", "map", "scatter", "select-and-scatter",
                        "sort", "reduce-window"):
                for lst in calls.values():
                    for c in lst:
                        total += cost_of(c, False)
        memo[key] = total
        return total

    return cost_of(entry, True)
