"""Roofline analysis from compiled dry-run artifacts (deliverable g).

This container is CPU-only; TPU v5e is the TARGET.  We therefore derive the
three roofline terms from the compiled XLA artifact instead of wall-clock:

    compute term    = HLO_FLOPs      / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes      / (chips * HBM_BW)
    collective term = collective_bytes / (chips * LINK_BW)

``compiled.cost_analysis()`` runs on the post-SPMD per-device module, so its
flops/bytes are PER DEVICE; we report global = per_device * chips so the
formulas above hold verbatim.  collective_bytes is not in cost_analysis —
we parse the optimized HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(all-reduce counted twice: reduce + broadcast phases of a ring).

Hardware constants (TPU v5e, per chip):
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

__all__ = ["HW", "CollectiveStats", "parse_collectives", "RooflineReport",
           "analyze_compiled", "MODEL_FLOPS"]

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "link_bw": LINK_BW}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# "  %x = f32[8,128]{1,0} all-reduce(...)" or tuple results
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:\w+\[[\d,]*\][^)\s]*\s*,?\s*)+)\s*(?:\))?\s*"
    r"(" + "|".join(_COLLECTIVES) + r")\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes per collective kind over the optimized HLO.

    all-reduce bytes are doubled (ring reduce + broadcast traffic)."""
    counts = {k: 0 for k in _COLLECTIVES}
    bytes_by = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        if f" {kind}(" not in line and f"{kind}(" not in line:
            continue
        b = _shape_bytes(shapes)
        if kind == "all-reduce":
            b *= 2
        counts[kind] += 1
        bytes_by[kind] += b
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by)


def MODEL_FLOPS(n_params: int, tokens: int, kind: str = "train") -> float:
    """6*N*D for training; 2*N*D for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # global quantities (per_device * chips)
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    # terms in seconds
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    per_device_peak_memory: Optional[float]
    collective_counts: dict
    collective_bytes_by_kind: dict
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "RooflineReport":
        return RooflineReport(**d)


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float,
    note: str = "",
) -> RooflineReport:
    """Build the roofline report for one compiled (arch x shape x mesh).

    FLOPs/bytes/collective bytes come from the loop-aware HLO walker
    (``repro.roofline.hlo_cost``) — XLA's cost_analysis() counts while
    bodies (every lax.scan) once, under-reporting scanned programs by the
    trip count."""
    from repro.roofline.hlo_cost import analyze_hlo_text

    hlo_text = compiled.as_text()
    hc = analyze_hlo_text(hlo_text)
    coll = CollectiveStats(
        counts=dict(hc.collective_counts or {}),
        bytes_by_kind=dict(hc.collective_counts or {}))

    flops = hc.flops * chips
    bytes_ = hc.bytes * chips
    coll_bytes = hc.collective_bytes * chips

    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_ / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * LINK_BW)
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)),
        key=lambda kv: kv[1])[0]

    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        peak_mem = float(
            ma.temp_size_in_bytes + ma.argument_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    except Exception:
        pass

    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        collective_bytes=coll_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0,
        per_device_peak_memory=peak_mem,
        collective_counts=coll.counts,
        collective_bytes_by_kind=coll.bytes_by_kind,
        note=note,
    )
