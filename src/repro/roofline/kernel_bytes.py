"""Analytic per-step HBM-byte models: fused Pallas vs unfused XLA pipelines.

Companion to the compiled-HLO analyzer (``analysis.py``): that one measures
whatever XLA emitted; this one models what each kernel *must* move, so the
fused kernels in ``repro.kernels`` can be compared against the unfused XLA
lowering (and against the oracle-VJP backward, which replays the unfused
forward) without a TPU attached.

Modeling conventions (documented per op below):

  * one read per operand a kernel consumes, one write per tensor it
    produces — VMEM-resident reuse inside a fused kernel is free;
  * the unfused XLA pipelines are modeled at kernel-fusion granularity:
    matmuls/einsums materialize their outputs, the elementwise chains
    between them are assumed perfectly fused by XLA (generous to XLA);
  * the oracle-VJP backward replays the unfused forward (its residuals are
    the inputs) and materializes the gate/attention cotangents, exactly
    like ``jax.vjp`` over ``ref.py``;
  * scatters are modeled in-place (donated buffers inside the epoch scan):
    read + write of the touched rows only.  The O(N) terms charged to the
    unfused flush are the aggregation *tables* it genuinely materializes.

Every model returns an ``OpBytes`` with an itemized ``reads``/``writes``
dict so benchmark CSVs can show where the bytes go.

Lane padding (``lanes=True``): the Pallas wrappers in ``kernels/ops.py``
pad every contraction/lane dim the kernels see to a multiple of 128 lanes
(and the attention K axis to 8 sublanes) so the MXU gets aligned tiles.
The byte models here default to the RAW dims — call with ``lanes=True`` to
model what the padded launches actually move.  Guard rule: a model asked
about a non-multiple-of-128 dim is reporting *demanded* bytes only when
``lanes=False``; compare both to see the padding tax (typically small —
the padded columns ride in the same DMA lanes the hardware moves anyway).
"""

from __future__ import annotations

import dataclasses

__all__ = ["OpBytes", "gru_bytes", "attn_bytes", "flush_bytes",
           "sample_bytes", "epoch_plan_bytes", "step_pipeline_bytes",
           "pac_sync_bytes", "pac_staging_bytes",
           "lane_pad", "sublane_pad"]

F32 = 4
MASK = 1       # bool
LANES = 128    # f32 MXU/VREG lane count — last-dim tile
SUBLANES = 8   # f32 sublane count — second-to-last-dim tile


def lane_pad(n: int) -> int:
    """Round ``n`` up to the 128-lane tile the ops-boundary padding uses."""
    return -(-int(n) // LANES) * LANES


def sublane_pad(n: int) -> int:
    """Round ``n`` up to the 8-sublane tile (attention K axis)."""
    return -(-int(n) // SUBLANES) * SUBLANES


@dataclasses.dataclass(frozen=True)
class OpBytes:
    op: str
    direction: str          # "fwd" | "bwd"
    pipeline: str           # "fused" | "unfused" | "oracle"
    reads: dict
    writes: dict

    @property
    def read_bytes(self) -> int:
        return int(sum(self.reads.values()))

    @property
    def write_bytes(self) -> int:
        return int(sum(self.writes.values()))

    @property
    def total(self) -> int:
        return self.read_bytes + self.write_bytes


def _merge(*dicts):
    out: dict = {}
    for d in dicts:
        for k, v in d.items():
            out[k] = out.get(k, 0) + v
    return out


# ------------------------------------------------------------------- GRU

def gru_bytes(b, d_in, d_h, *, direction="fwd", fused=True,
              lanes=False, itemsize=F32) -> OpBytes:
    """h' = GRU(x, h) over (b, d_in) x (b, d_h) rows.

    unfused fwd: two gate matmuls materialize gx/gh (b, 3*d_h) in HBM, a
    fused elementwise kernel re-reads them plus h.  oracle bwd: replays
    that forward, materializes the r/z/n/nh residuals and the dgx/dgh gate
    cotangents, then runs 4 matmuls over them.  fused bwd: recomputes the
    gates in VMEM — one read per operand, one write per gradient.

    ``lanes=True`` models the lane-padded launch ``kernels/ops.py``
    actually makes: d_in and d_h rounded up to 128 (every gate block
    padded, so gx/gh are 3 * lane_pad(d_h) wide).
    """
    if lanes:
        d_in, d_h = lane_pad(d_in), lane_pad(d_h)
    x, h = b * d_in * itemsize, b * d_h * itemsize
    wx, wh = d_in * 3 * d_h * itemsize, d_h * 3 * d_h * itemsize
    bias = 2 * 3 * d_h * itemsize
    gates = b * 3 * d_h * itemsize          # one of gx / gh / dgx / dgh
    operands = {"x": x, "h": h, "wx": wx, "wh": wh, "bias": bias}

    if direction == "fwd":
        if fused:
            return OpBytes("gru", "fwd", "fused", operands, {"out": h})
        return OpBytes(
            "gru", "fwd", "unfused",
            _merge(operands, {"gx_gh_reread": 2 * gates, "h_reread": h}),
            {"gx_gh": 2 * gates, "out": h})

    grads = {"dx": x, "dh": h, "dwx": wx, "dwh": wh, "dbias": bias}
    if fused:
        return OpBytes("gru", "bwd", "fused",
                       _merge(operands, {"g": h}), grads)
    # oracle-VJP: forward replay + residual/cotangent round-trips
    replay_r = _merge(operands, {"gx_gh_reread": 2 * gates, "h_reread": h})
    replay_w = {"gx_gh": 2 * gates, "rznn_residuals": 4 * h}
    bwd_r = {"g": h, "rznn_residuals": 4 * h, "h_bwd": h,
             "dgx_dgh_reread": 2 * 2 * gates,      # dx/dwx + dh/dwh matmuls
             "x_bwd": x, "wx_bwd": wx, "wh_bwd": wh}
    bwd_w = {"dgx_dgh": 2 * gates}
    return OpBytes("gru", "bwd", "oracle",
                   _merge(replay_r, bwd_r),
                   _merge(replay_w, bwd_w, grads))


# ------------------------------------------------------- temporal attention

def attn_bytes(b, k, h, d, *, direction="fwd", fused=True,
               lanes=False, itemsize=F32) -> OpBytes:
    """Masked neighbor attention over q (b,h,d), k/v (b,k,h,d), mask (b,k).

    unfused fwd: QK^T materializes scores (b,h,k), softmax+zero-fix
    re-reads/rewrites them, AV re-reads.  oracle bwd: replays that, then
    materializes datt/ds cotangents for the dq/dk/dv einsums.  fused bwd:
    softmax recomputed in VMEM — one pass per operand/gradient.

    ``lanes=True`` models the lane-padded launch ``kernels/ops.py`` makes:
    head dim d rounded up to 128 lanes, neighbor axis k to 8 sublanes
    (padded slots carry mask=False but still ride the DMA).
    """
    if lanes:
        k, d = sublane_pad(k), lane_pad(d)
    q = b * h * d * itemsize
    kv = b * k * h * d * itemsize
    mask = b * k * MASK
    sc = b * h * k * itemsize               # one scores-shaped tensor
    operands = {"q": q, "k": kv, "v": kv, "mask": mask}

    if direction == "fwd":
        if fused:
            return OpBytes("temporal_attn", "fwd", "fused",
                           operands, {"out": q})
        return OpBytes(
            "temporal_attn", "fwd", "unfused",
            _merge(operands, {"scores_reread": sc, "att_reread": sc}),
            {"scores": sc, "att": sc, "out": q})

    grads = {"dq": q, "dk": kv, "dv": kv}
    if fused:
        return OpBytes("temporal_attn", "bwd", "fused",
                       _merge(operands, {"g": q}), grads)
    replay_r = _merge(operands, {"scores_reread": sc, "att_reread": sc})
    replay_w = {"scores": sc, "att": sc}
    bwd_r = {"g": 2 * q,                    # datt einsum + dv einsum
             "v_bwd": kv, "att_bwd": 2 * sc,
             "datt": sc, "ds_reread": 2 * sc,    # dq + dk einsums
             "k_bwd": kv, "q_bwd": q}
    bwd_w = {"datt": sc, "ds": sc}
    return OpBytes("temporal_attn", "bwd", "oracle",
                   _merge(replay_r, bwd_r),
                   _merge(replay_w, bwd_w, grads))


# ------------------------------------------------------------ message flush

def flush_bytes(n_nodes, rows, d_msg, d_mem, *, direction="fwd", fused=True,
                lanes=False, itemsize=F32) -> OpBytes:
    """The flush_pending message pipeline: segment-mean over ``rows``
    (=2B) pending messages, GRU update, scatter of mem/last.

    ``lanes=True`` pads ONLY the d_msg side (message columns + wx gate
    rows) to 128 lanes, matching ``kernels/ops.py``: the memory table is
    aliased in place, so d_mem stays raw — padding it would force an O(N)
    copy and defeat the kernel's O(rows)-traffic point.

    unfused fwd: materializes the (N+1, d_msg) scatter-add sums table and
    the (N+1,) counts, divides over the FULL table (read+write), gathers
    back, then runs the unfused GRU on the touched rows — O(N) traffic for
    O(rows) work.  fused fwd: one Pallas launch touching only the ``rows``
    gathered memory rows (+ weights); no tables.  bwd is the oracle VJP in
    both pipelines (it replays the unfused forward and emits a full-table
    memory cotangent) — the fused win in the backward comes from the GRU /
    attention kernels, not the flush.
    """
    if lanes:
        d_msg = lane_pad(d_msg)
    msg = rows * d_msg * itemsize
    memrows = rows * d_mem * itemsize
    ids = rows * 4
    ts = rows * itemsize
    tbl = (n_nodes + 1) * d_msg * itemsize      # sums / mbar table
    cnt = (n_nodes + 1) * itemsize
    wx = d_msg * 3 * d_mem * itemsize
    wh = d_mem * 3 * d_mem * itemsize
    bias = 2 * 3 * d_mem * itemsize
    weights = {"wx": wx, "wh": wh, "bias": bias}

    if direction == "fwd":
        if fused:
            return OpBytes(
                "flush", "fwd", "fused",
                _merge({"msg": msg, "ids": 3 * ids, "ts": ts,
                        "mem_rows": memrows, "last_rows": ts}, weights),
                {"mem_rows": memrows, "last_rows": ts, "mbar": msg})
        gru_u = gru_bytes(rows, d_msg, d_mem, fused=False,
                          itemsize=itemsize)
        return OpBytes(
            "flush", "fwd", "unfused",
            _merge({"msg": msg, "ids": ids, "ts": ts,
                    "sums_tbl_scatter": msg, "cnt_scatter": ts,
                    "sums_cnt_tbl_div": tbl + cnt,
                    "mbar_tbl_gather": msg,
                    "mem_rows": memrows, "last_rows": ts},
                   {k: v for k, v in gru_u.reads.items()
                    if k not in ("x", "h")}),
            _merge({"sums_tbl_zeros": tbl, "cnt_zeros": cnt,
                    "mbar_tbl": tbl,
                    "mem_rows": memrows, "last_rows": ts, "mbar": msg},
                   {k: v for k, v in gru_u.writes.items() if k != "out"}))

    # oracle VJP either way: unfused forward replay + cotangent tables
    fwd_u = flush_bytes(n_nodes, rows, d_msg, d_mem,
                        direction="fwd", fused=False, itemsize=itemsize)
    gru_b = gru_bytes(rows, d_msg, d_mem, direction="bwd", fused=False,
                      itemsize=itemsize)
    return OpBytes(
        "flush", "bwd", "oracle",
        _merge(fwd_u.reads, {"g_mem": (n_nodes + 1) * d_mem * itemsize,
                             "g_mbar": msg},
               {k: v for k, v in gru_b.reads.items() if k not in ("x", "h")}),
        _merge({"dmsg": msg, "dmem_tbl": (n_nodes + 1) * d_mem * itemsize,
                "dsums_tbl": tbl, "dmbar": 2 * msg},
               {k: v for k, v in gru_b.writes.items()
                if k not in ("dx", "dh")}))


# ------------------------------------------------------- neighbor sampling

I32 = 4


def sample_bytes(rows, k, total_events, *, itemsize=F32) -> OpBytes:
    """One fused temporal-neighbor-sample launch over ``rows`` (=3B) query
    nodes against a ``total_events``-event T-CSR (``kernels.neighbor_sample``).

    The kernel is gather-bound, not compute-bound: per row it runs a
    ceil(log2(total)) binary search over the batch-key array (one 4-byte
    HBM probe per iteration — the DMA engine moves a full transfer lane,
    but the *demanded* bytes are one int32) and then three K-wide window
    DMAs (ids / times / edge rows).  start/stop/key arrive via scalar
    prefetch.  Writes are the three (rows, K) output grids.
    """
    iters = max(1, int(total_events).bit_length())
    reads = {
        "start_stop_key_prefetch": 3 * rows * I32,
        "bisect_probes": rows * iters * I32,
        "nbr_window": rows * k * I32,
        "t_window": rows * k * itemsize,
        "eidx_window": rows * k * I32,
    }
    writes = {
        "ids": rows * k * I32,
        "times": rows * k * itemsize,
        "eidx": rows * k * I32,
    }
    return OpBytes("neighbor_sample", "fwd", "fused", reads, writes)


def epoch_plan_bytes(steps, batch, k, num_nodes, total_events, *,
                     itemsize=F32) -> dict:
    """Per-epoch host->device staging (H2D) bytes: ``plan="host"`` vs
    ``plan="device"`` (``batching.build_batch_program``).

    Both plans ship the raw edge records — per grid row: src/dst/neg/eidx
    int32, t f32, valid bool (21 B).  The host plan additionally stages
    nine pre-sampled neighbor grids (3 roles x (ids + times + edge rows) x
    K = 12K B/row, re-shipped EVERY epoch).  The device plan instead
    stages the stream's T-CSR once — (N+1) int32 indptr plus four
    K-front-padded event columns (ids/times/edge rows/batch keys, 16 B per
    event) — and the scanned step re-samples on device (``sample_bytes``,
    HBM-local traffic, not H2D).

    Returns ``{"host", "device", "host_detail", "device_detail",
    "sample"}`` — totals in bytes, itemized dicts, and the per-step
    on-device sampling ``OpBytes`` the device plan trades the grid H2D
    for.
    """
    rows = steps * batch
    records = rows * (4 * I32 + itemsize + MASK)
    grids = rows * 3 * k * (2 * I32 + itemsize)
    tcsr = (num_nodes + 1) * I32 + (total_events + k) * (3 * I32 + itemsize)
    host = {"records": records, "neighbor_grids": grids}
    device = {"records": records, "tcsr": tcsr}
    return {
        "host": int(sum(host.values())),
        "device": int(sum(device.values())),
        "host_detail": host,
        "device_detail": device,
        "sample": sample_bytes(3 * batch, k, total_events + k,
                               itemsize=itemsize),
    }


# ------------------------------------------------------- PAC pod plumbing

def pac_sync_bytes(n_shared, d_mem, n_devices, n_hosts=1, *,
                   mode="latest", itemsize=F32) -> dict:
    """Per-device link bytes of PAC's shared-node memory sync epilogue
    (``distributed.device_epoch``), with the cross-host (DCN) share.

    ``"latest"`` (the paper's rule) all-gathers only the (S,) last-update
    timestamps — each device receives the other ``N-1`` replicas' rows —
    then combines the (S, d) ``mem``/``mem2`` rows with a winner-masked
    ``psum`` (ring all-reduce: ``2(N-1)/N`` traversals of the tensor per
    device).  ``"mean"`` psums all three tensors instead.  On a mesh whose
    "part" axis spans ``n_hosts`` processes with contiguous per-host
    ranks (``launch.mesh.make_tig_mesh``), ``n_hosts`` of the ring's
    ``N`` hops cross host boundaries, so that fraction of the traffic
    rides the data-center network instead of ICI.

    Returns ``{"per_device", "cross_host", "dcn_fraction", "detail"}`` —
    bytes per device, the slice of them crossing hosts, and the itemized
    collectives.
    """
    assert mode in ("latest", "mean"), mode
    s, d, n = int(n_shared), int(d_mem), int(n_devices)
    ring = 2 * (n - 1) / max(n, 1)     # reduce-scatter + all-gather
    if mode == "latest":
        detail = {
            "gather_ts": (n - 1) * s * itemsize,
            "psum_mem": int(ring * s * d * itemsize),
            "psum_mem2": int(ring * s * d * itemsize),
        }
    else:
        detail = {
            "psum_mem": int(ring * s * d * itemsize),
            "psum_mem2": int(ring * s * d * itemsize),
            "psum_ts": int(ring * s * itemsize),
        }
    per_device = int(sum(detail.values()))
    dcn_fraction = (n_hosts / n) if (n_hosts > 1 and n > 0) else 0.0
    return {
        "per_device": per_device,
        "cross_host": int(per_device * dcn_fraction),
        "dcn_fraction": dcn_fraction,
        "detail": detail,
    }


def pac_staging_bytes(real_batches, events_per_device, row_bytes, *,
                      event_bytes=3 * I32 + F32, n_hosts=1) -> dict:
    """Per-host staged H2D bytes of the PAC batch plane: replicated flat
    grid vs the row-range-sharded layout (``plan_epoch(layout=...)``).

    ``real_batches`` / ``events_per_device`` are per-device row and T-CSR
    event counts; ``row_bytes`` is one grid row's bytes (one batch).  The
    replicated layout ships EVERY device the full flat buffer, so a host
    with ``n_local`` devices stages ``n_local * (sum rows + sum events)``;
    the sharded layout pads each device to the global caps (a shard_map
    uniform-block requirement) but ships each device only its OWN rows:
    ``sum_local (max rows + max events)``.  Devices split contiguously
    across ``n_hosts`` (the ``make_tig_mesh`` ordering).

    Returns per-host lists plus totals; sharded is strictly below
    replicated whenever a host has >1 device elsewhere to pay for, i.e.
    for every multi-device mesh with at least one real batch per device.
    """
    rows = [int(r) for r in real_batches]
    events = [int(e) for e in events_per_device]
    assert len(rows) == len(events) and rows, (rows, events)
    flat = sum(rows) * row_bytes + sum(events) * event_bytes
    rows_cap, ev_cap = max(rows), max(events)
    per_dev_sharded = rows_cap * row_bytes + ev_cap * event_bytes
    n_dev, rem = divmod(len(rows), n_hosts)
    groups = [n_dev + (1 if h < rem else 0) for h in range(n_hosts)]
    replicated = [int(n_local * flat) for n_local in groups]
    sharded = [int(n_local * per_dev_sharded) for n_local in groups]
    return {
        "replicated": replicated,
        "sharded": sharded,
        "total_replicated": int(sum(replicated)),
        "total_sharded": int(sum(sharded)),
        "per_device_replicated": int(flat),
        "per_device_sharded": int(per_dev_sharded),
    }


# --------------------------------------------------------------- whole step

def step_pipeline_bytes(n_nodes, batch, d_msg, d_mem, k_neighbors, n_heads,
                        *, n_layers=1, lanes=False, itemsize=F32) -> dict:
    """Modeled HBM bytes for the kernelized portion of one training step
    (flush pipeline + the 3B-row embedding attention), fwd + bwd, fused vs
    unfused.  Returns {"fused": bytes, "unfused": bytes, "detail": [...]}.

    ``n_layers``: the stacked temporal-attention fold runs one attention
    launch per layer over the same 3B rows (the scanned layer block), so
    the attention fwd+bwd parts repeat per layer — the flush runs once
    regardless.  ``lanes=True`` models the lane-padded launches (see the
    per-op models).  ``detail`` holds one OpBytes per modeled launch:
    2 flush + 2 * n_layers attention entries per pipeline (8 at defaults).
    """
    head_d = d_mem // n_heads
    out = {}
    detail = []
    for pipeline in ("fused", "unfused"):
        fused = pipeline == "fused"
        parts = [
            flush_bytes(n_nodes, 2 * batch, d_msg, d_mem,
                        direction="fwd", fused=fused, lanes=lanes,
                        itemsize=itemsize),
            flush_bytes(n_nodes, 2 * batch, d_msg, d_mem,
                        direction="bwd", fused=fused, lanes=lanes,
                        itemsize=itemsize),
        ]
        for _ in range(n_layers):
            parts += [
                attn_bytes(3 * batch, k_neighbors, n_heads, head_d,
                           direction="fwd", fused=fused, lanes=lanes,
                           itemsize=itemsize),
                attn_bytes(3 * batch, k_neighbors, n_heads, head_d,
                           direction="bwd", fused=fused, lanes=lanes,
                           itemsize=itemsize),
            ]
        out[pipeline] = sum(p.total for p in parts)
        detail.extend(parts)
    out["detail"] = detail
    return out
