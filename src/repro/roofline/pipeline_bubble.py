"""Epoch-boundary pipeline-bubble model: serial vs overlapped boundaries.

PAC's per-epoch device work is one scanned program, but three more things
happen at every epoch boundary:

  * **plan** — host-side shuffle-combine + localization + batch grids
    (``plan_epoch``), pure CPU wall-time;
  * **stage** — host->device transfer of the plan
    (``make_array_from_process_local_data`` / ``device_put``), modeled
    from staged bytes over the H2D link;
  * **sync** — the Alg.2 shared-node memory epilogue's cross-host
    collectives, modeled from ``kernel_bytes.pac_sync_bytes`` over the
    DCN link;
  * **fetch** — the per-epoch device->host loss read (a replicating
    all-gather + copy on a multi-host mesh).

Three boundary disciplines are modeled, matching the trainers:

  * ``serial`` — everything in line: plan + stage + sync + fetch per
    epoch (``prefetch=False`` + ``epoch_boundary="serial"``);
  * ``prefetch`` — plan+stage hidden behind the scan on the worker
    thread (the PR 2-8 baseline): only the *spill* — the part of
    plan+stage longer than the scan — plus sync + fetch stays exposed;
  * ``overlapped`` — the async boundary (``epoch_boundary="overlap"``):
    sync is dispatched as a separate program the main thread never
    blocks on and the loss read is an async copy collected after the
    loop, so per-epoch only the spill and the dispatch overhead remain;
    one full sync+fetch drain is paid once, at the end of training,
    amortized as ``(sync + fetch) / epochs`` per epoch.

All quantities are per-epoch *boundary* seconds — scan time itself is
identical across disciplines and excluded (it enters only through the
spill term).  ``benchmarks/epoch_pipeline.py`` measures the same three
disciplines on the simulated 2-host pod and cross-checks this model.
"""

from __future__ import annotations

__all__ = ["boundary_component_seconds", "pipeline_bubble"]


def boundary_component_seconds(*, sync_bytes: float, staging_bytes: float,
                               plan_s: float, dcn_gbps: float = 1.25,
                               h2d_gbps: float = 8.0) -> dict:
    """Convert boundary byte counts into per-component seconds.

    ``sync_bytes`` is the cross-host slice of the sync epilogue
    (``pac_sync_bytes(...)["cross_host"]`` summed over local devices),
    ``staging_bytes`` the per-host staged plan bytes
    (``pac_staging_bytes`` / ``EpochPlan.plan_bytes``), ``plan_s`` the
    measured host planning wall-time.  Link rates are GB/s (1e9).
    """
    if dcn_gbps <= 0 or h2d_gbps <= 0:
        raise ValueError(f"link rates must be positive, got "
                         f"dcn_gbps={dcn_gbps}, h2d_gbps={h2d_gbps}")
    return {
        "plan_s": float(plan_s),
        "stage_s": float(staging_bytes) / (h2d_gbps * 1e9),
        "sync_s": float(sync_bytes) / (dcn_gbps * 1e9),
    }


def pipeline_bubble(*, plan_s: float, stage_s: float, sync_s: float,
                    fetch_s: float, scan_s: float, epochs: int,
                    dispatch_s: float = 0.0) -> dict:
    """Per-epoch boundary-bubble seconds for the three disciplines.

    ``scan_s`` is the per-epoch device scan time (what the worker thread
    can hide plan+stage behind); ``dispatch_s`` is the per-epoch Python/
    jit dispatch overhead of issuing the extra sync program and the async
    loss copy (measure it — on a CPU test rig it is not negligible
    against simulated link times).  ``epochs`` amortizes the single
    end-of-training drain the overlapped discipline still pays.

    Returns the three per-epoch bubbles plus the spill term and the
    speedup ratios (``inf``-guarded for degenerate zero bubbles).
    """
    if epochs < 1:
        raise ValueError(f"epochs={epochs}: expected >= 1")
    for name, v in (("plan_s", plan_s), ("stage_s", stage_s),
                    ("sync_s", sync_s), ("fetch_s", fetch_s),
                    ("scan_s", scan_s), ("dispatch_s", dispatch_s)):
        if v < 0:
            raise ValueError(f"{name}={v}: expected >= 0")
    # the part of host planning + staging that does NOT fit behind the
    # scan — exposed in every discipline that prefetches
    spill = max(0.0, plan_s + stage_s - scan_s)
    serial = plan_s + stage_s + sync_s + fetch_s
    prefetch = spill + sync_s + fetch_s
    overlapped = spill + dispatch_s + (sync_s + fetch_s) / epochs
    return {
        "spill_s": spill,
        "serial_s": serial,
        "prefetch_s": prefetch,
        "overlapped_s": overlapped,
        "speedup_vs_serial": serial / overlapped if overlapped > 0
        else float("inf"),
        "speedup_vs_prefetch": prefetch / overlapped if overlapped > 0
        else float("inf"),
    }
