"""Pallas TPU kernels for the perf-critical compute hot-spots.

    kernel            used by                         file
    fused GRU         TIG memory update (UPD)         fused_gru.py
    temporal attn     TIG embedding module            temporal_attn.py
    flash attention   LLM train/prefill (+SWA)        flash_attention.py
    RWKV6 WKV         rwkv6-1.6b / linear attention   rwkv6_scan.py

``ops.py`` is the dispatching entry point (pallas / interpret / xla);
``ref.py`` holds the pure-jnp oracles the tests validate against.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
