"""Blockwise (flash) causal attention — Pallas TPU kernel.

Online-softmax attention with O(S) memory: grid (B*H, num_q_blocks,
num_k_blocks), with running max / denominator / accumulator carried in VMEM
scratch across the k-block axis (TPU executes the grid sequentially along the
trailing axis, so scratch is a legal carry).  Supports causal masking and a
sliding window (``window`` tokens lookback, inclusive of self) — the
starcoder2 / hymba long-context path.

Blocks are 128-aligned for the MXU; out-of-range k blocks are skipped with
``pl.when`` (predication, no wasted matmuls) — with a sliding window this is
what makes attention cost O(S * window) instead of O(S^2).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, block_q, block_k, num_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    run = jnp.bool_(True)
    if causal:
        # need k_start <= last query index of this block
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        # need block's last key index >= first allowed index of the block's
        # first query: q_start - window + 1
        run = jnp.logical_and(
            run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q, k, v: (B, H, S, D) -> (B, H, S, D).  D and S should be multiples of
    128 on real TPUs (the wrapper in ops.py pads); any shape works in
    interpret mode."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    num_q = pl.cdiv(s, block_q)
    num_k = pl.cdiv(s, block_k)
    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * h, s, d)
    vr = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k=num_k)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)
