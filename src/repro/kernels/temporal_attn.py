"""Temporal neighbor attention — Pallas TPU kernels (forward and backward).

The TGN/TIGE embedding module attends from each node over its K sampled
temporal neighbors (K is small, 10-32).  XLA handles the einsums fine but
round-trips the (B, H, K) score tensor and the (B, K, H, D) projections
through HBM; with K this small the whole per-row working set fits VMEM, so
we fuse QK^T -> mask -> softmax -> AV into one kernel.

The backward kernel is flash-attention-style: scores and the softmax are
recomputed in VMEM from (q, k, v, mask) — nothing but the inputs is saved
as residuals — so the backward pass makes one HBM read per operand and one
write per gradient instead of round-tripping the (B, H, K) attention
tensor and its cotangent chain through HBM.

Tiling: grid over row blocks (block_b); K and the head dims live entirely in
registers/VMEM.  The mask handles both empty slots and rows with zero
neighbors (output exactly 0 — matching the oracle and the model semantics
for never-seen nodes).

The kernel itself is shape-generic, but the public wrapper
(``kernels/ops.py``) pads the head dim D to a multiple of 128 lanes and K
to a multiple of 8 sublanes before calling it, so the QK^T/AV contractions
here always see MXU-aligned tiles.  Padded K slots arrive with
``mask=False`` (they never contribute); the padded tail of D is zeros on
both q and k, with q pre-scaled so the 1/sqrt(D_padded) below equals the
raw 1/sqrt(D) — the wrapper's padding is value-invariant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["temporal_attn", "temporal_attn_bwd"]


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # (b, H, D)
    k = k_ref[...].astype(jnp.float32)          # (b, K, H, D)
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]                         # (b, K) bool
    d = q.shape[-1]
    scores = jnp.einsum("bhd,bkhd->bhk", q, k) / jnp.sqrt(
        jnp.asarray(d, jnp.float32))
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    att = e / denom
    att = jnp.where(mask.any(axis=-1)[:, None, None], att, 0.0)
    ctx = jnp.einsum("bhk,bkhd->bhd", att, v)
    out_ref[...] = ctx.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def temporal_attn(q, k, v, mask, *, block_b: int = 128,
                  interpret: bool = False):
    """Masked attention over sampled neighbors.

    q: (B, H, D); k, v: (B, K, H, D); mask: (B, K) bool -> (B, H, D).
    """
    b, h, d = q.shape
    kk = k.shape[1]
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    return pl.pallas_call(
        _attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, kk, h, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_b, kk, h, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_b, kk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(q, k, v, mask)


def _attn_bwd_kernel(g_ref, q_ref, k_ref, v_ref, mask_ref,
                     dq_ref, dk_ref, dv_ref):
    g = g_ref[...].astype(jnp.float32)           # (b, H, D)
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)           # (b, K, H, D)
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]                         # (b, K) bool
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))

    # in-VMEM softmax recompute (identical math to the forward kernel)
    scores = jnp.einsum("bhd,bkhd->bhk", q, k) * scale
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    att = e / jnp.sum(e, axis=-1, keepdims=True)
    att = jnp.where(mask.any(axis=-1)[:, None, None], att, 0.0)

    # masked slots have att == 0, so the softmax-backward formula below
    # already routes zero gradient to them (and to zero-neighbor rows)
    dv = jnp.einsum("bhk,bhd->bkhd", att, g)
    datt = jnp.einsum("bhd,bkhd->bhk", g, v)
    ds = att * (datt - jnp.sum(att * datt, axis=-1, keepdims=True))
    dq = jnp.einsum("bhk,bkhd->bhd", ds, k) * scale
    dk = jnp.einsum("bhk,bhd->bkhd", ds, q) * scale
    dq_ref[...] = dq.astype(dq_ref.dtype)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def temporal_attn_bwd(g, q, k, v, mask, *, block_b: int = 128,
                      interpret: bool = False):
    """One-pass attention backward: (dq, dk, dv) from the output cotangent
    ``g`` and the forward inputs (softmax recomputed in VMEM)."""
    b, h, d = q.shape
    kk = k.shape[1]
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    row3 = pl.BlockSpec((block_b, h, d), lambda i: (i, 0, 0))
    row4 = pl.BlockSpec((block_b, kk, h, d), lambda i: (i, 0, 0, 0))
    return pl.pallas_call(
        _attn_bwd_kernel,
        grid=grid,
        in_specs=[row3, row3, row4, row4,
                  pl.BlockSpec((block_b, kk), lambda i: (i, 0))],
        out_specs=[row3, row4, row4],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(g, q, k, v, mask)
