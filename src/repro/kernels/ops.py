"""Public kernel entry points with backend dispatch.

Each op picks its execution path:
  * ``backend="pallas"``     — pl.pallas_call targeting real TPUs,
  * ``backend="interpret"``  — the same kernel body executed in Python on
                               CPU (correctness validation; what tests use),
  * ``backend="xla"``        — the pure-jnp oracle from ``ref.py`` (what the
                               models use on CPU and in dry-runs; on TPU
                               deployments flip the default to "pallas").

``default_backend()`` resolves "auto": pallas on TPU, xla elsewhere.  The
``REPRO_KERNEL_BACKEND`` environment variable overrides the "auto"
resolution (e.g. ``REPRO_KERNEL_BACKEND=interpret`` exercises the Pallas
kernel bodies on CPU without touching any config).

Backward passes: the differentiable ops (``gru``, ``temporal_attention``,
``fused_flush``) carry custom VJPs.  For gru/attention the default
backward is a real Pallas kernel (flash-style in-kernel recompute from the
input residuals — one HBM pass per operand); ``bwd="oracle"`` (or
``REPRO_KERNEL_BWD=oracle``) falls back to differentiating the pure-jnp
oracle from ``ref.py``, which is the parity reference and what the
``"xla"`` backend uses implicitly.  ``fused_flush`` always differentiates
through its oracle (``ref.flush_ref``) — the backward is dominated by the
same scatter/gather XLA handles for the forward XLA path.
"""

from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.fused_flush import fused_flush_fwd as _flush_pallas
from repro.kernels.fused_gru import fused_gru as _gru_pallas
from repro.kernels.fused_gru import fused_gru_bwd as _gru_bwd_pallas
from repro.kernels.neighbor_sample import neighbor_sample_fwd as _ns_pallas
from repro.kernels.rwkv6_scan import rwkv6_chunked as _wkv_pallas
from repro.kernels.temporal_attn import temporal_attn as _tattn_pallas
from repro.kernels.temporal_attn import temporal_attn_bwd as _tattn_bwd_pallas

__all__ = ["default_backend", "default_bwd", "gru", "temporal_attention",
           "fused_flush", "neighbor_sample", "flash_attention", "rwkv6"]


@functools.cache
def default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def _resolve(backend: str | None) -> str:
    if backend not in (None, "auto"):
        return backend
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in ("xla", "pallas", "interpret", "scan"):
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}: expected one of "
                "xla / pallas / interpret / scan")
        return env
    return default_backend()


def default_bwd() -> str:
    env = os.environ.get("REPRO_KERNEL_BWD")
    if env:
        if env not in ("fused", "oracle"):
            raise ValueError(
                f"REPRO_KERNEL_BWD={env!r}: expected fused / oracle")
        return env
    return "fused"


def _resolve_bwd(bwd: str | None) -> str:
    return bwd if bwd not in (None, "auto") else default_bwd()


# The TIG training scan differentiates through the fused kernels, but raw
# ``pallas_call`` has no transpose rule.  Fix: custom VJP.  The default
# backward (``bwd="fused"``) is a real Pallas kernel that recomputes the
# gates/softmax in VMEM from the input residuals; ``bwd="oracle"`` keeps
# the original fallback — differentiate the pure-jnp oracle (ref.py),
# recomputing the forward through XLA.  Both produce gradients identical
# to the XLA path (the kernels are validated against the oracles).

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _gru_fused(x, h, wx, wh, bx, bh, interpret, bwd):
    return _gru_pallas(x, h, wx, wh, bx, bh, interpret=interpret)


def _gru_fused_fwd(x, h, wx, wh, bx, bh, interpret, bwd):
    return (_gru_fused(x, h, wx, wh, bx, bh, interpret, bwd),
            (x, h, wx, wh, bx, bh))


def _gru_fused_bwd(interpret, bwd, res, g):
    if bwd == "oracle":
        _, vjp = jax.vjp(ref.gru_ref, *res)
        return vjp(g)
    return _gru_bwd_pallas(g, *res, interpret=interpret)


_gru_fused.defvjp(_gru_fused_fwd, _gru_fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _tattn_fused(q, k, v, mask, interpret, bwd):
    return _tattn_pallas(q, k, v, mask, interpret=interpret)


def _tattn_fused_fwd(q, k, v, mask, interpret, bwd):
    return _tattn_fused(q, k, v, mask, interpret, bwd), (q, k, v, mask)


def _tattn_fused_bwd(interpret, bwd, res, g):
    q, k, v, mask = res
    if bwd == "oracle":
        _, vjp = jax.vjp(
            lambda q_, k_, v_: ref.temporal_attention_ref(q_, k_, v_, mask),
            q, k, v)
        return (*vjp(g), None)
    return (*_tattn_bwd_pallas(g, q, k, v, mask, interpret=interpret), None)


_tattn_fused.defvjp(_tattn_fused_fwd, _tattn_fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(9,))
def _flush_fused(ids, msg, ts, mem, last, wx, wh, bx, bh, interpret):
    return _flush_pallas(ids, msg, ts, mem, last, wx, wh, bx, bh,
                         interpret=interpret)


def _flush_fused_fwd(ids, msg, ts, mem, last, wx, wh, bx, bh, interpret):
    return (_flush_fused(ids, msg, ts, mem, last, wx, wh, bx, bh, interpret),
            (ids, msg, ts, mem, last, wx, wh, bx, bh))


def _flush_fused_bwd(interpret, res, g):
    ids = res[0]
    _, vjp = jax.vjp(
        lambda *diff: ref.flush_ref(ids, *diff), *res[1:])
    return (None, *vjp(g))


_flush_fused.defvjp(_flush_fused_fwd, _flush_fused_bwd)


def gru(x, h, wx, wh, bx, bh, *, backend: str | None = None,
        bwd: str | None = None):
    b = _resolve(backend)
    if b in ("xla", "scan"):   # "scan" only exists for rwkv6 -> oracle here
        return ref.gru_ref(x, h, wx, wh, bx, bh)
    return _gru_fused(x, h, wx, wh, bx, bh, b == "interpret",
                      _resolve_bwd(bwd))


def temporal_attention(q, k, v, mask, *, backend: str | None = None,
                       bwd: str | None = None):
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.temporal_attention_ref(q, k, v, mask)
    return _tattn_fused(q, k, v, mask, b == "interpret", _resolve_bwd(bwd))


def fused_flush(ids, msg, ts, mem, last, wx, wh, bx, bh, *,
                backend: str | None = None):
    """The whole ``flush_pending`` message pipeline (segment-mean + GRU +
    mem/last scatter) as one kernel; ``(mem', last', mbar)``.  Backward is
    always the ``ref.flush_ref`` oracle VJP."""
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.flush_ref(ids, msg, ts, mem, last, wx, wh, bx, bh)
    return _flush_fused(ids, msg, ts, mem, last, wx, wh, bx, bh,
                        b == "interpret")


def neighbor_sample(tcsr, nodes, batch_of, k, *, backend: str | None = None):
    """K most recent temporal neighbors from a device-resident T-CSR.

    ``tcsr`` is the staged dict from ``ChronoNeighborIndex.device_export``
    (keys indptr / nbr / t / eidx / bat); nodes: (R,) int32; batch_of:
    scalar or (R,) int32 batch index (events of stream batches >= batch_of
    are excluded, history always included).  Returns ((R, k) ids, times,
    edge rows), -1 / -1.0 front-padded, oldest -> newest — bit-identical
    to ``ChronoNeighborIndex.sample``.

    Forward-only: sampling produces integer ids and already-materialized
    times before the differentiated section of the step, so there is no
    VJP to define.
    """
    b = _resolve(backend)
    args = (tcsr["indptr"], tcsr["nbr"], tcsr["t"], tcsr["eidx"],
            tcsr["bat"], nodes, batch_of)
    if b in ("xla", "scan"):
        return ref.sample_ref(*args, k)
    return _ns_pallas(*args, k=k, interpret=(b == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=None,
                    backend: str | None = None, block_q=128, block_k=128):
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=(b == "interpret"))


def rwkv6(r, k, v, w, u, *, state=None, chunk=64,
          backend: str | None = None, return_state=True):
    b = _resolve(backend)
    if b == "xla":
        # chunked XLA path (falls back to the token scan for short/ragged
        # sequences) — §Perf iteration B1: ~chunk-fold fewer state carries.
        o, s = ref.rwkv6_chunked_xla(r, k, v, w, u, state=state,
                                     chunk=chunk, return_state=True)
    elif b == "scan":
        o, s = ref.rwkv6_ref(r, k, v, w, u, state=state, return_state=True)
    else:
        o, s = _wkv_pallas(r, k, v, w, u, state=state, chunk=chunk,
                           interpret=(b == "interpret"))
    return (o, s) if return_state else o
