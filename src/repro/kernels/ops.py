"""Public kernel entry points with backend dispatch.

Each op picks its execution path:
  * ``backend="pallas"``     — pl.pallas_call targeting real TPUs,
  * ``backend="interpret"``  — the same kernel body executed in Python on
                               CPU (correctness validation; what tests use),
  * ``backend="xla"``        — the pure-jnp oracle from ``ref.py`` (what the
                               models use on CPU and in dry-runs; on TPU
                               deployments flip the default to "pallas").

``default_backend()`` resolves "auto": pallas on TPU, xla elsewhere.  The
``REPRO_KERNEL_BACKEND`` environment variable overrides the "auto"
resolution (e.g. ``REPRO_KERNEL_BACKEND=interpret`` exercises the Pallas
kernel bodies on CPU without touching any config).

Backward passes: the differentiable ops (``gru``, ``temporal_attention``,
``fused_flush``) carry custom VJPs.  For gru/attention the default
backward is a real Pallas kernel (flash-style in-kernel recompute from the
input residuals — one HBM pass per operand); ``bwd="oracle"`` (or
``REPRO_KERNEL_BWD=oracle``) falls back to differentiating the pure-jnp
oracle from ``ref.py``, which is the parity reference and what the
``"xla"`` backend uses implicitly.  ``fused_flush`` always differentiates
through its oracle (``ref.flush_ref``) — the backward is dominated by the
same scatter/gather XLA handles for the forward XLA path.

MXU alignment: the f32 TPU tile is (8, 128) and the MXU is 128x128, so
kernels fed unaligned feature dims waste tile columns.  The Pallas-bound
ops below lane-pad their feature dims to multiples of 128 (and the
neighbor axis to 8 sublanes) HERE, once, in plain differentiable jnp —
before the custom-VJP wrappers, so autodiff transposes pad -> slice for
free — and slice the results back.  The kernels themselves stay
shape-generic, and the ``ref.py`` oracles stay UNPADDED: parity tests
against them prove the padding is value-invariant.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.fused_flush import fused_flush_fwd as _flush_pallas
from repro.kernels.fused_gru import fused_gru as _gru_pallas
from repro.kernels.fused_gru import fused_gru_bwd as _gru_bwd_pallas
from repro.kernels.neighbor_sample import neighbor_sample_fwd as _ns_pallas
from repro.kernels.rwkv6_scan import rwkv6_chunked as _wkv_pallas
from repro.kernels.temporal_attn import temporal_attn as _tattn_pallas
from repro.kernels.temporal_attn import temporal_attn_bwd as _tattn_bwd_pallas

__all__ = ["default_backend", "default_bwd", "gru", "temporal_attention",
           "fused_flush", "neighbor_sample", "flash_attention", "rwkv6",
           "lane_pad", "LANES", "SUBLANES"]


@functools.cache
def default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def _resolve(backend: str | None) -> str:
    if backend not in (None, "auto"):
        return backend
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in ("xla", "pallas", "interpret", "scan"):
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}: expected one of "
                "xla / pallas / interpret / scan")
        return env
    return default_backend()


def default_bwd() -> str:
    env = os.environ.get("REPRO_KERNEL_BWD")
    if env:
        if env not in ("fused", "oracle"):
            raise ValueError(
                f"REPRO_KERNEL_BWD={env!r}: expected fused / oracle")
        return env
    return "fused"


def _resolve_bwd(bwd: str | None) -> str:
    return bwd if bwd not in (None, "auto") else default_bwd()


# ----------------------------------------------------------- MXU alignment

LANES = 128      # last-dim tile width (f32) — MXU columns
SUBLANES = 8     # second-to-last-dim tile height (f32)


def _pad_to(n: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``n``."""
    return -(-n // m) * m


def lane_pad(n: int) -> int:
    """Lane-aligned width of a feature dim: what the MXU tier actually
    launches for a raw dim ``n`` (compiled-program cache keys hash this)."""
    return _pad_to(n, LANES)


def _pad_axis(x, target: int, axis: int = -1):
    """Zero-pad ``x`` along ``axis`` up to length ``target`` (no-op when
    already there).  Plain jnp: under autodiff this transposes to a slice,
    keeping the custom-VJP kernels downstream oblivious to padding."""
    n = x.shape[axis]
    if n == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis % x.ndim] = (0, target - n)
    return jnp.pad(x, pad)


def _pad_gates(w, d_h: int, d_p: int, axis: int = -1):
    """Pad a GRU [r|z|n] gate matrix/bias from 3*d_h to 3*d_p along
    ``axis``, padding each gate block separately so kernels (and the
    oracle) that split gates at thirds keep addressing the right block."""
    if d_h == d_p:
        return w
    blocks = jnp.split(w, 3, axis=axis)
    return jnp.concatenate([_pad_axis(b, d_p, axis) for b in blocks],
                           axis=axis)


# The TIG training scan differentiates through the fused kernels, but raw
# ``pallas_call`` has no transpose rule.  Fix: custom VJP.  The default
# backward (``bwd="fused"``) is a real Pallas kernel that recomputes the
# gates/softmax in VMEM from the input residuals; ``bwd="oracle"`` keeps
# the original fallback — differentiate the pure-jnp oracle (ref.py),
# recomputing the forward through XLA.  Both produce gradients identical
# to the XLA path (the kernels are validated against the oracles).

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _gru_fused(x, h, wx, wh, bx, bh, interpret, bwd):
    return _gru_pallas(x, h, wx, wh, bx, bh, interpret=interpret)


def _gru_fused_fwd(x, h, wx, wh, bx, bh, interpret, bwd):
    return (_gru_fused(x, h, wx, wh, bx, bh, interpret, bwd),
            (x, h, wx, wh, bx, bh))


def _gru_fused_bwd(interpret, bwd, res, g):
    if bwd == "oracle":
        _, vjp = jax.vjp(ref.gru_ref, *res)
        return vjp(g)
    return _gru_bwd_pallas(g, *res, interpret=interpret)


_gru_fused.defvjp(_gru_fused_fwd, _gru_fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _tattn_fused(q, k, v, mask, interpret, bwd):
    return _tattn_pallas(q, k, v, mask, interpret=interpret)


def _tattn_fused_fwd(q, k, v, mask, interpret, bwd):
    return _tattn_fused(q, k, v, mask, interpret, bwd), (q, k, v, mask)


def _tattn_fused_bwd(interpret, bwd, res, g):
    q, k, v, mask = res
    if bwd == "oracle":
        _, vjp = jax.vjp(
            lambda q_, k_, v_: ref.temporal_attention_ref(q_, k_, v_, mask),
            q, k, v)
        return (*vjp(g), None)
    return (*_tattn_bwd_pallas(g, q, k, v, mask, interpret=interpret), None)


_tattn_fused.defvjp(_tattn_fused_fwd, _tattn_fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(9,))
def _flush_fused(ids, msg, ts, mem, last, wx, wh, bx, bh, interpret):
    return _flush_pallas(ids, msg, ts, mem, last, wx, wh, bx, bh,
                         interpret=interpret)


def _flush_fused_fwd(ids, msg, ts, mem, last, wx, wh, bx, bh, interpret):
    return (_flush_fused(ids, msg, ts, mem, last, wx, wh, bx, bh, interpret),
            (ids, msg, ts, mem, last, wx, wh, bx, bh))


def _flush_fused_bwd(interpret, res, g):
    ids = res[0]
    _, vjp = jax.vjp(
        lambda *diff: ref.flush_ref(ids, *diff), *res[1:])
    return (None, *vjp(g))


_flush_fused.defvjp(_flush_fused_fwd, _flush_fused_bwd)


def gru(x, h, wx, wh, bx, bh, *, backend: str | None = None,
        bwd: str | None = None):
    b = _resolve(backend)
    if b in ("xla", "scan"):   # "scan" only exists for rwkv6 -> oracle here
        return ref.gru_ref(x, h, wx, wh, bx, bh)
    # MXU tier: pad d_in and d_h up to 128 lanes.  Padded h columns are 0,
    # padded gate columns see zero pre-activations (r = z = 0.5, n = 0), so
    # padded outputs are (1-z)*0 + z*0 = 0 and real columns are unchanged.
    d_in, d_h = x.shape[-1], h.shape[-1]
    d_in_p, d_h_p = _pad_to(d_in, LANES), _pad_to(d_h, LANES)
    if (d_in_p, d_h_p) != (d_in, d_h):
        x = _pad_axis(x, d_in_p)
        h = _pad_axis(h, d_h_p)
        wx = _pad_gates(_pad_axis(wx, d_in_p, axis=0), d_h, d_h_p)
        wh = _pad_gates(_pad_axis(wh, d_h_p, axis=0), d_h, d_h_p)
        bx = _pad_gates(bx, d_h, d_h_p)
        bh = _pad_gates(bh, d_h, d_h_p)
    out = _gru_fused(x, h, wx, wh, bx, bh, b == "interpret",
                     _resolve_bwd(bwd))
    return out[..., :d_h]


def temporal_attention(q, k, v, mask, *, backend: str | None = None,
                       bwd: str | None = None):
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.temporal_attention_ref(q, k, v, mask)
    # MXU tier: pad the head dim D to 128 lanes and the neighbor axis K to
    # 8 sublanes (padded slots masked False).  Kernel and oracle both scale
    # scores by 1/sqrt(D of their input), so q is pre-scaled by
    # sqrt(D_p/D): the padded launch then computes the raw 1/sqrt(D)
    # scores exactly (zero-padded D columns add nothing to q.k).
    d, kn = q.shape[-1], k.shape[1]
    d_p, k_p = _pad_to(d, LANES), _pad_to(kn, SUBLANES)
    if d_p != d:
        q = q * jnp.sqrt(jnp.float32(d_p) / jnp.float32(d))
        q = _pad_axis(q, d_p)
        k = _pad_axis(k, d_p)
        v = _pad_axis(v, d_p)
    if k_p != kn:
        k = _pad_axis(k, k_p, axis=1)
        v = _pad_axis(v, k_p, axis=1)
        mask = _pad_axis(mask, k_p, axis=1)
    out = _tattn_fused(q, k, v, mask, b == "interpret", _resolve_bwd(bwd))
    return out[..., :d]


def fused_flush(ids, msg, ts, mem, last, wx, wh, bx, bh, *,
                backend: str | None = None):
    """The whole ``flush_pending`` message pipeline (segment-mean + GRU +
    mem/last scatter) as one kernel; ``(mem', last', mbar)``.  Backward is
    always the ``ref.flush_ref`` oracle VJP."""
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.flush_ref(ids, msg, ts, mem, last, wx, wh, bx, bh)
    # MXU tier: pad ONLY the message (d_msg) side — msg columns plus the
    # matching wx rows (zero rows contribute nothing to the gate matmul).
    # The (N+1, d) memory table is aliased in place; padding d_h would
    # reintroduce O(N) HBM traffic the kernel exists to avoid.
    dm = msg.shape[-1]
    dm_p = _pad_to(dm, LANES)
    if dm_p != dm:
        msg = _pad_axis(msg, dm_p)
        wx = _pad_axis(wx, dm_p, axis=0)
    mem2, last2, mbar = _flush_fused(ids, msg, ts, mem, last, wx, wh,
                                     bx, bh, b == "interpret")
    return mem2, last2, mbar[..., :dm]


def neighbor_sample(tcsr, nodes, batch_of, k, *, backend: str | None = None,
                    window=None):
    """K most recent temporal neighbors from a device-resident T-CSR.

    ``tcsr`` is the staged dict from ``ChronoNeighborIndex.device_export``
    (keys indptr / nbr / t / eidx / bat); nodes: (R,) int32; batch_of:
    scalar or (R,) int32 batch index (events of stream batches >= batch_of
    are excluded, history always included); window: None (= 0), scalar or
    (R,) int32 K-window shift — window w returns events
    ``[end-(w+1)K, end-wK)``, the multi-layer fold's per-layer grids
    (requires an export with depth > w).  Returns ((R, k) ids, times,
    edge rows), -1 / -1.0 front-padded, oldest -> newest — bit-identical
    to ``ChronoNeighborIndex.sample``.

    Forward-only: sampling produces integer ids and already-materialized
    times before the differentiated section of the step, so there is no
    VJP to define.
    """
    b = _resolve(backend)
    args = (tcsr["indptr"], tcsr["nbr"], tcsr["t"], tcsr["eidx"],
            tcsr["bat"], nodes, batch_of)
    if b in ("xla", "scan"):
        return ref.sample_ref(*args, k, 0 if window is None else window)
    return _ns_pallas(*args, k=k, interpret=(b == "interpret"),
                      window=window)


def flash_attention(q, k, v, *, causal=True, window=None,
                    backend: str | None = None, block_q=128, block_k=128):
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=(b == "interpret"))


def rwkv6(r, k, v, w, u, *, state=None, chunk=64,
          backend: str | None = None, return_state=True):
    b = _resolve(backend)
    if b == "xla":
        # chunked XLA path (falls back to the token scan for short/ragged
        # sequences) — §Perf iteration B1: ~chunk-fold fewer state carries.
        o, s = ref.rwkv6_chunked_xla(r, k, v, w, u, state=state,
                                     chunk=chunk, return_state=True)
    elif b == "scan":
        o, s = ref.rwkv6_ref(r, k, v, w, u, state=state, return_state=True)
    else:
        o, s = _wkv_pallas(r, k, v, w, u, state=state, chunk=chunk,
                           interpret=(b == "interpret"))
    return (o, s) if return_state else o
