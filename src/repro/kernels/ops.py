"""Public kernel entry points with backend dispatch.

Each op picks its execution path:
  * ``backend="pallas"``     — pl.pallas_call targeting real TPUs,
  * ``backend="interpret"``  — the same kernel body executed in Python on
                               CPU (correctness validation; what tests use),
  * ``backend="xla"``        — the pure-jnp oracle from ``ref.py`` (what the
                               models use on CPU and in dry-runs; on TPU
                               deployments flip the default to "pallas").

``default_backend()`` resolves "auto": pallas on TPU, xla elsewhere.  The
``REPRO_KERNEL_BACKEND`` environment variable overrides the "auto"
resolution (e.g. ``REPRO_KERNEL_BACKEND=interpret`` exercises the Pallas
kernel bodies on CPU without touching any config).
"""

from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.fused_gru import fused_gru as _gru_pallas
from repro.kernels.rwkv6_scan import rwkv6_chunked as _wkv_pallas
from repro.kernels.temporal_attn import temporal_attn as _tattn_pallas

__all__ = ["default_backend", "gru", "temporal_attention",
           "flash_attention", "rwkv6"]


@functools.cache
def default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def _resolve(backend: str | None) -> str:
    if backend not in (None, "auto"):
        return backend
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in ("xla", "pallas", "interpret", "scan"):
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}: expected one of "
                "xla / pallas / interpret / scan")
        return env
    return default_backend()


# The TIG training scan differentiates through the fused kernels, but raw
# ``pallas_call`` has no transpose rule.  Standard fix: custom VJP — fused
# Pallas forward, pure-jnp oracle (ref.py) recomputation backward.  The
# oracles are exact (the kernels are validated against them), so gradients
# are identical to the XLA path.

@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _gru_fused(x, h, wx, wh, bx, bh, interpret):
    return _gru_pallas(x, h, wx, wh, bx, bh, interpret=interpret)


def _gru_fused_fwd(x, h, wx, wh, bx, bh, interpret):
    return _gru_fused(x, h, wx, wh, bx, bh, interpret), (x, h, wx, wh, bx, bh)


def _gru_fused_bwd(interpret, res, g):
    _, vjp = jax.vjp(ref.gru_ref, *res)
    return vjp(g)


_gru_fused.defvjp(_gru_fused_fwd, _gru_fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _tattn_fused(q, k, v, mask, interpret):
    return _tattn_pallas(q, k, v, mask, interpret=interpret)


def _tattn_fused_fwd(q, k, v, mask, interpret):
    return _tattn_fused(q, k, v, mask, interpret), (q, k, v, mask)


def _tattn_fused_bwd(interpret, res, g):
    q, k, v, mask = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.temporal_attention_ref(q_, k_, v_, mask),
        q, k, v)
    return (*vjp(g), None)


_tattn_fused.defvjp(_tattn_fused_fwd, _tattn_fused_bwd)


def gru(x, h, wx, wh, bx, bh, *, backend: str | None = None):
    b = _resolve(backend)
    if b in ("xla", "scan"):   # "scan" only exists for rwkv6 -> oracle here
        return ref.gru_ref(x, h, wx, wh, bx, bh)
    return _gru_fused(x, h, wx, wh, bx, bh, b == "interpret")


def temporal_attention(q, k, v, mask, *, backend: str | None = None):
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.temporal_attention_ref(q, k, v, mask)
    return _tattn_fused(q, k, v, mask, b == "interpret")


def flash_attention(q, k, v, *, causal=True, window=None,
                    backend: str | None = None, block_q=128, block_k=128):
    b = _resolve(backend)
    if b in ("xla", "scan"):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=(b == "interpret"))


def rwkv6(r, k, v, w, u, *, state=None, chunk=64,
          backend: str | None = None, return_state=True):
    b = _resolve(backend)
    if b == "xla":
        # chunked XLA path (falls back to the token scan for short/ragged
        # sequences) — §Perf iteration B1: ~chunk-fold fewer state carries.
        o, s = ref.rwkv6_chunked_xla(r, k, v, w, u, state=state,
                                     chunk=chunk, return_state=True)
    elif b == "scan":
        o, s = ref.rwkv6_ref(r, k, v, w, u, state=state, return_state=True)
    else:
        o, s = _wkv_pallas(r, k, v, w, u, state=state, chunk=chunk,
                           interpret=(b == "interpret"))
    return (o, s) if return_state else o
