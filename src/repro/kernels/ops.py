"""Public kernel entry points with backend dispatch.

Each op picks its execution path:
  * ``backend="pallas"``     — pl.pallas_call targeting real TPUs,
  * ``backend="interpret"``  — the same kernel body executed in Python on
                               CPU (correctness validation; what tests use),
  * ``backend="xla"``        — the pure-jnp oracle from ``ref.py`` (what the
                               models use on CPU and in dry-runs; on TPU
                               deployments flip the default to "pallas").

``default_backend()`` resolves "auto": pallas on TPU, xla elsewhere.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.fused_gru import fused_gru as _gru_pallas
from repro.kernels.rwkv6_scan import rwkv6_chunked as _wkv_pallas
from repro.kernels.temporal_attn import temporal_attn as _tattn_pallas

__all__ = ["default_backend", "gru", "temporal_attention",
           "flash_attention", "rwkv6"]


@functools.cache
def default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def _resolve(backend: str | None) -> str:
    return backend if backend not in (None, "auto") else default_backend()


def gru(x, h, wx, wh, bx, bh, *, backend: str | None = None):
    b = _resolve(backend)
    if b == "xla":
        return ref.gru_ref(x, h, wx, wh, bx, bh)
    return _gru_pallas(x, h, wx, wh, bx, bh, interpret=(b == "interpret"))


def temporal_attention(q, k, v, mask, *, backend: str | None = None):
    b = _resolve(backend)
    if b == "xla":
        return ref.temporal_attention_ref(q, k, v, mask)
    return _tattn_pallas(q, k, v, mask, interpret=(b == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=None,
                    backend: str | None = None, block_q=128, block_k=128):
    b = _resolve(backend)
    if b == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=(b == "interpret"))


def rwkv6(r, k, v, w, u, *, state=None, chunk=64,
          backend: str | None = None, return_state=True):
    b = _resolve(backend)
    if b == "xla":
        # chunked XLA path (falls back to the token scan for short/ragged
        # sequences) — §Perf iteration B1: ~chunk-fold fewer state carries.
        o, s = ref.rwkv6_chunked_xla(r, k, v, w, u, state=state,
                                     chunk=chunk, return_state=True)
    elif b == "scan":
        o, s = ref.rwkv6_ref(r, k, v, w, u, state=state, return_state=True)
    else:
        o, s = _wkv_pallas(r, k, v, w, u, state=state, chunk=chunk,
                           interpret=(b == "interpret"))
    return (o, s) if return_state else o
