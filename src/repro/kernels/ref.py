"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernels are validated against them in
``tests/test_kernels.py`` over shape/dtype sweeps (interpret=True on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gru_ref",
    "temporal_attention_ref",
    "flush_ref",
    "sample_ref",
    "flash_attention_ref",
    "rwkv6_ref",
    "rwkv6_chunked_xla",
]


def gru_ref(x, h, wx, wh, bx, bh):
    """Fused GRU cell oracle.

    x: (B, d_in), h: (B, d_h); wx: (d_in, 3*d_h), wh: (d_h, 3*d_h);
    biases (3*d_h,).  Gate order: [reset, update, candidate] (matches
    ``repro.tig.modules.gru``).
    """
    gx = x @ wx + bx
    gh = h @ wh + bh
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * n + z * h


def temporal_attention_ref(q, k, v, mask):
    """Masked neighbor attention oracle.

    q: (B, H, D); k, v: (B, K, H, D); mask: (B, K) bool.
    Rows with no valid neighbor yield exactly zero context.
    """
    scores = jnp.einsum("bhd,bkhd->bhk", q, k) / jnp.sqrt(
        jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    att = jnp.where(mask.any(-1)[:, None, None], att, 0.0)
    return jnp.einsum("bhk,bkhd->bhd", att, v)


def flush_ref(ids, msg, ts, mem, last, wx, wh, bx, bh):
    """Message-pipeline oracle: segment-mean aggregation of the pending
    messages + GRU memory update + scatter of ``mem``/``last``.

    This is exactly the XLA path of ``repro.tig.models.flush_pending`` for
    the GRU flavors (the fused Pallas kernel in ``fused_flush.py`` is
    validated against it, and its custom VJP recomputes through it).

    ids: (R,) int32 touched rows (dump row ``mem.shape[0]-1`` = padding);
    msg: (R, dm) post-MSG messages; ts: (R,) event times; mem: (N+1, d);
    last: (N+1,); wx/wh/bx/bh: GRU gate parameters.
    Returns ``(mem', last', mbar)`` with ``mbar`` the (R, dm) per-row
    aggregated messages (consumed by TIGE's second-memory update).
    """
    n_dump = mem.shape[0] - 1
    live = ids < n_dump
    zeros = jnp.zeros((n_dump + 1, msg.shape[-1]), msg.dtype)
    sums = zeros.at[ids].add(jnp.where(live[:, None], msg, 0.0))
    cnt = jnp.zeros((n_dump + 1,), msg.dtype).at[ids].add(
        live.astype(msg.dtype))
    mbar_tbl = sums / jnp.clip(cnt, 1.0)[:, None]
    mbar = mbar_tbl[ids]
    s_new = gru_ref(mbar, mem[ids], wx, wh, bx, bh)
    mem = mem.at[ids].set(s_new).at[n_dump].set(0.0)
    last = last.at[ids].max(jnp.where(live, ts, 0.0)).at[n_dump].set(0.0)
    return mem, last, mbar


def sample_ref(indptr, nbr, t, eidx, bat, nodes, batch_of, k, window=0):
    """Device-side temporal neighbor sampling oracle over an exported T-CSR.

    Mirrors ``ChronoNeighborIndex.sample`` bit-for-bit on device: for each
    queried node a branchless binary search over the node's time-sorted
    event segment finds the first event of stream batch >= ``batch_of``
    (events carry the key ``batch + 1`` with history pinned to 0), then the
    K-wide window ``[end-(w+1)k, end-wk)`` before it is gathered, -1
    front-padded, oldest -> newest (w = ``window``, default 0 = most
    recent; the multi-layer fold passes per-row windows).

    indptr: (N+1,) int32 and nbr / t / eidx / bat: (pad + total,) arrays
    from ``ChronoNeighborIndex.device_export`` (front-padded by k*depth,
    so every window w < depth never underflows); nodes: (R,) int32 node
    ids; batch_of: scalar or (R,) int32 batch index — events of stream
    batches >= batch_of are excluded, history always included; window:
    scalar or (R,) int32.  Returns ((R, k) int32 ids, (R, k) float32
    times, (R, k) int32 edge rows).
    """
    total = nbr.shape[0]
    nodes = nodes.astype(jnp.int32)
    start = indptr[nodes]
    stop = indptr[nodes + 1]
    key = jnp.broadcast_to(
        jnp.asarray(batch_of, jnp.int32) + 1, nodes.shape)
    win = jnp.broadcast_to(jnp.asarray(window, jnp.int32), nodes.shape)
    # branchless bisect_left for `key` within [start, stop); the iteration
    # count is static (log2 of the buffer covers any segment length)
    lo, hi = start, stop
    for _ in range(max(1, int(total).bit_length())):
        mid = (lo + hi) // 2
        v = bat[jnp.minimum(mid, total - 1)]
        active = lo < hi
        go = active & (v < key)
        lo = jnp.where(go, mid + 1, lo)
        hi = jnp.where(active & ~go, mid, hi)
    end = lo
    idx = (end[:, None] - (win[:, None] + 1) * k
           + jnp.arange(k, dtype=jnp.int32)[None, :])
    valid = idx >= start[:, None]
    # in-bounds even if a caller passes window >= export depth (those
    # slots are already masked invalid); a no-op at window = 0
    idx = jnp.maximum(idx, 0)
    ids = jnp.where(valid, nbr[idx], -1)
    tms = jnp.where(valid, t[idx], jnp.float32(-1.0))
    eix = jnp.where(valid, eidx[idx], -1)
    return ids, tms, eix


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Dense attention oracle (the thing flash attention must equal).

    q, k, v: (B, H, S, D).  ``window``: sliding-window size (#tokens each
    query may look back, incl. itself); None = unbounded.
    """
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / jnp.sqrt(
        jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    m = jnp.ones((s, s), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    logits = jnp.where(m, logits, -1e30)
    att = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", att,
                      v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_ref(r, k, v, w, u, *, state=None, return_state=False):
    """RWKV6 (Finch) WKV recurrence oracle — token-by-token scan.

    r, k, w: (B, H, S, Dk); v: (B, H, S, Dv); u: (H, Dk).
    ``w`` is the per-channel decay in (0, 1) (data-dependent in v6).
    state: optional (B, H, Dk, Dv) initial state.

        o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)

    def step(S, inp):
        rt, kt, vt, wt = inp      # (B,H,Dk) x3, (B,H,Dv)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,Dk,Dv)
        o = jnp.einsum("bhk,bhkv->bhv", rt,
                       S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, o

    inputs = (jnp.moveaxis(r, 2, 0), jnp.moveaxis(k, 2, 0),
              jnp.moveaxis(v, 2, 0), jnp.moveaxis(w, 2, 0))
    state, o = jax.lax.scan(step, state, inputs)
    o = jnp.moveaxis(o, 0, 2)     # (B, H, S, Dv)
    if return_state:
        return o, state
    return o


def rwkv6_chunked_xla(r, k, v, w, u, *, state=None, chunk: int = 64,
                      return_state: bool = False):
    """Chunked WKV6 in pure XLA — the same matmul reformulation as the
    Pallas kernel (see rwkv6_scan.py for the math), used as the production
    XLA path: the token-by-token scan round-trips the (B,H,Dk,Dv) state
    through HBM S times; chunking turns that into S/C state carries plus
    three MXU matmuls per chunk (§Perf iteration B1)."""
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    if s % chunk or s <= chunk:
        return rwkv6_ref(r, k, v, w, u, state=state,
                         return_state=return_state)
    nc = s // chunk
    f32 = jnp.float32
    rr, kk, vv, ww = (jnp.reshape(x.astype(f32), (b, h, nc, chunk, -1))
                      for x in (r, k, v, w))
    u = u.astype(f32)
    lw = jnp.log(jnp.clip(ww, 1e-38, 1.0))           # (B,H,NC,C,Dk)
    c = jnp.cumsum(lw, axis=-2)
    c_prev = c - lw
    c_tot = c[..., -1:, :]                            # (B,H,NC,1,Dk)
    z = 0.5 * c_tot

    r_dec = rr * jnp.exp(c_prev - z)
    k_dec = kk * jnp.exp(z - c)
    scores = jnp.einsum("bhnid,bhnjd->bhnij", r_dec, k_dec)
    ti = jnp.arange(chunk)
    scores = jnp.where(ti[None, :] < ti[:, None], scores, 0.0)
    intra = jnp.einsum("bhnij,bhnjd->bhnid", scores, vv)
    bonus = jnp.sum(rr * u[None, :, None, None, :] * kk,
                    axis=-1, keepdims=True) * vv

    # inter-chunk: sequential state carry (S/C steps instead of S)
    r_in = rr * jnp.exp(c_prev)                       # (B,H,NC,C,Dk)
    k_carry = kk * jnp.exp(c_tot - c)
    decay_tot = jnp.exp(c_tot[..., 0, :])             # (B,H,NC,Dk)
    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)

    def step(s0, inp):
        r_c, kc_c, v_c, dec = inp                     # per-chunk blocks
        inter = jnp.einsum("bhid,bhdv->bhiv", r_c, s0)
        s1 = dec[..., None] * s0 + jnp.einsum("bhjd,bhjv->bhdv", kc_c, v_c)
        return s1, inter

    xs = (jnp.moveaxis(r_in, 2, 0), jnp.moveaxis(k_carry, 2, 0),
          jnp.moveaxis(vv, 2, 0), jnp.moveaxis(decay_tot, 2, 0))
    state, inter = jax.lax.scan(step, state, xs)
    inter = jnp.moveaxis(inter, 0, 2)                 # (B,H,NC,C,Dv)

    o = (intra + bonus + inter).reshape(b, h, s, dv).astype(r.dtype)
    if return_state:
        return o, state
    return o
