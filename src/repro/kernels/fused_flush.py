"""Fused TGN message-pipeline kernel — Pallas TPU.

``flush_pending`` (repro.tig.models) applies the previous batch's stashed
messages to node memory: segment-mean aggregation of the (R=2B, d_msg)
pending messages per touched node, a GRU update of those nodes' memory
rows, and a scatter of the new ``mem``/``last`` values.  The XLA path
materializes two (N+1, d_msg) aggregation tables (scatter-add sums +
counts), divides over the FULL table, gathers back, and functionally
updates the (N+1, d) memory — O(N) HBM traffic per step for work that only
touches 2B rows.  TGL (Zhou et al., 2022) identifies exactly this
mailbox/memory-update scatter as the step-time bottleneck at scale.

This kernel does the whole pipeline in one ``pallas_call`` with O(R) HBM
traffic:

  * grid over the R touched rows (+1 cleanup step), one row per step;
  * ``ids`` ride in scalar-prefetch SMEM, so the BlockSpec index maps
    gather row ``ids[i]`` of ``mem``/``last`` straight into VMEM and
    scatter the results back — no aggregation tables, no O(N) pass;
  * the segment mean is an equality-mask matvec against the VMEM-resident
    (R, d_msg) message block: rows of one node see identical ``mbar``;
  * gate math (the GRU) runs in VMEM on the gathered row;
  * ``mem``/``last`` are input/output-aliased, so untouched rows are
    untouched in HBM.

Duplicate ids write identical values, but a *later* duplicate would
re-read a row the first occurrence already updated (the buffers are
aliased), so the wrapper redirects every non-first occurrence's write to
the dump row, which the final grid step re-zeroes anyway.  Reads of
already-written rows then only happen for rows whose output is discarded.

MXU alignment: the public wrapper (``kernels/ops.py``) pads ONLY the
d_msg side (message columns + the wx gate blocks) to a multiple of 128
lanes before calling this kernel.  The memory table is aliased in place
and must keep its raw width — padding d_mem would force an O(N) copy and
defeat the O(R)-traffic point of the kernel.  Padded message columns feed
zero weight rows, so the gate pre-activations (and hence mem/last/mbar on
the raw columns) are bit-identical to the unpadded call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_flush_fwd"]


def _flush_kernel(ids_r_ref, ids_w_ref, msg_ref, ids_v_ref, ts_ref,
                  mem_ref, last_ref, wx_ref, wh_ref, bx_ref, bh_ref,
                  mem_out_ref, last_out_ref, mbar_ref, *, n_rows, n_dump):
    i = pl.program_id(0)

    @pl.when(i >= n_rows)
    def _zero_dump():
        # final step: the dump row collected padding + duplicate writes
        mem_out_ref[...] = jnp.zeros_like(mem_out_ref)
        last_out_ref[...] = jnp.zeros_like(last_out_ref)

    @pl.when(i < n_rows)
    def _row():
        f32 = jnp.float32
        id_i = ids_r_ref[i]
        ids_v = ids_v_ref[...]                       # (1, R) int32
        live = ids_v < n_dump
        eq = jnp.logical_and(ids_v == id_i, live)    # (1, R)
        eqf = eq.astype(f32)

        # segment mean over this node's pending rows (msg resident in VMEM)
        cnt = jnp.sum(eqf)
        sums = jnp.dot(eqf, msg_ref[...].astype(f32),
                       preferred_element_type=f32)   # (1, dm)
        mbar = sums / jnp.maximum(cnt, 1.0)

        # GRU gate math in VMEM on the gathered memory row
        s_old = mem_ref[...].astype(f32)             # (1, d)
        gx = jnp.dot(mbar, wx_ref[...].astype(f32),
                     preferred_element_type=f32) + bx_ref[...]
        gh = jnp.dot(s_old, wh_ref[...].astype(f32),
                     preferred_element_type=f32) + bh_ref[...]
        d_h = s_old.shape[-1]
        r = jax.nn.sigmoid(gx[:, :d_h] + gh[:, :d_h])
        z = jax.nn.sigmoid(gx[:, d_h:2 * d_h] + gh[:, d_h:2 * d_h])
        n = jnp.tanh(gx[:, 2 * d_h:] + r * gh[:, 2 * d_h:])
        s_new = (1.0 - z) * n + z * s_old

        tmax = jnp.max(jnp.where(eq, ts_ref[...], -3.4e38))
        mem_out_ref[...] = s_new.astype(mem_out_ref.dtype)
        last_out_ref[...] = jnp.maximum(
            last_ref[...], tmax).astype(last_out_ref.dtype)
        mbar_ref[...] = mbar.astype(mbar_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_flush_fwd(ids, msg, ts, mem, last, wx, wh, bx, bh, *,
                    interpret: bool = False):
    """Segment-mean + GRU + scatter in one launch.

    ids: (R,) int32; msg: (R, dm); ts: (R,); mem: (N+1, d); last: (N+1,);
    GRU weights as in ``ref.gru_ref``.  Returns ``(mem', last', mbar)``
    matching ``ref.flush_ref``.
    """
    n_rows, dm = msg.shape
    n1, d = mem.shape
    n_dump = n1 - 1
    ids = ids.astype(jnp.int32)

    # redirect non-first duplicate writes to the dump row (see module doc)
    dup = jnp.tril(ids[:, None] == ids[None, :], k=-1).any(axis=1)
    pad = jnp.full((1,), n_dump, jnp.int32)
    ids_r = jnp.concatenate([ids, pad])
    ids_w = jnp.concatenate([jnp.where(dup, n_dump, ids), pad])

    kernel = functools.partial(_flush_kernel, n_rows=n_rows, n_dump=n_dump)
    const2 = lambda rows, cols: pl.BlockSpec(
        (rows, cols), lambda i, ir, iw: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_rows + 1,),
        in_specs=[
            const2(n_rows, dm),                               # msg
            const2(1, n_rows),                                # ids (vector)
            const2(1, n_rows),                                # ts  (vector)
            pl.BlockSpec((1, d), lambda i, ir, iw: (ir[i], 0)),   # mem row
            pl.BlockSpec((1, 1), lambda i, ir, iw: (ir[i], 0)),   # last row
            const2(dm, 3 * d),                                # wx
            const2(d, 3 * d),                                 # wh
            const2(1, 3 * d),                                 # bx
            const2(1, 3 * d),                                 # bh
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, ir, iw: (iw[i], 0)),   # mem'
            pl.BlockSpec((1, 1), lambda i, ir, iw: (iw[i], 0)),   # last'
            pl.BlockSpec(
                (1, dm),
                lambda i, ir, iw: (jnp.minimum(i, n_rows - 1), 0)),  # mbar
        ],
    )
    mem_out, last_out, mbar = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n1, d), mem.dtype),
            jax.ShapeDtypeStruct((n1, 1), last.dtype),
            jax.ShapeDtypeStruct((n_rows, dm), msg.dtype),
        ],
        # inputs count scalar-prefetch args: 5 = mem, 6 = last
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(ids_r, ids_w, msg, ids[None, :], ts[None, :].astype(last.dtype),
      mem, last[:, None], wx, wh, bx[None, :], bh[None, :])
    return mem_out, last_out[:, 0], mbar
