"""Chunked RWKV6 (Finch) WKV recurrence — Pallas TPU kernel.

The WKV recurrence (per head, per batch)

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T        w_t in (0,1), data-dependent

is sequential per token — useless for the MXU if evaluated naively.  The
chunked reformulation (chunk C tokens, log-space cumulative decays
c_t = sum_{s<=t} log w_s within the chunk):

    inter-chunk:  o_t += (r_t ⊙ exp(c_{t-1}))^T  S_0
    intra-chunk:  o_t += sum_{j<t} [(r_t ⊙ exp(c_{t-1} - z)) · (k_j ⊙
                         exp(z - c_j))] v_j           (one (C,C) matmul!)
    bonus:        o_t += ((r_t ⊙ u) · k_t) v_t
    state:        S_C  = diag(exp(c_C)) S_0 + (k ⊙ exp(c_C - c))^T V

where z is any per-channel shift (we use c_C / 2 to center the exponents —
keeps everything within fp32 range for |log w|·C ≲ 80).  This turns the
recurrence into three MXU matmuls per chunk plus one rank-C state update.

Grid: (B*H, S/C) — the trailing chunk axis executes sequentially on TPU, so
the running state lives in VMEM scratch and is carried across chunks; the
final state is emitted for decode-time continuation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_chunked"]


def _wkv_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, s0_ref,
                o_ref, sout_ref, state, *, chunk, num_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)       # (C, Dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)       # (C, Dv)
    lw = logw_ref[0].astype(jnp.float32)   # (C, Dk) log-decay (negative)
    u = u_ref[0].astype(jnp.float32)       # (1, Dk) bonus

    c = jnp.cumsum(lw, axis=0)             # (C, Dk) inclusive cumulative
    c_prev = c - lw                        # exclusive: c_{t-1}
    c_tot = c[-1]                          # (Dk,)
    z = 0.5 * c_tot                        # exponent-centering shift

    r_dec = r * jnp.exp(c_prev - z)        # (C, Dk)
    k_dec = k * jnp.exp(z - c)             # (C, Dk)

    s0 = state[...]                        # (Dk, Dv)

    # inter-chunk: queries see the carried state
    o = jax.lax.dot_general(
        r * jnp.exp(c_prev), s0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (C, Dv)

    # intra-chunk: strictly-lower-triangular token mixing
    scores = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(tj < ti, scores, 0.0)
    o = o + jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # current-token bonus
    o = o + jnp.sum(r * u * k, axis=-1, keepdims=True) * v

    o_ref[0] = o.astype(o_ref.dtype)

    # state update: S_C = diag(exp(c_tot)) S_0 + (k ⊙ exp(c_tot - c))^T V
    k_carry = k * jnp.exp(c_tot[None, :] - c)            # (C, Dk)
    state[...] = jnp.exp(c_tot)[:, None] * s0 + jax.lax.dot_general(
        k_carry, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        sout_ref[0] = state[...].astype(sout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def rwkv6_chunked(r, k, v, w, u, *, state=None, chunk: int = 64,
                  interpret: bool = False):
    """Chunked WKV6.  r,k,w: (B,H,S,Dk); v: (B,H,S,Dv); u: (H,Dk);
    optional state (B,H,Dk,Dv).  Returns (o, final_state).

    S must be a multiple of ``chunk`` (pad upstream)."""
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    num_chunks = s // chunk
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    bh = b * h
    rr = r.reshape(bh, s, dk)
    kk = k.reshape(bh, s, dk)
    vv = v.reshape(bh, s, dv)
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-38, 1.0)
                 ).reshape(bh, s, dk)
    uu = jnp.broadcast_to(u[None], (b, h, dk)).reshape(bh, 1, dk)
    s0 = state.reshape(bh, dk, dv)

    kernel = functools.partial(_wkv_kernel, chunk=chunk,
                               num_chunks=num_chunks)
    o, s_out = pl.pallas_call(
        kernel,
        grid=(bh, num_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, dk), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, c: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, uu, s0)
    return o.reshape(b, h, s, dv), s_out.reshape(b, h, dk, dv)
