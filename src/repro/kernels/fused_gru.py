"""Fused GRU cell — Pallas TPU kernels (forward and backward).

The TIG memory update (paper Fig.6 UPD module) applies a GRU to every node
touched by a batch: rows (B, d_in) x (B, d_h).  Unfused, XLA emits two gate
matmuls plus ~10 elementwise HBM round-trips over (B, 3*d_h) intermediates.
The forward kernel keeps the gate activations in VMEM: one pass over HBM
for x, h and the weights, one write for h'.

The backward kernel is flash-attention-style: no gate activations are
saved as residuals — r/z/n are recomputed in VMEM from (x, h, weights),
so the backward pass reads each operand from HBM exactly once and writes
each gradient exactly once.  Weight/bias gradients are accumulated across
the row-block grid in a VMEM-resident output block (TPU grids execute
sequentially, making the revisited block a legal carry).

Tiling: grid over row blocks of ``block_b``; both weight matrices are small
(d <= 512 in TIG models) and are resident in VMEM for every grid step.
d_h is padded to a multiple of 128 lanes by the wrapper (ops.py), so the
(d_in, 3*d_h) matmuls hit the MXU with aligned shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_gru", "fused_gru_bwd"]


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, bx_ref, bh_ref, out_ref):
    x = x_ref[...]
    h = h_ref[...]
    gx = jnp.dot(x, wx_ref[...],
                 preferred_element_type=jnp.float32) + bx_ref[...]
    gh = jnp.dot(h, wh_ref[...],
                 preferred_element_type=jnp.float32) + bh_ref[...]
    d_h = h.shape[-1]
    rx, zx, nx = gx[:, :d_h], gx[:, d_h:2 * d_h], gx[:, 2 * d_h:]
    rh, zh, nh = gh[:, :d_h], gh[:, d_h:2 * d_h], gh[:, 2 * d_h:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    out_ref[...] = ((1.0 - z) * n + z * h).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_gru(x, h, wx, wh, bx, bh, *, block_b: int = 128,
              interpret: bool = False):
    """h' = GRU(x, h).  Shapes: x (B, d_in), h (B, d_h), wx (d_in, 3*d_h),
    wh (d_h, 3*d_h), bx/bh (3*d_h,)."""
    b, d_in = x.shape
    d_h = h.shape[-1]
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d_h), lambda i: (i, 0)),
            pl.BlockSpec((d_in, 3 * d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h, 3 * d_h), lambda i: (0, 0)),
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d_h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d_h), h.dtype),
        interpret=interpret,
    )(x, h, wx, wh, bx, bh)


def _gru_bwd_kernel(g_ref, x_ref, h_ref, wx_ref, wh_ref, bx_ref, bh_ref,
                    dx_ref, dh_ref, dwx_ref, dwh_ref, dbx_ref, dbh_ref, *,
                    n_rows, block_b):
    i = pl.program_id(0)
    f32 = jnp.float32
    g = g_ref[...].astype(f32)
    x = x_ref[...].astype(f32)
    h = h_ref[...].astype(f32)
    # rows past n_rows are block padding: mask them out of the weight/bias
    # accumulators (their dx/dh writes are dropped by the block machinery)
    row = i * block_b + jax.lax.broadcasted_iota(jnp.int32, (block_b, 1), 0)
    valid = row < n_rows
    x = jnp.where(valid, x, 0.0)
    h = jnp.where(valid, h, 0.0)
    g = jnp.where(valid, g, 0.0)

    # in-VMEM recompute of the gates from the (x, h, weights) residuals
    gx = jnp.dot(x, wx_ref[...].astype(f32),
                 preferred_element_type=f32) + bx_ref[...]
    gh = jnp.dot(h, wh_ref[...].astype(f32),
                 preferred_element_type=f32) + bh_ref[...]
    d_h = h.shape[-1]
    rx, zx, nx = gx[:, :d_h], gx[:, d_h:2 * d_h], gx[:, 2 * d_h:]
    rh, zh, nh = gh[:, :d_h], gh[:, d_h:2 * d_h], gh[:, 2 * d_h:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)

    # out = (1-z)*n + z*h
    dn = g * (1.0 - z)
    dz = g * (h - n)
    dpre_n = dn * (1.0 - n * n)
    dpre_r = (dpre_n * nh) * r * (1.0 - r)
    dpre_z = dz * z * (1.0 - z)
    dgx = jnp.concatenate([dpre_r, dpre_z, dpre_n], axis=-1)
    dgh = jnp.concatenate([dpre_r, dpre_z, dpre_n * r], axis=-1)

    t_dims = (((1,), (1,)), ((), ()))      # contract gate axis: dg @ w.T
    a_dims = (((0,), (0,)), ((), ()))      # contract row axis:  op.T @ dg
    dx_ref[...] = jax.lax.dot_general(
        dgx, wx_ref[...].astype(f32), t_dims,
        preferred_element_type=f32).astype(dx_ref.dtype)
    dh_ref[...] = (jax.lax.dot_general(
        dgh, wh_ref[...].astype(f32), t_dims,
        preferred_element_type=f32) + g * z).astype(dh_ref.dtype)

    @pl.when(i == 0)
    def _init():
        dwx_ref[...] = jnp.zeros_like(dwx_ref)
        dwh_ref[...] = jnp.zeros_like(dwh_ref)
        dbx_ref[...] = jnp.zeros_like(dbx_ref)
        dbh_ref[...] = jnp.zeros_like(dbh_ref)

    dwx_ref[...] += jax.lax.dot_general(
        x, dgx, a_dims, preferred_element_type=f32).astype(dwx_ref.dtype)
    dwh_ref[...] += jax.lax.dot_general(
        h, dgh, a_dims, preferred_element_type=f32).astype(dwh_ref.dtype)
    dbx_ref[...] += jnp.sum(dgx, axis=0).astype(dbx_ref.dtype)
    dbh_ref[...] += jnp.sum(dgh, axis=0).astype(dbh_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_gru_bwd(g, x, h, wx, wh, bx, bh, *, block_b: int = 128,
                  interpret: bool = False):
    """One-pass GRU backward: (dx, dh, dwx, dwh, dbx, dbh) from the output
    cotangent ``g`` and the forward residuals (inputs only — gates are
    recomputed in VMEM)."""
    b, d_in = x.shape
    d_h = h.shape[-1]
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    kernel = functools.partial(_gru_bwd_kernel, n_rows=b, block_b=block_b)
    row_spec = lambda cols: pl.BlockSpec((block_b, cols), lambda i: (i, 0))
    full = lambda rows, cols: pl.BlockSpec((rows, cols), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            row_spec(d_h),                               # g
            row_spec(d_in),                              # x
            row_spec(d_h),                               # h
            full(d_in, 3 * d_h),                         # wx
            full(d_h, 3 * d_h),                          # wh
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),    # bx
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),    # bh
        ],
        out_specs=[
            row_spec(d_in),                              # dx
            row_spec(d_h),                               # dh
            full(d_in, 3 * d_h),                         # dwx (accumulated)
            full(d_h, 3 * d_h),                          # dwh (accumulated)
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),    # dbx (accumulated)
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),    # dbh (accumulated)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d_in), x.dtype),
            jax.ShapeDtypeStruct((b, d_h), h.dtype),
            jax.ShapeDtypeStruct(wx.shape, wx.dtype),
            jax.ShapeDtypeStruct(wh.shape, wh.dtype),
            jax.ShapeDtypeStruct(bx.shape, bx.dtype),
            jax.ShapeDtypeStruct(bh.shape, bh.dtype),
        ],
        interpret=interpret,
    )(g, x, h, wx, wh, bx, bh)
