"""Fused GRU cell — Pallas TPU kernel.

The TIG memory update (paper Fig.6 UPD module) applies a GRU to every node
touched by a batch: rows (B, d_in) x (B, d_h).  Unfused, XLA emits two gate
matmuls plus ~10 elementwise HBM round-trips over (B, 3*d_h) intermediates.
This kernel keeps the gate activations in VMEM: one pass over HBM for x, h
and the weights, one write for h'.

Tiling: grid over row blocks of ``block_b``; both weight matrices are small
(d <= 512 in TIG models) and are resident in VMEM for every grid step.
d_h is padded to a multiple of 128 lanes by the wrapper (ops.py), so the
(d_in, 3*d_h) matmuls hit the MXU with aligned shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_gru"]


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, bx_ref, bh_ref, out_ref):
    x = x_ref[...]
    h = h_ref[...]
    gx = jnp.dot(x, wx_ref[...],
                 preferred_element_type=jnp.float32) + bx_ref[...]
    gh = jnp.dot(h, wh_ref[...],
                 preferred_element_type=jnp.float32) + bh_ref[...]
    d_h = h.shape[-1]
    rx, zx, nx = gx[:, :d_h], gx[:, d_h:2 * d_h], gx[:, 2 * d_h:]
    rh, zh, nh = gh[:, :d_h], gh[:, d_h:2 * d_h], gh[:, 2 * d_h:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    out_ref[...] = ((1.0 - z) * n + z * h).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_gru(x, h, wx, wh, bx, bh, *, block_b: int = 128,
              interpret: bool = False):
    """h' = GRU(x, h).  Shapes: x (B, d_in), h (B, d_h), wx (d_in, 3*d_h),
    wh (d_h, 3*d_h), bx/bh (3*d_h,)."""
    b, d_in = x.shape
    d_h = h.shape[-1]
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d_h), lambda i: (i, 0)),
            pl.BlockSpec((d_in, 3 * d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h, 3 * d_h), lambda i: (0, 0)),
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),
            pl.BlockSpec((3 * d_h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d_h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d_h), h.dtype),
        interpret=interpret,
    )(x, h, wx, wh, bx, bh)
