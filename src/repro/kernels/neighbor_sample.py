"""Device-side temporal neighbor sampling kernel — Pallas TPU.

Host planning (``ChronoNeighborIndex.sample`` inside ``build_batch_program``)
pre-samples every batch's (B, K) neighbor grids on the CPU and ships them to
the device — a serial planner stage plus O(steps x B x K) H2D traffic per
epoch.  This kernel moves the sampling step onto the device: the T-CSR
(``ChronoNeighborIndex.device_export``) lives in HBM once per stream, the
scanned step hands over only raw edge records, and each query is answered
in-kernel.

Per grid step (one query row):

  * the query's segment bounds ``[start, stop)`` and its batch-boundary
    search key ride in scalar-prefetch SMEM (the bounds are a cheap XLA
    gather of ``indptr`` in the wrapper);
  * the event arrays stay in HBM (``memory_space=ANY``) — a binary search
    DMAs one ``bat`` element per probe into a (1, 1) VMEM scratch, giving
    the first event of a stream batch >= the boundary (bisect_left on the
    per-event key ``batch + 1``, history = 0);
  * one K-wide async copy per output array gathers the K-wide window
    ``[end - (w+1)K, end - wK)`` of neighbor ids / times / edge rows into
    VMEM (w = the per-row window shift riding in scalar prefetch; 0 = the
    trailing K, the multi-layer fold asks for older windows per layer) —
    in-bounds by construction because the export front-pads the buffers by
    K x depth and shifts ``indptr``;
  * slots before ``start`` are masked to the -1 / -1.0 padding with a
    ``broadcasted_iota`` validity mask.

HBM traffic is O(R x (log2(total) + 3K)) elements instead of the host
path's O(R x 3K) *transferred* elements — the search probes read memory
that is already device-resident, so the epoch's H2D volume shrinks to the
raw edge stream plus one T-CSR upload (see ``roofline.kernel_bytes``).

The pure-jnp oracle is ``ref.sample_ref``; parity is bit-exact (both
reproduce the host index's ``searchsorted`` semantics).  Sampling happens
before the differentiated section of the step (it produces integer ids and
already-materialized times), so no custom VJP is needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["neighbor_sample_fwd"]


def _sample_kernel(start_ref, stop_ref, key_ref, win_ref,
                   bat_hbm, nbr_hbm, t_hbm, e_hbm,
                   ids_out, t_out, e_out,
                   bat_s, nbr_s, t_s, e_s, sem_b, sem_n, sem_t, sem_e,
                   *, iters, k, total):
    i = pl.program_id(0)
    start = start_ref[i]
    stop = stop_ref[i]
    key = key_ref[i]
    win = win_ref[i]

    def probe(_, carry):
        lo, hi = carry
        mid = jax.lax.div(lo + hi, 2)
        cp = pltpu.make_async_copy(
            bat_hbm.at[0, pl.ds(jnp.minimum(mid, total - 1), 1)],
            bat_s.at[0, pl.ds(0, 1)], sem_b)
        cp.start()
        cp.wait()
        v = bat_s[0, 0]
        active = lo < hi
        go = jnp.logical_and(active, v < key)
        return (jnp.where(go, mid + 1, lo),
                jnp.where(jnp.logical_and(active, ~go), mid, hi))

    end, _ = jax.lax.fori_loop(0, iters, probe, (start, stop))

    # window ``win`` gathers [end-(win+1)k, end-win*k): in-bounds for any
    # win < export depth (the export front-pads the event arrays by
    # k*depth); the max(., 0) guards callers passing deeper windows, whose
    # out-of-segment slots the validity mask already kills
    w = jnp.maximum(end - (win + 1) * k, 0)
    copies = [
        pltpu.make_async_copy(hbm.at[0, pl.ds(w, k)], dst.at[0, :], sem)
        for hbm, dst, sem in ((nbr_hbm, nbr_s, sem_n),
                              (t_hbm, t_s, sem_t),
                              (e_hbm, e_s, sem_e))
    ]
    for cp in copies:
        cp.start()
    for cp in copies:
        cp.wait()

    slot = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    valid = (w + slot) >= start
    ids_out[...] = jnp.where(valid, nbr_s[...], -1)
    t_out[...] = jnp.where(valid, t_s[...], jnp.float32(-1.0))
    e_out[...] = jnp.where(valid, e_s[...], -1)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def neighbor_sample_fwd(indptr, nbr, t, eidx, bat, nodes, batch_of, *,
                        k: int, interpret: bool = False, window=None):
    """K most recent neighbors of ``nodes`` as of batch ``batch_of``.

    indptr: (N+1,) int32; nbr / t / eidx / bat: (pad + total,) event arrays
    from ``ChronoNeighborIndex.device_export``; nodes: (R,) int32;
    batch_of: scalar or (R,) int32; window: None (= 0, most recent),
    scalar, or (R,) int32 per-row K-window shift (multi-layer grids).
    Returns ((R, k) int32 ids, (R, k) float32 times, (R, k) int32 edge
    rows) matching ``ref.sample_ref``.
    """
    r = nodes.shape[0]
    total = nbr.shape[0]
    nodes = nodes.astype(jnp.int32)
    start = indptr[nodes]
    stop = indptr[nodes + 1]
    key = jnp.broadcast_to(jnp.asarray(batch_of, jnp.int32) + 1, (r,))
    window = 0 if window is None else window
    win = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (r,))

    kernel = functools.partial(
        _sample_kernel, iters=max(1, int(total).bit_length()),
        k=k, total=total)
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    row = lambda i, s, e, b, w: (i, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(r,),
        in_specs=[hbm, hbm, hbm, hbm],               # bat, nbr, t, eidx
        out_specs=[pl.BlockSpec((1, k), row),
                   pl.BlockSpec((1, k), row),
                   pl.BlockSpec((1, k), row)],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.int32),           # bat probe
            pltpu.VMEM((1, k), jnp.int32),           # nbr window
            pltpu.VMEM((1, k), jnp.float32),         # t window
            pltpu.VMEM((1, k), jnp.int32),           # eidx window
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids, tms, eix = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.int32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        interpret=interpret,
    )(start, stop, key, win,
      bat[None, :], nbr[None, :], t[None, :], eidx[None, :])
    return ids, tms, eix
