"""Serving-time token sampling (greedy / temperature / top-k / top-p)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample_tokens"]


def sample_tokens(
    key,
    logits: jnp.ndarray,           # (B, V) — REAL vocab only
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jnp.ndarray:
    """Sample next tokens; temperature == 0 -> greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)
