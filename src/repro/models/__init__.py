"""LLM pillar: the 10 assigned architectures as one composable model zoo.

  * ``layers``      — norms, RoPE/M-RoPE, chunked attention, FFN, conv.
  * ``moe``         — sort-based top-k expert routing (expert parallel).
  * ``ssm``         — mamba-style selective SSM (hymba hybrid heads).
  * ``rwkv``        — RWKV6 time-mix / channel-mix blocks.
  * ``transformer`` — per-family blocks + TP padding rules.
  * ``model``       — init/forward/loss/train_step/serve_step + shardings.
"""

from repro.models.model import (
    batch_specs,
    cache_specs,
    forward,
    init_cache,
    init_params,
    loss_fn,
    make_train_step,
    param_specs,
    serve_step,
)

__all__ = [
    "init_params", "forward", "loss_fn", "make_train_step",
    "init_cache", "serve_step", "param_specs", "batch_specs", "cache_specs",
]
