"""Tracing-time context for distribution decisions.

The launcher / dry-run sets these before tracing; model code reads them.
Kept in a leaf module so layers/transformer/model can all import it without
cycles.
  * ACT_BATCH_AXES — mesh axes the activation batch dim is sharded over
    (e.g. ("data",) or ("pod", "data")); None = no constraints (single
    device).
  * SHARDED_MOE — route MoE layers through the shard_map expert-parallel
    dispatch (§Perf A1) instead of the plain pjit path.
"""

from __future__ import annotations

ACT_BATCH_AXES = None
SHARDED_MOE = False


class activation_batch_axes:
    """Context manager pinning activation sharding (and optionally the
    shard_map MoE path) during tracing."""

    def __init__(self, axes, sharded_moe: bool = False):
        self.axes = axes
        self.sharded_moe = sharded_moe

    def __enter__(self):
        global ACT_BATCH_AXES, SHARDED_MOE
        self._prev = (ACT_BATCH_AXES, SHARDED_MOE)
        ACT_BATCH_AXES = self.axes
        SHARDED_MOE = self.sharded_moe
        return self

    def __exit__(self, *exc):
        global ACT_BATCH_AXES, SHARDED_MOE
        ACT_BATCH_AXES, SHARDED_MOE = self._prev
