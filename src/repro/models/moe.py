"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Design (see DESIGN.md §5): instead of the GShard one-hot dispatch einsum
(whose (T, E, C) tensors dwarf the useful compute), tokens are routed by
*sorting* the flattened (token, expert) assignments by expert id and
scattering into a capacity-bucketed (E, C+1, d) buffer (slot C is the
overflow dump).  The expert matmuls are then plain batched GEMMs — the only
O(T·k·d·d_ff) compute — and the combine is a weighted scatter-add.  Experts
shard over the mesh "model" axis (expert parallelism); XLA inserts the
token exchange collectives from the shardings.

Router aux loss: the standard load-balance term E * sum_e f_e * P_e
(Switch/GShard), returned alongside so PAC... the LM loss can add it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import _act, linear_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d: int, d_ff: int, n_experts: int, act: str) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    def e_init(k, din, dout):
        return jax.random.normal(k, (n_experts, din, dout), jnp.float32) \
            * (din ** -0.5)
    p = {
        "router": linear_init(k1, d, n_experts),
        "wi": e_init(k2, d, d_ff),
        "wo": e_init(k4, d_ff, d),
    }
    if act in ("swiglu", "geglu"):
        p["wg"] = e_init(k3, d, d_ff)
    return p


def moe_apply(p: dict, x: jnp.ndarray, *, top_k: int, act: str,
              capacity_factor: float = 1.25, dropless: bool = False):
    """x: (T, d) -> (y: (T, d), aux_loss: scalar).

    Tokens beyond an expert's capacity C = ceil(T * top_k / E * cf) are
    dropped (contribute zero), the standard capacity-based behaviour.
    ``dropless=True`` sets C = T (serving: one token must never be dropped,
    and decode batches are small enough that the buffer stays cheap).
    """
    t, d = x.shape
    e = p["wi"].shape[0]
    logits = x.astype(jnp.float32) @ p["router"]["w"]        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)               # (T, k)
    # renormalize the chosen gates (Qwen/Mixtral convention)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary (Switch eq.4-6) ----
    me = probs.mean(axis=0)                                   # (E,)
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)      # (T, k, E)
    ce = onehot.sum(axis=(0, 1)) / (t * top_k)                # fraction
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    cap = t if dropless else int(max(1, -(-t * top_k // e)
                                     * capacity_factor))
    flat_e = top_i.reshape(-1)                                # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = top_p.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * top_k) - starts[se]                  # rank in expert
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                          # cap = dump

    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[se, slot].set(x[st], mode="drop")

    h = _act(act, jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype)))
    if "wg" in p:
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    yb = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))

    contrib = yb[se, slot] * sw[:, None] * keep[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[st].add(contrib)
    return y, aux


def moe_apply_sharded(p: dict, x: jnp.ndarray, *, top_k: int, act: str,
                      capacity_factor: float, token_axes,
                      expert_axis: str = "model"):
    """Expert-parallel MoE via shard_map (§Perf iteration A1).

    Under plain pjit the sort-based dispatch crosses the data<->model
    sharding boundary, so GSPMD materializes and all-reduces the global
    (E, C, d) dispatch buffer — ~1000s of collective time per step for the
    235B config.  Here each (data, model) device instead:

      1. routes ITS token shard with the (replicated, tiny) router,
      2. keeps only assignments to ITS local experts (everything else goes
         to a dump expert slot), sorts locally, capacity cap/shard,
      3. runs its local expert GEMMs,
      4. psum's the combined output over the expert axis — the ONLY
         collective: O(T_loc * d) per layer instead of O(E * C * d).

    Per-expert capacity is ceil(T_loc*k/E*cf) per data shard, which sums to
    the same global capacity as the pjit path (drop pattern differs
    per-shard, the standard behaviour of distributed capacity MoE).
    """
    t, d = x.shape
    e_total = p["wi"].shape[0]
    has_gate = "wg" in p

    def body(router_w, wi, wo, wg_or_none, xs):
        x_loc = xs                                    # (T_loc, d)
        t_loc = x_loc.shape[0]
        e_loc = wi.shape[0]
        m = jax.lax.axis_index(expert_axis)
        logits = x_loc.astype(jnp.float32) @ router_w   # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, top_k)
        top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

        # load-balance aux (identical on every expert shard; mean over data)
        me = probs.mean(axis=0)
        onehot = jax.nn.one_hot(top_i, e_total, dtype=jnp.float32)
        ce = onehot.sum(axis=(0, 1)) / (t_loc * top_k)
        aux = e_total * jnp.sum(me * ce)
        if token_axes is not None:
            aux = jax.lax.pmean(aux, token_axes)

        # local dispatch: only MY experts; everything else -> dump expert
        my_lo = m * e_loc
        sel = (top_i >= my_lo) & (top_i < my_lo + e_loc)
        flat_e = jnp.where(sel, top_i - my_lo, e_loc).reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc), top_k)
        flat_w = (top_p * sel.astype(top_p.dtype)).reshape(-1).astype(
            x_loc.dtype)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        cap = int(max(1, -(-t_loc * top_k // e_total) * capacity_factor))
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[se].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_loc * top_k) - starts[se]
        keep = (pos < cap) & (se < e_loc)
        slot = jnp.where(keep, pos, cap)
        ebuf = jnp.where(keep, se, 0)

        buf = jnp.zeros((e_loc, cap + 1, d), x_loc.dtype)
        buf = buf.at[ebuf, slot].set(
            jnp.where(keep[:, None], x_loc[st], 0), mode="drop")
        h = _act(act, jnp.einsum("ecd,edf->ecf", buf, wi.astype(x_loc.dtype)))
        if has_gate:
            h = h * jnp.einsum("ecd,edf->ecf", buf,
                               wg_or_none.astype(x_loc.dtype))
        yb = jnp.einsum("ecf,efd->ecd", h, wo.astype(x_loc.dtype))
        contrib = yb[ebuf, slot] * sw[:, None] * keep[:, None].astype(
            x_loc.dtype)
        y_loc = jnp.zeros((t_loc, d), x_loc.dtype).at[st].add(contrib)
        # the only collective: combine expert shards' outputs
        y_loc = jax.lax.psum(y_loc, expert_axis)
        return y_loc, aux

    from jax.sharding import PartitionSpec as P

    tok = P(token_axes, None)
    wspec = P(expert_axis, None, None)
    wg = p.get("wg", p["wi"][:, :0, :0])   # dummy when ungated
    out = compat.shard_map(
        body,
        in_specs=(P(None, None), wspec, wspec, wspec, tok),
        out_specs=(tok, P()),
    )(p["router"]["w"], p["wi"], p["wo"], wg, x)
    return out
