"""Public LM API: init / forward / loss / train_step / serve_step.

Layer parameters are STACKED over the layer axis and applied with
``jax.lax.scan`` (+ optional remat) so 512-device programs stay compilable.
Sharding rules live in ``param_specs`` / ``batch_specs`` (pjit; the mesh
axes are ("data", "model") or ("pod", "data", "model")).

Batch layouts (also produced by ``repro.launch.dryrun.input_specs``):
  train/prefill:
    dense/moe/ssm/hybrid: {"tokens": (B,S), "targets": (B,S)}
    vlm:   + {"patches": (B,F,d), "positions3": (B,3,S)}   (stub frontend)
    audio: {"frames": (B,S,d), "tokens": (B,S), "targets": (B,S)}
  decode (serve_step):
    {"token": (B,), "pos": (B,)} + cache pytree (stacked over layers)
    audio adds {"enc_out": (B,S_enc,d)} fixed encoder memory.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import (
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
)
from repro.models.transformer import (
    PadDims,
    attn_apply,
    block_apply,
    block_decode,
    block_init,
    init_block_cache,
    pad_dims,
)
from repro.optim import Optimizer

__all__ = ["init_params", "forward", "loss_fn", "make_train_step",
           "init_cache", "serve_step", "param_specs", "batch_specs",
           "cache_specs", "pad_dims", "activation_batch_axes"]


# Activation-sharding convention for pjit runs (see repro.models.ctx):
# forward() pins activations to P(axes, None, ...) after gathers/reshapes
# whose inferred sharding XLA otherwise gets wrong (the embedding gather is
# the notorious one: without the constraint XLA replicates activations
# across "data" and involuntarily rematerializes).
from repro.models.ctx import activation_batch_axes  # re-export  # noqa
from repro.models import ctx as _ctx


def _pin_batch(x, *, extra=()):
    """with_sharding_constraint(P(batch_axes, None...)) when configured."""
    if _ctx.ACT_BATCH_AXES is None:
        return x
    spec = P(_ctx.ACT_BATCH_AXES,
             *([None] * (x.ndim - 1 - len(extra))), *extra)
    return jax.lax.with_sharding_constraint(x, spec)


# ======================================================================
# init
# ======================================================================

def init_params(key, cfg: ArchConfig, tp: int = 1) -> dict:
    pd = pad_dims(cfg, tp)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (pd.vocab, d), jnp.float32)
        * (d ** -0.5),
        "final_norm": rmsnorm_init(d),
    }

    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: block_init(k, cfg, pd, cross=cfg.enc_dec)
    )(layer_keys)

    if cfg.enc_dec:
        enc_keys = jax.random.split(ks[2], cfg.n_enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: block_init(k, cfg, pd)
        )(enc_keys)
        params["enc_norm"] = rmsnorm_init(d)

    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(ks[3], d, pd.vocab)
    return params


# ======================================================================
# forward (train / prefill)
# ======================================================================

def _embed_tokens(params, cfg, pd, tokens):
    e = params["embed"].astype(jnp.bfloat16 if cfg.dtype == "bfloat16"
                               else jnp.float32)
    return e[tokens]


def _stack_scan(layers_params, cfg: ArchConfig, pd: PadDims, x, positions,
                *, enc_out=None, causal=True):
    """scan over stacked layer params; accumulates MoE aux loss."""

    def body(carry, p_layer):
        x, aux = carry
        x, a = block_apply(p_layer, cfg, pd, x, positions,
                           enc_out=enc_out, causal=causal)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               layers_params)
    return x, aux


def encode(params, frames, cfg: ArchConfig, tp: int = 1):
    """Encoder stack over (stubbed) frame embeddings -> encoder memory."""
    pd = pad_dims(cfg, tp)
    d = cfg.d_model
    frames = _pin_batch(frames.astype(jnp.bfloat16))
    s_enc = frames.shape[1]
    frames = frames + sinusoidal_positions(s_enc, d).astype(frames.dtype)
    enc_pos = jnp.broadcast_to(jnp.arange(s_enc), frames.shape[:2])
    enc_x, _ = _stack_scan(params["enc_layers"], cfg, pd, frames,
                           enc_pos, causal=False)
    return rmsnorm(params["enc_norm"], enc_x, cfg.norm_eps)


def fill_enc_cache(params, cache, frames, cfg: ArchConfig, tp: int = 1):
    """Serving prefill for enc-dec archs: run the encoder ONCE and project
    every decoder layer's cross-attention K/V into the cache (decode steps
    then never touch the encoder — see §Perf bring-up notes)."""
    from repro.models.transformer import _project_qkv

    pd = pad_dims(cfg, tp)
    enc_out = encode(params, frames, cfg, tp)

    def proj(p_layer):
        _, k, v = _project_qkv(p_layer["cross"], cfg, pd, enc_out, None,
                               kv_x=enc_out)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    k, v = jax.vmap(proj)(params["layers"])
    return {**cache, "enc_k": k, "enc_v": v}


def forward(params, batch, cfg: ArchConfig, tp: int = 1):
    """Returns (logits (B, S, vocab_padded), aux_loss)."""
    pd = pad_dims(cfg, tp)
    d = cfg.d_model

    if cfg.enc_dec:
        enc_out = encode(params, batch["frames"], cfg, tp)
    else:
        enc_out = None

    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, pd, tokens)             # (B, S_txt, d)
    x = _pin_batch(x)

    if cfg.frontend == "vision" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)         # (B, F, d)
        x = _pin_batch(jnp.concatenate([patches, x], axis=1))

    b, s, _ = x.shape
    if cfg.rope == "mrope":
        positions = batch["positions3"]                    # (B, 3, S)
    elif cfg.rope == "none":
        if not cfg.enc_dec and not cfg.rwkv:
            # absolute sinusoidal positions (seamless decoder gets them via
            # its own branch; RWKV is position-free by construction)
            x = x + sinusoidal_positions(s, d).astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    x, aux = _stack_scan(params["layers"], cfg, pd, x, positions,
                         enc_out=enc_out, causal=True)
    x = _pin_batch(rmsnorm(params["final_norm"], x, cfg.norm_eps))

    if cfg.frontend == "vision" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]               # logits on text

    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = x @ head.astype(x.dtype).T if cfg.tie_embeddings \
        else x @ head.astype(x.dtype)
    return logits, aux


def loss_fn(params, batch, cfg: ArchConfig, tp: int = 1):
    """Masked CE over the REAL vocab (padded vocab rows are excluded)."""
    pd = pad_dims(cfg, tp)
    logits, aux = forward(params, batch, cfg, tp)
    logits = logits.astype(jnp.float32)
    if pd.vocab > cfg.vocab:
        pad_mask = jnp.arange(pd.vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], -1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    ce = (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return ce + cfg.router_aux_weight * aux, (ce, aux)


# ======================================================================
# train step (with microbatch gradient accumulation)
# ======================================================================

def make_train_step(cfg: ArchConfig, opt: Optimizer, tp: int = 1,
                    batch_axes=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``cfg.microbatch`` splits the batch for gradient accumulation (an
    activation-memory knob; see DESIGN.md §5).  ``batch_axes`` (e.g.
    ("data",) or ("pod","data")) pins the per-microbatch sharding so the
    reshape (B, ...) -> (m, B/m, ...) does not trigger XLA's involuntary
    full-rematerialization resharding."""

    def split_micro(batch):
        m = cfg.microbatch

        def rs(x):
            b = x.shape[0]
            y = x.reshape((m, b // m) + x.shape[1:])
            if batch_axes is not None:
                spec = P(None, batch_axes, *([None] * (y.ndim - 2)))
                y = jax.lax.with_sharding_constraint(y, spec)
            return y
        return jax.tree.map(rs, batch)

    def step(params, opt_state, batch):
        if cfg.microbatch > 1:
            micro = split_micro(batch)

            def accum(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, cfg, tp)
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / cfg.microbatch, grads)
            loss = loss / cfg.microbatch
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, cfg, tp)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step


# ======================================================================
# decode / serve
# ======================================================================

def init_cache(cfg: ArchConfig, tp: int, batch: int, cache_len: int,
               enc_len: int = 0) -> dict:
    """Decode state, stacked over layers: each leaf (L, B, ...)."""
    pd = pad_dims(cfg, tp)
    if cfg.window is not None:
        cache_len = min(cache_len, cfg.window)
    one = init_block_cache(cfg, pd, batch, cache_len, enc_len)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape
                                   ).copy(), one)


def serve_step(params, cache, batch, cfg: ArchConfig, tp: int = 1):
    """One decode step: batch {"token": (B,), "pos": (B,)} (+"enc_out").

    Returns (logits (B, vocab_padded), new_cache)."""
    pd = pad_dims(cfg, tp)
    d = cfg.d_model
    tokens = batch["token"][:, None]                      # (B, 1)
    pos = batch["pos"]
    x = _embed_tokens(params, cfg, pd, tokens)
    if cfg.rope == "none" and not cfg.enc_dec and not cfg.rwkv:
        x = x + _sinusoid_at(pos, d).astype(x.dtype)[:, None, :]
    if cfg.rope == "mrope":
        # text continuation: t == h == w == pos (Qwen2-VL convention)
        positions = jnp.tile(pos[:, None, None], (1, 3, 1))   # (B, 3, 1)
    else:
        positions = pos

    def body(carry, scanned):
        x = carry
        p_layer, cache_l = scanned
        x, new_cache_l = block_decode(p_layer, cfg, pd, x, pos, cache_l)
        return x, new_cache_l

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)[:, 0]
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = x @ head.astype(x.dtype).T if cfg.tie_embeddings \
        else x @ head.astype(x.dtype)
    return logits, new_cache


def _sinusoid_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos[:, None].astype(jnp.float32) / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ======================================================================
# sharding rules
# ======================================================================

def _layer_specs(cfg: ArchConfig, prefix_l: bool, fsdp: bool = False) -> dict:
    """PartitionSpecs for one (stacked) layer dict.  prefix_l adds the
    leading layer axis (None).

    ``fsdp=True`` additionally shards each weight's non-"model" matrix dim
    over "data" (ZeRO-3 style: XLA all-gathers weights per layer; params +
    optimizer state shrink by the data-axis size — required for the 32B+
    configs to fit v5e HBM)."""
    L = (None,) if prefix_l else ()
    fs = "data" if fsdp else None

    def sp(*axes):
        return P(*(L + axes))

    norm = {"g": sp(None)}
    attn = {
        "wq": {"w": sp(fs, "model")},
        "wk": {"w": sp(fs, "model")},
        "wv": {"w": sp(fs, "model")},
        "wo": {"w": sp("model", fs)},
    }
    if cfg.qk_norm:
        attn["qn"] = {"g": sp(None)}
        attn["kn"] = {"g": sp(None)}
    if cfg.rwkv:
        lin = lambda: {"w": sp(fs, "model")}
        out = lambda: {"w": sp("model", fs)}
        return {
            "ln1": norm, "ln2": norm,
            "tm": {
                **{f"mix_{n}": sp(None) for n in "rkvwg"},
                "wr": lin(), "wk": lin(), "wv": lin(), "wg": lin(),
                "wo": out(),
                "w0": sp(None),
                "w_lora_a": sp(None, None),
                "w_lora_b": sp(None, None),
                "u": sp("model", None),
                "ln_x": norm,
            },
            "cm": {
                "mix_k": sp(None), "mix_r": sp(None),
                "wk": lin(), "wv": out(), "wr": {"w": sp(None, None)},
            },
        }
    d = {
        "ln1": norm, "ln2": norm,
        "attn": attn,
    }
    if cfg.is_moe:
        d["moe"] = {
            "router": {"w": sp(None, None)},
            "wi": sp("model", fs, None),
            "wo": sp("model", None, fs),
        }
        if cfg.act in ("swiglu", "geglu"):
            d["moe"]["wg"] = sp("model", fs, None)
    else:
        d["ffn"] = {
            "wi": {"w": sp(fs, "model")},
            "wo": {"w": sp("model", fs)},
        }
        if cfg.act in ("swiglu", "geglu"):
            d["ffn"]["wg"] = {"w": sp(fs, "model")}
    if cfg.ssm_state:
        d["ssm"] = {
            "in_proj": {"w": sp(fs, "model")},
            "conv_w": sp("model", None),
            "conv_b": sp("model"),
            "x_proj": {"w": sp("model", None)},
            "dt_proj": {"w": sp(None, "model"), "b": sp("model")},
            "a_log": sp("model", None),
            "d_skip": sp("model"),
            "out_proj": {"w": sp("model", fs)},
        }
        d["ln_attn_out"] = norm
        d["ln_ssm_out"] = norm
    if cfg.enc_dec:
        d["ln_cross"] = norm
        d["cross"] = {
            "wq": {"w": sp(fs, "model")},
            "wk": {"w": sp(fs, "model")},
            "wv": {"w": sp(fs, "model")},
            "wo": {"w": sp("model", fs)},
        }
    return d


def param_specs(cfg: ArchConfig, fsdp: bool = False) -> dict:
    """PartitionSpec pytree matching init_params' structure."""
    fs = "data" if fsdp else None
    specs: dict[str, Any] = {
        "embed": P("model", fs),
        "final_norm": {"g": P(None)},
        "layers": _layer_specs(cfg, prefix_l=True, fsdp=fsdp),
    }
    if cfg.enc_dec:
        enc = _layer_specs(
            dataclasses.replace(cfg, enc_dec=False, ssm_state=0),
            prefix_l=True, fsdp=fsdp)
        specs["enc_layers"] = enc
        specs["enc_norm"] = {"g": P(None)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": P(fs, "model")}
    return specs


def batch_specs(cfg: ArchConfig, kind: str, multi_pod: bool) -> dict:
    """PartitionSpecs for the batch dict (batch axis over data (+pod))."""
    b = ("pod", "data") if multi_pod else "data"
    if kind in ("train", "prefill"):
        specs = {"tokens": P(b, None), "targets": P(b, None)}
        if cfg.frontend == "vision":
            specs["patches"] = P(b, None, None)
            specs["positions3"] = P(b, None, None)
        if cfg.enc_dec:
            specs["frames"] = P(b, None, None)
        return specs
    return {"token": P(b), "pos": P(b)}


def cache_specs(cfg: ArchConfig, multi_pod: bool) -> dict:
    """PartitionSpecs for the decode cache (kv heads over model)."""
    b = ("pod", "data") if multi_pod else "data"
    if cfg.rwkv:
        return {
            "wkv": P(None, b, "model", None, None),
            "tm_shift": P(None, b, None, None),
            "cm_shift": P(None, b, None, None),
        }
    specs = {
        "k": P(None, b, None, "model", None),
        "v": P(None, b, None, "model", None),
    }
    if cfg.enc_dec:
        specs["enc_k"] = P(None, b, None, "model", None)
        specs["enc_v"] = P(None, b, None, "model", None)
    if cfg.ssm_state:
        specs["conv"] = P(None, b, None, "model")
        specs["ssm"] = P(None, b, "model", None)
    return specs
