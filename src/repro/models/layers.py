"""Transformer building blocks (raw JAX, functional params, TP-friendly).

Conventions:
  * params are stored float32; compute casts to ``cfg.dtype`` (bf16 default);
  * activations are (B, S, ...); attention heads (B, S, H, Dh);
  * every matmul keeps its contraction dims MXU-aligned where the published
    architecture allows; head counts are padded to the mesh's "model" axis by
    the model builder (padding overhead is surfaced in the roofline's
    MODEL_FLOPS / HLO_FLOPs ratio);
  * attention is *chunked* over query blocks (online softmax not needed —
    full-row softmax per chunk) so the (S, S) score tensor never
    materializes; sliding-window attention slices keys to the window, making
    cost O(S * window).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "linear_init", "linear",
    "rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm",
    "rope_freqs", "apply_rope", "apply_mrope",
    "ffn_init", "ffn_apply",
    "chunked_attention", "decode_attention",
    "sinusoidal_positions", "causal_conv1d",
]


# ------------------------------------------------------------------ basics

def linear_init(key, d_in: int, d_out: int, *, scale: Optional[float] = None
                ) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


def rmsnorm_init(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["g"]).astype(x.dtype)


def layernorm_init(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)) * p["g"] + p["b"]
            ).astype(x.dtype)


# -------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim/2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def _rotate(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (..., H, Dh) with angles (..., Dh/2) broadcast over H."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) int."""
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,Dh/2)
    return _rotate(x, angles)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                inv_freq: jnp.ndarray, sections: tuple[int, ...]
                ) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): rotary frequency ladder split into
    per-axis sections (t, h, w); each section rotates by its own position
    stream.  x: (B, S, H, Dh); positions3: (B, 3, S)."""
    assert sum(sections) == inv_freq.shape[0], (sections, inv_freq.shape)
    angle_parts = []
    off = 0
    for axis, sec in enumerate(sections):
        f = inv_freq[off: off + sec]
        p = positions3[:, axis, :, None].astype(jnp.float32)   # (B,S,1)
        angle_parts.append(p * f)
        off += sec
    angles = jnp.concatenate(angle_parts, axis=-1)             # (B,S,Dh/2)
    return _rotate(x, angles)


def sinusoidal_positions(s: int, d: int, offset: int = 0) -> jnp.ndarray:
    """Classic sin/cos table (seamless uses non-rotary positions)."""
    pos = jnp.arange(offset, offset + s, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- FFN

def ffn_init(key, d: int, d_ff: int, act: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "wi": linear_init(k1, d, d_ff),
            "wg": linear_init(k2, d, d_ff),
            "wo": linear_init(k3, d_ff, d, scale=d_ff ** -0.5),
        }
    return {
        "wi": linear_init(k1, d, d_ff),
        "wo": linear_init(k3, d_ff, d, scale=d_ff ** -0.5),
    }


def _act(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "swiglu":
        return jax.nn.silu(x)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def ffn_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = _act(act, linear(p["wi"], x))
    if "wg" in p:                      # gated variants
        h = h * linear(p["wg"], x)
    return linear(p["wo"], h)


# -------------------------------------------------------------- attention

def _gqa_scores(q, k):
    """q: (B, Sq, H, Dh); k: (B, Sk, Hkv, Dh) -> (B, Hkv, G, Sq, Sk).

    Heads use a KV-MAJOR layout (head h = kv_idx * G + g_idx): the reshape
    (H,) -> (Hkv, G) then splits the model-sharded head axis on its FIRST
    factor, which GSPMD can shard; (G, Hkv) order would force replication.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)


def _gqa_out(att, v):
    """att: (B, Hkv, G, Sq, Sk); v: (B, Sk, Hkv, Dh) -> (B, Sq, H, Dh)."""
    b, hkv, g, sq, sk = att.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", att, v)
    return out.reshape(b, sq, hkv * g, v.shape[-1])


def chunked_attention(
    q: jnp.ndarray,            # (B, S, H, Dh)
    k: jnp.ndarray,            # (B, S, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 512,
    mask: Optional[jnp.ndarray] = None,   # (B, Sk) key validity
) -> jnp.ndarray:
    """Query-chunked attention: scores materialize as (B, G, Hkv, chunk, Sk)
    only.  With a sliding ``window`` the key extent per chunk is sliced to
    window + chunk (static size) — cost O(S * (window + chunk)).
    """
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    sk = k.shape[1]

    def one_chunk(ci):
        q_start = ci * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, chunk, axis=1)
        q_idx = q_start + jnp.arange(chunk)
        if window is not None:
            # keys the whole chunk can see: [q_start - window + 1,
            #                                q_start + chunk)
            span = window + chunk
            k_off = jnp.clip(q_start - window + 1, 0, max(sk - span, 0))
            kc = jax.lax.dynamic_slice_in_dim(k, k_off, min(span, sk), 1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_off, min(span, sk), 1)
            k_idx = k_off + jnp.arange(min(span, sk))
            mc = None if mask is None else jax.lax.dynamic_slice_in_dim(
                mask, k_off, min(span, sk), 1)
        else:
            kc, vc, k_idx = k, v, jnp.arange(sk)
            mc = mask
        scores = _gqa_scores(qc, kc) * scale          # (B,G,Hkv,chunk,Sk')
        m = jnp.ones((chunk, k_idx.shape[0]), bool)
        if causal:
            m &= k_idx[None, :] <= q_idx[:, None]
        if window is not None:
            m &= k_idx[None, :] > q_idx[:, None] - window
        big_neg = jnp.asarray(-1e30, scores.dtype)
        scores = jnp.where(m[None, None, None], scores, big_neg)
        if mc is not None:
            scores = jnp.where(mc[:, None, None, None, :], scores, big_neg)
        att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                             ).astype(q.dtype)
        return _gqa_out(att, vc)                      # (B, chunk, H, Dh)

    if n_chunks == 1:
        return one_chunk(0)
    # checkpoint each q-chunk: backward recomputes its (chunk, Sk)
    # attention probabilities instead of keeping all n_chunks of them
    # stacked in f32 (flash-attention's recompute trick at chunk
    # granularity — §Perf dense-train iteration).
    outs = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))
    # (n_chunks, B, chunk, H, Dh) -> (B, S, H, Dh)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dh)


def decode_attention(
    q: jnp.ndarray,            # (B, H, Dh) — one new token per sequence
    k_cache: jnp.ndarray,      # (B, S, Hkv, Dh)
    v_cache: jnp.ndarray,
    valid: jnp.ndarray,        # (B, S) bool — which cache slots are live
) -> jnp.ndarray:
    """Single-token attention against a KV cache (masked, GQA, kv-major)."""
    b, h, dh = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache) * (dh ** -0.5)
    big_neg = jnp.asarray(-1e30, scores.dtype)
    scores = jnp.where(valid[:, None, None, :], scores, big_neg)
    att = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", att, v_cache)
    return out.reshape(b, h, dh)


# ------------------------------------------------------------------ conv1d

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv (mamba's local mixing).

    x: (B, S, D); w: (D, K); b: (D,).  Returns (y, new_state) where state is
    the last K-1 inputs ((B, K-1, D)) for streaming decode.
    """
    bsz, s, d = x.shape
    kk = w.shape[1]
    if state is None:
        state = jnp.zeros((bsz, kk - 1, d), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, D)
    idx = jnp.arange(s)[:, None] + jnp.arange(kk)[None, :]
    windows = xp[:, idx, :]                           # (B, S, K, D)
    y = jnp.einsum("bskd,dk->bsd", windows, w.astype(x.dtype)) \
        + b.astype(x.dtype)
    new_state = xp[:, -(kk - 1):, :] if kk > 1 else state
    return y, new_state
