"""Per-family transformer blocks + scan-over-layers stacks.

One block function per family, all driven by the same stacked-parameter
layout so ``jax.lax.scan`` over layers keeps the HLO small enough to compile
512-device programs on this CPU-only host (DESIGN.md §5).

Head/ff/vocab padding for tensor parallelism is decided by ``PadDims``
(model.py); blocks receive already-padded parameter shapes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    chunked_attention,
    decode_attention,
    ffn_init,
    ffn_apply,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
    rope_freqs,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.rwkv import (
    rwkv_block_init,
    rwkv_channel_mix,
    rwkv_time_mix,
)
from repro.models.ssm import mamba_apply, mamba_decode_step, mamba_init

__all__ = ["PadDims", "pad_dims", "attn_init", "block_init", "block_apply",
           "block_decode", "init_block_cache"]


@dataclasses.dataclass(frozen=True)
class PadDims:
    """Tensor-parallel-padded dimensions (see DESIGN.md §5).

    Padding exists so every sharded axis divides the mesh "model" size; the
    roofline's MODEL_FLOPS / HLO_FLOPs ratio surfaces its cost.
    """

    n_heads: int
    n_kv_heads: int
    d_ff: int
    n_experts: int
    vocab: int


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_dims(cfg: ArchConfig, tp: int) -> PadDims:
    if tp <= 1:
        return PadDims(cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
                       cfg.n_experts, cfg.vocab)
    hkv = _round_up(cfg.n_kv_heads, tp)
    groups = max(1, -(-cfg.n_heads // hkv))
    return PadDims(
        n_heads=groups * hkv,
        n_kv_heads=hkv,
        d_ff=_round_up(cfg.d_ff, tp),
        n_experts=_round_up(cfg.n_experts, tp) if cfg.n_experts else 0,
        vocab=_round_up(cfg.vocab, tp) if cfg.vocab else 0,
    )


# =====================================================================
# attention sub-block (shared by dense / moe / vlm / hybrid / enc-dec)
# =====================================================================

def attn_init(key, cfg: ArchConfig, pd: PadDims, *, cross: bool = False
              ) -> dict:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, pd.n_heads * dh),
        "wk": linear_init(ks[1], d, pd.n_kv_heads * dh),
        "wv": linear_init(ks[2], d, pd.n_kv_heads * dh),
        "wo": linear_init(ks[3], pd.n_heads * dh, d,
                          scale=(pd.n_heads * dh) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        p["qn"] = rmsnorm_init(dh)
        p["kn"] = rmsnorm_init(dh)
    return p


def _project_qkv(p, cfg: ArchConfig, pd: PadDims, x, positions, kv_x=None):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    kv_x = x if kv_x is None else kv_x
    sk = kv_x.shape[1]
    q = linear(p["wq"], x).reshape(b, s, pd.n_heads, dh)
    k = linear(p["wk"], kv_x).reshape(b, sk, pd.n_kv_heads, dh)
    v = linear(p["wv"], kv_x).reshape(b, sk, pd.n_kv_heads, dh)
    if "qn" in p:
        q = rmsnorm(p["qn"], q, cfg.norm_eps)
        k = rmsnorm(p["kn"], k, cfg.norm_eps)
    if cfg.rope == "rope" and positions is not None:
        freqs = rope_freqs(dh, cfg.rope_theta)
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    elif cfg.rope == "mrope" and positions is not None:
        freqs = rope_freqs(dh, cfg.rope_theta)
        if positions.ndim == 2:
            # text-only stream (e.g. decode): t == h == w == pos
            positions = jnp.tile(positions[:, None, :], (1, 3, 1))
        q = apply_mrope(q, positions, freqs, tuple(cfg.mrope_sections))
        k = apply_mrope(k, positions, freqs, tuple(cfg.mrope_sections))
    return q, k, v


def attn_apply(p, cfg: ArchConfig, pd: PadDims, x, positions, *,
               causal=True, window=None, kv_x=None, kv_positions=None,
               return_kv=False):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, _ = x.shape
    if kv_x is not None:
        # cross-attention: keys from encoder memory, no rope on q/k
        q, k, v = _project_qkv(p, cfg, pd, x, None, kv_x=kv_x)
        causal = False
        window = None
    else:
        q, k, v = _project_qkv(p, cfg, pd, x, positions)
    ctx = chunked_attention(q, k, v, causal=causal, window=window,
                            chunk=min(cfg.attn_chunk, s))
    out = linear(p["wo"], ctx.reshape(b, s, -1))
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(p, cfg: ArchConfig, pd: PadDims, x, pos, k_cache, v_cache,
                slot, valid, *, kv_x=None):
    """One-token attention.  x: (B, 1, d); pos: (B,) absolute position;
    slot: (B,) cache write index (== pos, or pos % window for SWA rings);
    valid: (B, S_cache) live-slot mask AFTER insertion.

    Returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    if kv_x is not None:
        q, _, _ = _project_qkv(p, cfg, pd, x, None, kv_x=x)
        # cross-attention cache is the (precomputed) encoder K/V — no update
        out = decode_attention(q[:, 0], k_cache, v_cache, valid)
        return linear(p["wo"], out.reshape(b, 1, -1)[..., :]), \
            k_cache, v_cache
    q, k, v = _project_qkv(p, cfg, pd, x, pos[:, None])
    bi = jnp.arange(b)
    k_cache = k_cache.at[bi, slot].set(k[:, 0])
    v_cache = v_cache.at[bi, slot].set(v[:, 0])
    out = decode_attention(q[:, 0], k_cache, v_cache, valid)
    return linear(p["wo"], out[:, None, :].reshape(b, 1, -1)), \
        k_cache, v_cache


# =====================================================================
# per-family blocks
# =====================================================================

def block_init(key, cfg: ArchConfig, pd: PadDims, *, cross: bool = False
               ) -> dict:
    """One decoder layer's params (structure depends on family)."""
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.rwkv:
        p = rwkv_block_init(ks[0], d, pd.d_ff, cfg.rwkv_head_dim)
        p["ln1"] = rmsnorm_init(d)
        p["ln2"] = rmsnorm_init(d)
        return p
    p = {
        "ln1": rmsnorm_init(d),
        "ln2": rmsnorm_init(d),
        "attn": attn_init(ks[0], cfg, pd),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(ks[1], d, cfg.d_ff_expert, pd.n_experts, cfg.act)
    else:
        p["ffn"] = ffn_init(ks[1], d, pd.d_ff, cfg.act)
    if cfg.ssm_state:           # hymba: parallel SSM heads
        p["ssm"] = mamba_init(ks[2], d, state=cfg.ssm_state,
                              conv=cfg.ssm_conv, expand=cfg.ssm_expand)
        p["ln_attn_out"] = rmsnorm_init(d)
        p["ln_ssm_out"] = rmsnorm_init(d)
    if cross:                   # enc-dec decoder layer
        p["ln_cross"] = rmsnorm_init(d)
        p["cross"] = attn_init(ks[3], cfg, pd, cross=True)
    return p


def block_apply(p, cfg: ArchConfig, pd: PadDims, x, positions, *,
                enc_out=None, causal=True):
    """Full-sequence layer application.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.rwkv:
        tm, _, _ = rwkv_time_mix(p["tm"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                                 head_dim=cfg.rwkv_head_dim)
        x = x + tm
        cm, _ = rwkv_channel_mix(p["cm"],
                                 rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x + cm, aux

    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out = attn_apply(p["attn"], cfg, pd, h, positions,
                          causal=causal, window=cfg.window)
    if cfg.ssm_state:
        ssm_out = mamba_apply(p["ssm"], h, state=cfg.ssm_state)
        attn_out = 0.5 * (rmsnorm(p["ln_attn_out"], attn_out, cfg.norm_eps)
                          + rmsnorm(p["ln_ssm_out"], ssm_out, cfg.norm_eps))
    x = x + attn_out

    if enc_out is not None and "cross" in p:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn_apply(p["cross"], cfg, pd, h, None, kv_x=enc_out)

    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        from repro.models import ctx as _ctx
        from repro.models.moe import moe_apply_sharded
        b, s, d = h.shape
        if _ctx.SHARDED_MOE:
            y, aux = moe_apply_sharded(
                p["moe"], h.reshape(b * s, d), top_k=cfg.top_k,
                act=cfg.act, capacity_factor=cfg.capacity_factor,
                token_axes=_ctx.ACT_BATCH_AXES)
        else:
            y, aux = moe_apply(p["moe"], h.reshape(b * s, d),
                               top_k=cfg.top_k, act=cfg.act,
                               capacity_factor=cfg.capacity_factor)
        x = x + y.reshape(b, s, d)
    else:
        x = x + ffn_apply(p["ffn"], h, cfg.act)
    return x, aux


# ---------------------------------------------------------------- decode

def init_block_cache(cfg: ArchConfig, pd: PadDims, batch: int,
                     cache_len: int, enc_len: int = 0) -> dict:
    """Per-layer decode state (zeros; stacked over layers by the caller)."""
    dh = cfg.resolved_head_dim
    d = cfg.d_model
    c: dict[str, Any] = {}
    if cfg.rwkv:
        hd = cfg.rwkv_head_dim
        nh = d // hd
        c["wkv"] = jnp.zeros((batch, nh, hd, hd), jnp.float32)
        c["tm_shift"] = jnp.zeros((batch, 1, d), jnp.bfloat16)
        c["cm_shift"] = jnp.zeros((batch, 1, d), jnp.bfloat16)
        return c
    c["k"] = jnp.zeros((batch, cache_len, pd.n_kv_heads, dh), jnp.bfloat16)
    c["v"] = jnp.zeros((batch, cache_len, pd.n_kv_heads, dh), jnp.bfloat16)
    if cfg.enc_dec and enc_len:
        # cross-attention K/V: projected ONCE from the encoder memory at
        # prefill time (recomputing them per decode step costs ~300x the
        # useful decode FLOPs — see EXPERIMENTS.md §Perf bring-up notes).
        c["enc_k"] = jnp.zeros((batch, enc_len, pd.n_kv_heads, dh),
                               jnp.bfloat16)
        c["enc_v"] = jnp.zeros((batch, enc_len, pd.n_kv_heads, dh),
                               jnp.bfloat16)
    if cfg.ssm_state:
        di = cfg.ssm_expand * d
        c["conv"] = jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.bfloat16)
        c["ssm"] = jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)
    return c


def block_decode(p, cfg: ArchConfig, pd: PadDims, x, pos, cache, *,
                 enc_out=None, enc_kv=None):
    """One-token layer step.  x: (B, 1, d); pos: (B,).
    Returns (x, new_cache)."""
    b = x.shape[0]
    if cfg.rwkv:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        tm, wkv, tshift = rwkv_time_mix(
            p["tm"], h, head_dim=cfg.rwkv_head_dim,
            wkv_state=cache["wkv"], shift_state=cache["tm_shift"].astype(
                h.dtype))
        x = x + tm
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        cm, cshift = rwkv_channel_mix(p["cm"], h,
                                      shift_state=cache["cm_shift"].astype(
                                          h.dtype))
        x = x + cm
        new_cache = {"wkv": wkv, "tm_shift": tshift.astype(jnp.bfloat16),
                     "cm_shift": cshift.astype(jnp.bfloat16)}
        return x, new_cache

    cache_len = cache["k"].shape[1]
    if cfg.window is not None and cache_len <= cfg.window:
        slot = pos % cache_len                 # ring buffer (SWA)
        # valid slots: filled and within window lookback
        idx = jnp.arange(cache_len)[None, :]
        filled = idx <= jnp.minimum(pos[:, None], cache_len - 1)
        # absolute position stored in slot j: the most recent p with
        # p % cache_len == j and p <= pos  ->  within window by construction
        valid = filled
    else:
        slot = pos
        idx = jnp.arange(cache_len)[None, :]
        valid = idx <= pos[:, None]
        if cfg.window is not None:
            valid &= idx > (pos[:, None] - cfg.window)

    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, k_c, v_c = attn_decode(
        p["attn"], cfg, pd, h, pos, cache["k"], cache["v"], slot, valid)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_c, v_c

    if cfg.ssm_state:
        ssm_out, (conv_s, ssm_s) = mamba_decode_step(
            p["ssm"], h, cache["conv"].astype(h.dtype), cache["ssm"],
            state=cfg.ssm_state)
        new_cache["conv"] = conv_s.astype(jnp.bfloat16)
        new_cache["ssm"] = ssm_s
        attn_out = 0.5 * (rmsnorm(p["ln_attn_out"], attn_out, cfg.norm_eps)
                          + rmsnorm(p["ln_ssm_out"], ssm_out, cfg.norm_eps))
    x = x + attn_out

    if "cross" in p and "enc_k" in cache:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        q, _, _ = _project_qkv(p["cross"], cfg, pd, h, None, kv_x=h)
        evalid = jnp.ones(cache["enc_k"].shape[:2], bool) if enc_kv is None \
            else enc_kv
        out = decode_attention(q[:, 0], cache["enc_k"].astype(h.dtype),
                               cache["enc_v"].astype(h.dtype), evalid)
        x = x + linear(p["cross"]["wo"], out[:, None, :].reshape(b, 1, -1))

    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        # bounded-capacity decode dispatch (§Perf A3); dropless when the
        # batch is tiny (tests / small-batch serving: exactness > padding).
        dropless = b * cfg.top_k <= 4 * cfg.n_experts
        y, _ = moe_apply(p["moe"], h.reshape(b, -1), top_k=cfg.top_k,
                         act=cfg.act, dropless=dropless,
                         capacity_factor=cfg.decode_capacity_factor)
        x = x + y.reshape(b, 1, -1)
    else:
        x = x + ffn_apply(p["ffn"], h, cfg.act)
    return x, new_cache
