"""Mamba-style selective SSM (hymba's parallel SSM heads).

Continuous-time diagonal state space with input-dependent (selective)
discretization:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t · h_t + D * x_t

A is diagonal (d_inner, n) with learned negative log; B_t, C_t, dt_t come
from the input (selective scan).  Sequence processing is a lax.scan (the
state is (B, d_inner, n)); decode is the same cell applied once.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, linear, linear_init

__all__ = ["mamba_init", "mamba_apply", "mamba_decode_step"]


def mamba_init(key, d: int, *, state: int, conv: int, expand: int) -> dict:
    di = expand * d
    dt_rank = max(16, d // 16)
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    return {
        "in_proj": linear_init(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (di, conv), jnp.float32)
        * (conv ** -0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": linear_init(ks[2], di, dt_rank + 2 * state),
        "dt_proj": {
            "w": jax.random.normal(ks[3], (dt_rank, di), jnp.float32)
            * (dt_rank ** -0.5),
            "b": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        },
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": linear_init(ks[4], di, d, scale=di ** -0.5),
    }


def _ssm_params(p, xc, state_dim: int, dt_rank: int):
    """Project conv output to (dt, B, C)."""
    proj = linear(p["x_proj"], xc)                       # (..., r+2n)
    dt_low = proj[..., :dt_rank]
    b = proj[..., dt_rank: dt_rank + state_dim]
    c = proj[..., dt_rank + state_dim:]
    dt = jax.nn.softplus(
        dt_low @ p["dt_proj"]["w"].astype(xc.dtype)
        + p["dt_proj"]["b"].astype(xc.dtype))            # (..., di)
    return dt, b, c


def mamba_apply(p: dict, x: jnp.ndarray, *, state: int,
                conv_state: Optional[jnp.ndarray] = None,
                ssm_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """x: (B, S, d) -> y: (B, S, d) (+ (conv_state, ssm_state))."""
    bsz, s, d = x.shape
    di = p["a_log"].shape[0]
    dt_rank = p["dt_proj"]["w"].shape[0]
    xz = linear(p["in_proj"], x)                         # (B, S, 2di)
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv1d(xr, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dt, bmat, cmat = _ssm_params(p, xc, state, dt_rank)  # (B,S,di),(B,S,n)x2

    a = -jnp.exp(p["a_log"]).astype(jnp.float32)         # (di, n)
    if ssm_state is None:
        ssm_state = jnp.zeros((bsz, di, state), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                            # (B,di),(B,di),(B,n)
        da = jnp.exp(dtt[..., None].astype(jnp.float32) * a)   # (B,di,n)
        h = da * h + (dtt * xt)[..., None].astype(jnp.float32) \
            * bt[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, ct.astype(jnp.float32))
        return h, y

    inputs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0),
              jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    # Chunked-residual scan (hymba §Perf): group 16 tokens per outer scan
    # step, fuse them with unroll, and jax.checkpoint the chunk so the
    # backward pass saves only per-CHUNK states and recomputes the
    # intra-chunk residuals — mamba1's per-(channel,state) decay rules out
    # the WKV-style matmul chunking, but the residual traffic (which
    # dominates the memory roofline term) still drops ~chunk-fold.
    chunk = 64
    if s % chunk == 0 and s > chunk:
        def chunk_step(h, chunk_inp):
            h, ys = jax.lax.scan(step, h, chunk_inp, unroll=16)
            return h, ys

        chunked = jax.tree.map(
            lambda a: a.reshape((s // chunk, chunk) + a.shape[1:]), inputs)
        ssm_state, ys = jax.lax.scan(jax.checkpoint(chunk_step),
                                     ssm_state, chunked)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        ssm_state, ys = jax.lax.scan(step, ssm_state, inputs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)           # (B, S, di)
    y = y + xc * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = linear(p["out_proj"], y)
    if return_state:
        return out, (conv_state, ssm_state)
    return out


def mamba_decode_step(p: dict, x: jnp.ndarray, conv_state, ssm_state, *,
                      state: int):
    """One-token step.  x: (B, 1, d) -> (y (B, 1, d), states)."""
    return mamba_apply(p, x, state=state, conv_state=conv_state,
                       ssm_state=ssm_state, return_state=True)
