"""RWKV6 (Finch) blocks: time-mix (WKV attention substitute) + channel-mix.

Follows arXiv:2404.05892 with one simplification recorded in DESIGN.md: the
token-shift interpolation weights are per-channel learned constants plus a
low-rank data-dependent term ONLY for the decay w (the paper's ddlerp is
applied to all five streams; the decay is where it matters most).

The WKV core routes through ``repro.kernels.ops.rwkv6`` — the chunked Pallas
kernel on TPU, the scan oracle on CPU.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import linear, linear_init, rmsnorm, rmsnorm_init

__all__ = ["rwkv_block_init", "rwkv_time_mix", "rwkv_channel_mix"]


def rwkv_block_init(key, d: int, d_ff: int, head_dim: int) -> dict:
    n_heads = d // head_dim
    ks = jax.random.split(key, 12)
    lora = max(32, d // 32)
    return {
        "tm": {
            "mix_r": jnp.full((d,), 0.5, jnp.float32),
            "mix_k": jnp.full((d,), 0.5, jnp.float32),
            "mix_v": jnp.full((d,), 0.5, jnp.float32),
            "mix_w": jnp.full((d,), 0.5, jnp.float32),
            "mix_g": jnp.full((d,), 0.5, jnp.float32),
            "wr": linear_init(ks[0], d, d),
            "wk": linear_init(ks[1], d, d),
            "wv": linear_init(ks[2], d, d),
            "wg": linear_init(ks[3], d, d),
            "wo": linear_init(ks[4], d, d),
            # decay: w = exp(-exp(w0 + tanh(x A) B))  (data-dependent, LoRA)
            "w0": jnp.full((d,), -1.8, jnp.float32),
            "w_lora_a": jax.random.normal(ks[5], (d, lora), jnp.float32)
            * 0.01,
            "w_lora_b": jnp.zeros((lora, d), jnp.float32),
            "u": jax.random.normal(ks[6], (n_heads, head_dim), jnp.float32)
            * 0.1,
            "ln_x": rmsnorm_init(d),     # per-head group norm substitute
        },
        "cm": {
            "mix_k": jnp.full((d,), 0.5, jnp.float32),
            "mix_r": jnp.full((d,), 0.5, jnp.float32),
            "wk": linear_init(ks[7], d, d_ff),
            "wv": linear_init(ks[8], d_ff, d, scale=d_ff ** -0.5),
            "wr": linear_init(ks[9], d, d),
        },
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]):
    """xx_t = x_{t-1}; returns (xx, new_prev) with prev (B, 1, d) carry."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    xx = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return xx, x[:, -1:]


def rwkv_time_mix(p: dict, x: jnp.ndarray, *, head_dim: int,
                  wkv_state: Optional[jnp.ndarray] = None,
                  shift_state: Optional[jnp.ndarray] = None,
                  backend: Optional[str] = "xla"):
    """x: (B, S, d) -> (y, new_wkv_state, new_shift_state)."""
    b, s, d = x.shape
    h = d // head_dim
    xx, new_shift = _token_shift(x, shift_state)

    def mixed(name):
        m = p[f"mix_{name}"].astype(x.dtype)
        return x + (xx - x) * m

    r = linear(p["wr"], mixed("r"))
    k = linear(p["wk"], mixed("k"))
    v = linear(p["wv"], mixed("v"))
    g = linear(p["wg"], mixed("g"))
    xw = mixed("w")
    w_log = p["w0"].astype(x.dtype) + jnp.tanh(
        xw @ p["w_lora_a"].astype(x.dtype)) @ p["w_lora_b"].astype(x.dtype)
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32)))      # (B,S,d) in (0,1)

    def heads(t):  # (B,S,d) -> (B,H,S,Dh)
        return jnp.moveaxis(t.reshape(b, s, h, head_dim), 2, 1)

    o, new_state = ops.rwkv6(
        heads(r), heads(k), heads(v), heads(w), p["u"],
        state=wkv_state, backend=backend, return_state=True)
    o = jnp.moveaxis(o, 1, 2).reshape(b, s, d).astype(x.dtype)
    o = rmsnorm(p["ln_x"], o)
    o = o * jax.nn.silu(g)
    return linear(p["wo"], o), new_state, new_shift


def rwkv_channel_mix(p: dict, x: jnp.ndarray, *,
                     shift_state: Optional[jnp.ndarray] = None):
    """Squared-ReLU channel mixing.  Returns (y, new_shift_state)."""
    xx, new_shift = _token_shift(x, shift_state)
    xk = x + (xx - x) * p["mix_k"].astype(x.dtype)
    xr = x + (xx - x) * p["mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(linear(p["wk"], xk)))
    return jax.nn.sigmoid(linear(p["wr"], xr)) * linear(p["wv"], kk), \
        new_shift
