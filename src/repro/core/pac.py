"""PAC — Parallel Acceleration Component, host-side logic (paper §II-C).

This module holds the *schedule* half of PAC (pure numpy, device-free):

  * ``shuffle_combine``  — the paper's random-shuffling strategy: partition
    into |P| > N small parts, then before every epoch randomly group them
    into N super-partitions.  Edges between small parts that land in the same
    group are *recovered* (trained this epoch).
  * ``build_subgraph``   — E_k = {(i,j,t) in E | i,j in V_k}: materialize a
    super-partition's edge stream (this is what recovers deleted edges).
  * ``LocalIndex``       — global<->local node-id mapping per device, with
    all partitions padded to the same local node count so one memory tensor
    (N_max_local, d) serves every device (the paper's "initialize a memory
    store module for each GPU with only maximisation of all GPUs nodes
    count").
  * ``cycle_schedule``   — Alg.2 loop-within-epoch: devices with fewer edges
    wrap around; steps_per_epoch = max_k(batches_k); per-device cycle length
    for the memory backup/restore rule.
  * ``sync_shared_memory`` — reference (numpy) implementation of the two
    shared-node memory synchronization modes: "latest" (largest timestamp
    wins — the paper's choice) and "mean".

The device half (shard_map over axis "part", psum of grads, masked memory
backup) lives in ``repro.tig.distributed`` and follows this schedule exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import numpy as np

from repro.core.sep import PartitionResult

__all__ = [
    "shuffle_combine",
    "member_mask",
    "subgraph_mask",
    "build_subgraph",
    "LocalIndex",
    "make_local_indices",
    "cycle_schedule",
    "CycleSchedule",
    "sync_shared_memory",
    "derived_speedup",
]


def shuffle_combine(
    node_lists: Sequence[np.ndarray],
    num_devices: int,
    rng: np.random.Generator,
) -> list[np.ndarray]:
    """Randomly group |P| small parts into ``num_devices`` super-partitions.

    |P| must be a multiple of N (the paper uses |P|=8 -> N=4).  Returns the
    union node list per super-partition.  Re-invoked before every epoch so
    different "deleted" edges are recovered across epochs (paper Fig.7).
    """
    p = len(node_lists)
    if p % num_devices:
        raise ValueError(f"|P|={p} not divisible by N={num_devices}")
    order = rng.permutation(p)
    group = p // num_devices
    combined = []
    for d in range(num_devices):
        ids = order[d * group: (d + 1) * group]
        combined.append(
            np.unique(np.concatenate([node_lists[i] for i in ids]))
        )
    return combined


def member_mask(nodes: np.ndarray, num_nodes: int) -> np.ndarray:
    """(num_nodes,) bool membership table for one device's node set."""
    member = np.zeros(num_nodes, dtype=bool)
    member[nodes] = True
    return member


def subgraph_mask(
    member: np.ndarray, src: np.ndarray, dst: np.ndarray
) -> np.ndarray:
    """Per-edge mask: BOTH endpoints inside ``member`` (E_k of §II-C).

    Takes a prebuilt membership table so chunked callers (out-of-core
    localization over ``ShardedStream.edge_chunks``) pay the O(N) mask
    build once per device, not once per chunk."""
    return member[src] & member[dst]


def build_subgraph(
    src: np.ndarray,
    dst: np.ndarray,
    nodes: np.ndarray,
    num_nodes: int,
) -> np.ndarray:
    """Indices of edges with BOTH endpoints inside ``nodes`` (E_k of §II-C)."""
    keep = subgraph_mask(member_mask(nodes, num_nodes), src, dst)
    return np.nonzero(keep)[0]


@dataclasses.dataclass
class LocalIndex:
    """Global<->local node-id mapping for one device's memory shard.

    ``globals_`` is the sorted global-id vector (padded with -1 up to
    ``capacity`` so every device's mapping has identical shape);
    ``to_local`` is a (num_nodes,) int32 lookup, -1 for non-members.
    """

    globals_: np.ndarray   # (capacity,) int64, -1 padded
    to_local: np.ndarray   # (num_nodes,) int32
    num_real: int
    capacity: int

    def localize_edges(
        self, src: np.ndarray, dst: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        return self.to_local[src], self.to_local[dst]


def make_local_indices(
    node_lists: Sequence[np.ndarray], num_nodes: int
) -> list[LocalIndex]:
    """Build per-device mappings, all padded to max partition node count."""
    cap = max((len(n) for n in node_lists), default=0)
    out = []
    for nodes in node_lists:
        nodes = np.sort(np.asarray(nodes, dtype=np.int64))
        g = np.full(cap, -1, dtype=np.int64)
        g[: len(nodes)] = nodes
        to_local = np.full(num_nodes, -1, dtype=np.int32)
        to_local[nodes] = np.arange(len(nodes), dtype=np.int32)
        out.append(
            LocalIndex(
                globals_=g,
                to_local=to_local,
                num_real=len(nodes),
                capacity=cap,
            )
        )
    return out


@dataclasses.dataclass
class CycleSchedule:
    """Alg.2 — lockstep steps with per-device wrap-around.

    At global step s, device k trains on its batch ``s % batches[k]``.
    Its data cycle ends whenever ``(s + 1) % batches[k] == 0`` — at that
    moment the device *backs up* its node memory; after the final step the
    memory is *restored* from the backup, so partially-replayed batches never
    leak into the next epoch (paper Alg.2 lines 10-11 + §II-C).
    """

    batches: np.ndarray          # (N,) int — real batches per device
    steps_per_epoch: int         # max_k batches[k]

    def batch_index(self, step: int) -> np.ndarray:
        return step % self.batches

    def is_cycle_end(self, step: int) -> np.ndarray:
        return (step + 1) % self.batches == 0


def cycle_schedule(edges_per_device: Sequence[int], batch_size: int) -> CycleSchedule:
    batches = np.maximum(
        1, -(-np.asarray(edges_per_device, dtype=np.int64) // batch_size)
    )
    return CycleSchedule(
        batches=batches, steps_per_epoch=int(batches.max())
    )


def sync_shared_memory(
    memories: np.ndarray,        # (N_dev, capacity, d)
    last_update: np.ndarray,     # (N_dev, capacity)
    shared_local: np.ndarray,    # (N_dev, S) local row of each shared node
    mode: Literal["latest", "mean"] = "latest",
) -> np.ndarray:
    """Reference shared-node memory synchronization (paper §II-C).

    ``shared_local[d, s]`` is the local row of global shared node s on device
    d (shared nodes exist on ALL devices per Alg.1 line 20).  Returns the
    synchronized copy of ``memories``.

      * "latest": every device adopts the replica with the largest
        last-update timestamp (the paper's choice).
      * "mean":   every device adopts the across-device mean.
    """
    n_dev, _, d = memories.shape
    s = shared_local.shape[1]
    out = memories.copy()
    if s == 0:
        return out
    dev = np.arange(n_dev)[:, None]
    rows = memories[dev, shared_local]          # (N_dev, S, d)
    times = last_update[dev, shared_local]      # (N_dev, S)
    if mode == "latest":
        winner = np.argmax(times, axis=0)       # (S,)
        chosen = rows[winner, np.arange(s)]     # (S, d)
    elif mode == "mean":
        chosen = rows.mean(axis=0)
    else:
        raise ValueError(mode)
    for k in range(n_dev):
        out[k, shared_local[k]] = chosen
    return out


def derived_speedup(edges_per_device: Sequence[int]) -> float:
    """Perfect-overlap speed-up bound: total_edges / max_device_edges.

    On this CPU-only host wall-clock multi-device speedup cannot be measured;
    this is the schedule-derived bound reported alongside measured per-edge
    step time (see DESIGN.md §3).  With balanced partitions and N devices it
    approaches N; imbalance (e.g. KL's) directly shows up as a lower bound —
    the paper's Tab.VII effect.
    """
    e = np.asarray(edges_per_device, dtype=np.float64)
    if e.max() <= 0:
        return 1.0
    return float(e.sum() / e.max())
