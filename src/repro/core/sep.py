"""SEP — Streaming Edge Partitioning (paper §II-B, Alg.1).

A single-pass, node-cut (vertex-cut) streaming partitioner for temporal
interaction graphs.  Edges arrive chronologically; each edge is immediately
assigned to one partition (or, for non-hub/non-hub conflicts, discarded).

Key properties (paper Tab.I):
  * temporal information     — hub selection uses time-decayed centrality,
  * low replication factor   — ONLY hub nodes may be replicated,
  * load balance             — greedy C_BAL term (Eq.6),
  * scalability              — O(|E| * |P|), one pass, O(|V| + |P|) state.

Scoring (Eq.2-6), for edge e=(i, j, t) and candidate partition p:

    theta(i)     = Cent(i) / (Cent(i) + Cent(j))                     (Eq.2)
    C(i, j, p)   = C_REP(i, j, p) + C_BAL(p)                         (Eq.3)
    C_REP(i,j,p) = h(i, p) + h(j, p)                                 (Eq.4)
    h(i, p)      = 1 + (1 - theta(i))  if p in A(i) else 0           (Eq.5)
    C_BAL(p)     = lam * (maxsize - |p|) / (eps + maxsize - minsize) (Eq.6)

Case analysis per Alg.1 (A(i) = set of partitions node i is assigned to):
  both assigned:
    Case 1  exactly one endpoint is a hub      -> partition of the non-hub
    Case 2  both endpoints are hubs            -> argmax_p C(i, j, p)
    Case 3  both non-hubs, same partition      -> that partition
            both non-hubs, different partition -> DISCARD the edge
  otherwise (Cases 4 & 5, at least one endpoint unassigned):
    argmax_p C(i, j, p), restricted so that an already-assigned NON-hub is
    never replicated (candidates = its single partition).

After the pass, every node present in >1 partition (hubs only, by
construction) is a *shared node*; per Alg.1 lines 17-22 shared nodes are added
to ALL partitions (their memory is synchronized globally by PAC).

Implementation notes (chunked-vectorized engine)
------------------------------------------------
The streaming pass is sequential in principle — every assignment mutates the
state later edges score against — but most of that sequential dependence is
an illusion.  The default engine exploits this with a chunked pass:

  * Edges are processed in blocks of ``chunk_size`` (~64k).  For each block
    the Alg.1 case of every edge is classified with vectorized numpy bitmask
    ops against the *start-of-block* assignment state.
  * Case-1 and Case-3 decisions depend only on quantities that are immutable
    within the block: a non-hub's single partition never changes once
    assigned (Thm.1), hub flags are static, and "assigned" only grows.  Any
    edge whose endpoints are BOTH already assigned at block start and that
    is not hub–hub therefore has a balance-independent, order-independent
    verdict — these (the bulk of a power-law stream after warm-up) are
    decided en masse: the non-hub partition is recovered from the single-bit
    mask with an exact ``frexp`` exponent, Case-3 conflicts are discarded by
    a vectorized mask comparison.
  * The remaining *dependency frontier* — score-based edges (Case 2 and
    Cases 4/5, whose C_BAL term sees every prior assignment) and edges
    touching a node first assigned inside the block — falls back to a scalar
    loop.  That loop is pure-Python bit arithmetic (no per-edge numpy), and
    the vectorized edges' side effects (partition-size increments, new hub
    bits) are merge-replayed into it *in stream order*, so every scalar
    score sees exactly the state the reference pass would.

The result is bit-identical to the per-edge reference pass
(``streaming_vertex_cut_reference``, kept as the parity oracle and exercised
by the property tests in ``tests/test_sep_chunked.py``) at >=10x the
throughput on million-edge streams (``benchmarks/table8_partition_time.py``).
Partition membership is a uint64 bitmask per node (|P| <= 64).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.centrality import (
    degree_centrality,
    temporal_centrality,
    top_k_hubs,
)

__all__ = [
    "PartitionResult",
    "sep_partition",
    "streaming_vertex_cut",
    "streaming_vertex_cut_reference",
]

_MAX_PARTS = 64  # uint64 bitmask
_DEFAULT_CHUNK = 65536


@dataclasses.dataclass
class PartitionResult:
    """Output of any partitioner in this package (vertex-cut or edge-cut).

    Attributes:
      num_parts: number of partitions |P|.
      num_nodes: |V| of the input graph.
      edge_part: (E,) int16 — partition id per edge, -1 for discarded edges.
      node_masks: (V,) uint64 — bitmask of partitions each node belongs to
        (AFTER shared-node broadcast, if the algorithm performs one).
      shared_nodes: (S,) int64 — nodes replicated in >1 partition ("shared
        nodes list" of Alg.1); their memory is synchronized by PAC.
      hubs: (V,) bool or None — hub mask used (None for non-SEP algorithms).
      elapsed_s: wall-clock partitioning time (paper Tab.VIII).
      algorithm: name tag.
    """

    num_parts: int
    num_nodes: int
    edge_part: np.ndarray
    node_masks: np.ndarray
    shared_nodes: np.ndarray
    hubs: Optional[np.ndarray]
    elapsed_s: float
    algorithm: str

    def nodes_of(self, p: int) -> np.ndarray:
        """Sorted global node ids belonging to partition ``p``."""
        return np.nonzero((self.node_masks >> np.uint64(p)) & np.uint64(1))[0]

    def node_lists(self) -> list[np.ndarray]:
        return [self.nodes_of(p) for p in range(self.num_parts)]

    def edge_counts(self) -> np.ndarray:
        counts = np.zeros(self.num_parts, dtype=np.int64)
        kept = self.edge_part[self.edge_part >= 0]
        np.add.at(counts, kept, 1)
        return counts

    def node_counts(self) -> np.ndarray:
        return np.array(
            [len(self.nodes_of(p)) for p in range(self.num_parts)],
            dtype=np.int64,
        )


def sep_partition(
    src: np.ndarray,
    dst: np.ndarray,
    t: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    k: float = 0.05,
    beta: float = 0.5,
    lam: float = 1.0,
    eps: float = 1e-6,
    centrality: Optional[np.ndarray] = None,
    shared_to_all: bool = True,
    chunk_size: int = _DEFAULT_CHUNK,
) -> PartitionResult:
    """SEP (Alg.1) with temporal centrality (Eq.1) hub selection.

    Args:
      src, dst, t: the edge stream, chronologically ordered.
      num_nodes: |V|.
      num_parts: |P| (<= 64).
      k: fraction of nodes designated hubs (paper's ``top_k``; 0 disables
        replication entirely, 1 degenerates to HDRF).
      beta: time-decay rate for Eq.1.
      lam: load-balance weight (Eq.6).
      eps: denominator guard (Eq.6).
      centrality: optional precomputed centrality (overrides Eq.1).
      shared_to_all: Alg.1 line 20 — broadcast shared nodes to all partitions.
      chunk_size: block size of the vectorized pass; ``0`` runs the per-edge
        reference pass instead (bit-identical, ~10x slower).
    """
    if centrality is None:
        centrality = temporal_centrality(src, dst, t, num_nodes, beta=beta)
    hubs = top_k_hubs(centrality, k)
    return streaming_vertex_cut(
        src,
        dst,
        num_nodes,
        num_parts,
        centrality=centrality,
        hubs=hubs,
        lam=lam,
        eps=eps,
        shared_to_all=shared_to_all,
        algorithm=f"sep(k={k},beta={beta})",
        chunk_size=chunk_size,
    )


def streaming_vertex_cut_reference(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    centrality: Optional[np.ndarray] = None,
    hubs: Optional[np.ndarray] = None,
    lam: float = 1.0,
    eps: float = 1e-6,
    shared_to_all: bool = True,
    algorithm: str = "streaming_vertex_cut",
) -> PartitionResult:
    """The per-edge reference pass — the parity oracle of the chunked engine.

    ``hubs=None`` means *every* node may replicate (no Case-3 discards) —
    with degree centrality that is exactly HDRF; with uniform centrality it is
    PowerGraph's Greedy heuristic.  A boolean ``hubs`` mask enables the SEP
    hub restriction.
    """
    if num_parts < 1 or num_parts > _MAX_PARTS:
        raise ValueError(f"num_parts must be in [1, {_MAX_PARTS}]")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    num_edges = src.shape[0]
    if centrality is None:
        centrality = degree_centrality(src, dst, num_nodes)

    t0 = time.perf_counter()

    # --- streaming state -------------------------------------------------
    # Partition sets A(i): python-int bitmasks (fast case checks / popcount)
    # mirrored by a bool matrix (vectorized Eq.4-5 scoring).
    assign_mask = [0] * num_nodes
    abits = np.zeros((num_nodes, num_parts), dtype=bool)
    part_edge_sizes = np.zeros(num_parts, dtype=np.float64)  # |p| in Eq.6
    edge_part = np.full(num_edges, -1, dtype=np.int16)
    restrict = hubs is not None
    hub_of = hubs if restrict else None
    cent = centrality
    part_bits = [1 << p for p in range(num_parts)]
    full_mask = (1 << num_parts) - 1

    def _score_and_pick(i: int, j: int, cand_bitmask: int) -> int:
        """argmax_p C(i, j, p) over candidate partitions (Eq.2-6)."""
        ci, cj = cent[i], cent[j]
        denom = ci + cj
        theta_i = 0.5 if denom <= 0 else ci / denom
        maxsize = part_edge_sizes.max()
        minsize = part_edge_sizes.min()
        bal = lam * (maxsize - part_edge_sizes) / (eps + maxsize - minsize)
        # C_REP (Eq.4-5): h(i,p) = 1 + (1 - theta(i)) when p in A(i).
        scores = (
            np.where(abits[i], 2.0 - theta_i, 0.0)
            + np.where(abits[j], 1.0 + theta_i, 0.0)
            + bal
        )
        if cand_bitmask != full_mask:
            cand = np.array(
                [p for p in range(num_parts) if cand_bitmask >> p & 1],
                dtype=np.int64,
            )
            return int(cand[int(np.argmax(scores[cand]))])
        return int(np.argmax(scores))

    def _assign(e: int, i: int, j: int, p: int) -> None:
        edge_part[e] = p
        part_edge_sizes[p] += 1.0
        bit = part_bits[p]
        assign_mask[i] |= bit
        assign_mask[j] |= bit
        abits[i, p] = True
        abits[j, p] = True

    for e in range(num_edges):
        i = int(src[e])
        j = int(dst[e])
        mi = assign_mask[i]
        mj = assign_mask[j]
        if mi and mj:
            if restrict:
                hi = bool(hub_of[i])
                hj = bool(hub_of[j])
                if hi != hj:
                    # Case 1: assign to the partition where the NON-hub lives
                    # (non-hubs live in exactly one partition by construction).
                    nm = mj if hi else mi
                    p = nm.bit_length() - 1
                    _assign(e, i, j, p)
                elif hi and hj:
                    # Case 2: both hubs -> best-scoring partition anywhere.
                    p = _score_and_pick(i, j, full_mask)
                    _assign(e, i, j, p)
                else:
                    # Case 3: both non-hubs.
                    if mi == mj:
                        p = mi.bit_length() - 1
                        _assign(e, i, j, p)
                    # else: discard (edge_part stays -1) — the only edge-cut
                    # source in SEP (Thm.2).
            else:
                # HDRF/Greedy: unrestricted replication, never discard; the
                # h terms (Eq.4-5) already pull the edge towards partitions
                # that hold i and/or j.
                p = _score_and_pick(i, j, full_mask)
                _assign(e, i, j, p)
        else:
            # Cases 4 & 5: at most one endpoint is assigned.  For SEP, an
            # assigned NON-hub pins the candidate set to its single partition
            # (non-hubs never replicate — Thm.1); hubs and fresh nodes score
            # over all partitions (paper line 16).  HDRF/Greedy always score
            # over all partitions; their h terms already favor A(i)/A(j).
            cand = full_mask
            if restrict:
                if mi and not hub_of[i]:
                    cand = mi
                elif mj and not hub_of[j]:
                    cand = mj
            p = _score_and_pick(i, j, cand)
            _assign(e, i, j, p)

    # --- epilogue: shared nodes (Alg.1 lines 17-22) -----------------------
    popcnt = np.array([m.bit_count() for m in assign_mask], dtype=np.int64)
    shared = np.nonzero(popcnt > 1)[0].astype(np.int64)
    if shared_to_all and shared.size:
        for i in shared:
            assign_mask[int(i)] = full_mask
    node_masks = np.array(
        [np.uint64(m) for m in assign_mask], dtype=np.uint64
    )
    elapsed = time.perf_counter() - t0

    return PartitionResult(
        num_parts=num_parts,
        num_nodes=num_nodes,
        edge_part=edge_part,
        node_masks=node_masks,
        shared_nodes=shared,
        hubs=(hub_of.copy() if restrict else None),
        elapsed_s=elapsed,
        algorithm=algorithm,
    )


def _single_bit_log2(mask: np.ndarray) -> np.ndarray:
    """Exact bit position of single-bit uint64 masks (frexp exponent)."""
    # single bits <= 2^63 convert to float64 exactly; frexp returns
    # (0.5, p + 1) exactly — no rounding anywhere.
    _, ex = np.frexp(mask.astype(np.float64))
    return (ex - 1).astype(np.int64)


def streaming_vertex_cut(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    centrality: Optional[np.ndarray] = None,
    hubs: Optional[np.ndarray] = None,
    lam: float = 1.0,
    eps: float = 1e-6,
    shared_to_all: bool = True,
    algorithm: str = "streaming_vertex_cut",
    chunk_size: int = _DEFAULT_CHUNK,
) -> PartitionResult:
    """Chunk-vectorized streaming engine behind SEP and the HDRF/Greedy
    baselines — bit-identical to ``streaming_vertex_cut_reference``.

    See the module docstring for the block decomposition.  ``chunk_size=0``
    delegates to the reference pass.
    """
    if chunk_size <= 0:
        return streaming_vertex_cut_reference(
            src, dst, num_nodes, num_parts, centrality=centrality, hubs=hubs,
            lam=lam, eps=eps, shared_to_all=shared_to_all,
            algorithm=algorithm)
    if num_parts < 1 or num_parts > _MAX_PARTS:
        raise ValueError(f"num_parts must be in [1, {_MAX_PARTS}]")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    num_edges = src.shape[0]
    if centrality is None:
        centrality = degree_centrality(src, dst, num_nodes)
    restrict = hubs is not None

    t0 = time.perf_counter()

    # --- streaming state ---------------------------------------------------
    # A(i) bitmasks live twice: a numpy array for the vectorized per-block
    # classification, a python list for the scalar frontier loop (C-long
    # reads are ~5x cheaper than numpy scalar extraction).  Both are updated
    # at every write site.
    masks_np = np.zeros(num_nodes, dtype=np.uint64)
    masks_l = [0] * num_nodes
    sizes = [0.0] * num_parts                         # |p| of Eq.6
    edge_part = np.full(num_edges, -1, dtype=np.int16)
    cent_l = np.asarray(centrality, dtype=np.float64).tolist()
    hubs_l = hubs.tolist() if restrict else None
    full_mask = (1 << num_parts) - 1
    parts_range = range(num_parts)
    parts_range1 = range(1, num_parts)

    # Tiered exact scoring (see _pick_score): requires theta in [0, 1] and
    # the strict tier separation 0 < bal < lam <= 1, plus enough headroom
    # that no float tie can cross a tier or hide a size difference.  The
    # imbalance guard (checked per call) keeps every relevant score gap
    # >= ~1e-12, i.e. ~3 orders of magnitude above double rounding at
    # magnitude 3; outside it we fall back to the oracle-mirror full scan.
    tier_ok = (0.0 < lam <= 1.0) and eps > 0.0 \
        and bool(np.all(np.asarray(centrality) >= 0.0))
    gap_lim = 1e12 * min(eps, lam) - eps if tier_ok else 0.0
    # O(1) imbalance guard for the inlined tier-1 path: cur_max is exact
    # (sizes only grow by 1), min_lb is a stale-but-valid lower bound on the
    # true min (the min never decreases), so cur_max - min_lb over-estimates
    # the true gap — failing edges re-check with the exact min.
    cur_max = 0.0
    min_lb = 0.0

    def _score_full(mi: int, mj: int, i: int, j: int,
                    cand_bitmask: int) -> int:
        """argmax_p C(i, j, p) — same float ops, same order, same first-max
        tie-break as the reference pass's numpy kernel."""
        ci = cent_l[i]
        cj = cent_l[j]
        denom = ci + cj
        theta_i = 0.5 if denom <= 0 else ci / denom
        a = 2.0 - theta_i
        b = 1.0 + theta_i
        maxsize = max(sizes)
        d = eps + maxsize - min(sizes)
        best_p = -1
        best_s = -np.inf
        for p in parts_range:
            if not (cand_bitmask >> p) & 1:
                continue
            s = ((a if (mi >> p) & 1 else 0.0)
                 + (b if (mj >> p) & 1 else 0.0)) \
                + lam * (maxsize - sizes[p]) / d
            if s > best_s:
                best_s = s
                best_p = p
        return best_p

    def _pick_score(mi: int, mj: int, i: int, j: int) -> int:
        """Full-candidate argmax_p C(i, j, p), via exact score tiers.

        With 0 < lam <= 1 and theta in [0, 1]: rep is 3 on partitions
        holding both endpoints, in [1, 2] on partitions holding one, 0
        elsewhere, while 0 <= bal < lam <= 1 — so the tiers are strictly
        ordered and the argmax lies in the best non-empty tier.  Within
        tier 1/3 all rep terms are equal, so argmax score = first argmin
        of |p| (bal is strictly decreasing in |p|).  Tie-breaks match
        np.argmax's first-max exactly; the imbalance guard rules out the
        astronomically-sized streams where float rounding could blur a
        tier boundary.
        """
        nonlocal min_lb
        maxsize = max(sizes)
        minsize = min(sizes)
        min_lb = minsize
        if not tier_ok or maxsize - minsize >= gap_lim:
            return _score_full(mi, mj, i, j, full_mask)
        both = mi & mj
        if both:
            best_p = -1
            best_s = np.inf
            m = both
            while m:
                low = m & -m
                p = low.bit_length() - 1
                sp = sizes[p]
                if sp < best_s:
                    best_s = sp
                    best_p = p
                m ^= low
            return best_p
        un = mi | mj
        if un:
            ci = cent_l[i]
            cj = cent_l[j]
            denom = ci + cj
            theta_i = 0.5 if denom <= 0 else ci / denom
            a = 2.0 - theta_i
            b = 1.0 + theta_i
            d = eps + maxsize - minsize
            best_p = -1
            best_s = -np.inf
            m = un
            while m:
                low = m & -m
                p = low.bit_length() - 1
                s = ((a if (mi >> p) & 1 else 0.0)
                     + (b if (mj >> p) & 1 else 0.0)) \
                    + lam * (maxsize - sizes[p]) / d
                if s > best_s:
                    best_s = s
                    best_p = p
                m ^= low
            return best_p
        best_p = 0
        best_s = sizes[0]
        for p in parts_range:
            if sizes[p] < best_s:
                best_s = sizes[p]
                best_p = p
        return best_p

    def _dispatch_edge(i: int, j: int) -> int:
        """Full Alg.1 case logic for a first-touch frontier edge (its case
        was unknown at block start); returns the partition or -1 (discard)."""
        mi = masks_l[i]
        mj = masks_l[j]
        if mi and mj:
            if restrict:
                hi = hubs_l[i]
                hj = hubs_l[j]
                if hi != hj:
                    return (mj if hi else mi).bit_length() - 1
                if hi:
                    return _pick_score(mi, mj, i, j)
                if mi != mj:
                    return -1          # Case-3 discard (Thm.2)
                return mi.bit_length() - 1
            return _pick_score(mi, mj, i, j)
        if restrict:
            # an assigned non-hub pins the candidate set to its single
            # partition: the restricted argmax is that partition, no floats.
            if mi and not hubs_l[i]:
                return mi.bit_length() - 1
            if mj and not hubs_l[j]:
                return mj.bit_length() - 1
        return _pick_score(mi, mj, i, j)

    for lo in range(0, num_edges, chunk_size):
        hi_ = min(lo + chunk_size, num_edges)
        bs = src[lo:hi_]
        bd = dst[lo:hi_]
        m_i = masks_np[bs]
        m_j = masks_np[bd]
        both = (m_i != 0) & (m_j != 0)

        if restrict:
            hub_i = hubs[bs]
            hub_j = hubs[bd]
            c1 = both & (hub_i ^ hub_j)                # Case 1
            c3 = both & ~(hub_i | hub_j)               # Case 3
            vec = c1 | c3
            known_score = both & hub_i & hub_j         # Case 2
        else:
            # HDRF/Greedy: every edge is score-based; both-assigned ones
            # have a statically-known code path (full-candidate scoring).
            vec = np.zeros(len(bs), dtype=bool)
            c1 = c3 = vec
            known_score = both

        # -- vectorized verdicts (balance- and order-independent) ----------
        pos1 = np.nonzero(c1)[0]
        if len(pos1):
            nh_mask = np.where(hub_i[pos1], m_j[pos1], m_i[pos1])
            p1 = _single_bit_log2(nh_mask)
            hub_node = np.where(hub_i[pos1], bs[pos1], bd[pos1])
        else:
            p1 = np.zeros(0, np.int64)
            hub_node = np.zeros(0, np.int64)

        pos3 = np.nonzero(c3)[0]
        keep3 = m_i[pos3] == m_j[pos3]
        pos3k = pos3[keep3]
        p3 = _single_bit_log2(m_i[pos3k])
        # Case-3 conflicts (mask mismatch) stay -1: the discard of Thm.2.

        edge_part[lo + pos1] = p1.astype(np.int16)
        edge_part[lo + pos3k] = p3.astype(np.int16)

        # effect stream of the vectorized edges, in block position order
        vpos = np.concatenate([pos1, pos3k])
        vpart = np.concatenate([p1, p3])
        vnode = np.concatenate([hub_node,
                                np.full(len(pos3k), -1, np.int64)])
        order = np.argsort(vpos, kind="stable")
        vpos, vpart, vnode = vpos[order], vpart[order], vnode[order]

        spos = np.nonzero(~vec)[0]
        if len(spos) == 0:
            # whole block vectorized: bulk-apply the effects
            _apply_effects_bulk(masks_np, masks_l, sizes, vpart, vnode,
                                num_parts)
            cur_max = max(sizes)
            continue

        # -- merge-replay: scalar frontier interleaved with vec effects ----
        sp_l = spos.tolist()
        si_l = bs[spos].tolist()
        sj_l = bd[spos].tolist()
        sk_l = known_score[spos].tolist()
        vp_l = vpos.tolist()
        vq_l = vpart.tolist()
        vn_l = vnode.tolist()
        nv = len(vp_l)
        v = 0
        spart: list[int] = []
        sp_append = spart.append
        dirty: list[int] = []                 # nodes whose numpy mask mirror
        d_append = dirty.append               # is stale (synced at block end)
        for pos, i, j, known in zip(sp_l, si_l, sj_l, sk_l):
            while v < nv and vp_l[v] < pos:
                q = vq_l[v]
                sq = sizes[q] + 1.0
                sizes[q] = sq
                if sq > cur_max:
                    cur_max = sq
                n = vn_l[v]
                if n >= 0:
                    masks_l[n] |= 1 << q
                    d_append(n)
                v += 1
            mi = masks_l[i]
            mj = masks_l[j]
            if known:
                # dominant path, inlined: both-endpoint tier (rep = 3
                # everywhere in A(i) ∩ A(j)) -> first argmin of |p|.
                bb = mi & mj
                if bb == full_mask and tier_ok \
                        and cur_max - min_lb < gap_lim:
                    # steady-state hub-hub edge: both masks saturated, so
                    # the verdict is first-argmin(|p|) and the assignment
                    # cannot add mask bits — sizes is the only effect.
                    p = 0
                    best_s = sizes[0]
                    for pp in parts_range1:
                        sp = sizes[pp]
                        if sp < best_s:
                            best_s = sp
                            p = pp
                    sp_append(p)
                    sp = sizes[p] + 1.0
                    sizes[p] = sp
                    if sp > cur_max:
                        cur_max = sp
                    continue
                if bb and tier_ok and cur_max - min_lb < gap_lim:
                    p = -1
                    best_s = np.inf
                    m = bb
                    while m:
                        low = m & -m
                        pp = low.bit_length() - 1
                        sp = sizes[pp]
                        if sp < best_s:
                            best_s = sp
                            p = pp
                        m ^= low
                else:
                    p = _pick_score(mi, mj, i, j)
            else:
                p = _dispatch_edge(i, j)
                if p < 0:
                    sp_append(-1)
                    continue
            sp_append(p)
            sp = sizes[p] + 1.0
            sizes[p] = sp
            if sp > cur_max:
                cur_max = sp
            bit = 1 << p
            masks_l[i] = mi | bit
            masks_l[j] = masks_l[j] | bit
            d_append(i)
            d_append(j)
        edge_part[lo + spos] = np.array(spart, dtype=np.int16)
        if v < nv:
            _apply_effects_bulk(masks_np, masks_l, sizes, vpart[v:],
                                vnode[v:], num_parts)
            cur_max = max(sizes)
        if dirty:
            dn = np.array(dirty, dtype=np.int64)
            masks_np[dn] = np.array([masks_l[x] for x in dirty],
                                    dtype=np.uint64)

    # --- epilogue: shared nodes (Alg.1 lines 17-22) -----------------------
    popcnt = _popcount(masks_np)
    shared = np.nonzero(popcnt > 1)[0].astype(np.int64)
    if shared_to_all and shared.size:
        masks_np[shared] = np.uint64(full_mask)
    elapsed = time.perf_counter() - t0

    return PartitionResult(
        num_parts=num_parts,
        num_nodes=num_nodes,
        edge_part=edge_part,
        node_masks=masks_np,
        shared_nodes=shared,
        hubs=(hubs.copy() if restrict else None),
        elapsed_s=elapsed,
        algorithm=algorithm,
    )


def _apply_effects_bulk(masks_np: np.ndarray, masks_l: list, sizes: list,
                        vpart: np.ndarray, vnode: np.ndarray,
                        num_parts: int) -> None:
    """Apply vectorized edges' side effects (order-commutative adds/ORs)."""
    if len(vpart) == 0:
        return
    counts = np.bincount(vpart, minlength=num_parts)
    for p in range(num_parts):
        sizes[p] += float(counts[p])
    upd = vnode >= 0
    if upd.any():
        np.bitwise_or.at(
            masks_np, vnode[upd],
            np.uint64(1) << vpart[upd].astype(np.uint64))
        for n, q in zip(vnode[upd].tolist(), vpart[upd].tolist()):
            masks_l[n] |= 1 << q


def _popcount(masks: np.ndarray) -> np.ndarray:
    try:
        return np.bitwise_count(masks).astype(np.int64)
    except AttributeError:  # numpy < 2.0
        return np.array([int(m).bit_count() for m in masks], dtype=np.int64)
