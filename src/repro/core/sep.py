"""SEP — Streaming Edge Partitioning (paper §II-B, Alg.1).

A single-pass, node-cut (vertex-cut) streaming partitioner for temporal
interaction graphs.  Edges arrive chronologically; each edge is immediately
assigned to one partition (or, for non-hub/non-hub conflicts, discarded).

Key properties (paper Tab.I):
  * temporal information     — hub selection uses time-decayed centrality,
  * low replication factor   — ONLY hub nodes may be replicated,
  * load balance             — greedy C_BAL term (Eq.6),
  * scalability              — O(|E| * |P|), one pass, O(|V| + |P|) state.

Scoring (Eq.2-6), for edge e=(i, j, t) and candidate partition p:

    theta(i)     = Cent(i) / (Cent(i) + Cent(j))                     (Eq.2)
    C(i, j, p)   = C_REP(i, j, p) + C_BAL(p)                         (Eq.3)
    C_REP(i,j,p) = h(i, p) + h(j, p)                                 (Eq.4)
    h(i, p)      = 1 + (1 - theta(i))  if p in A(i) else 0           (Eq.5)
    C_BAL(p)     = lam * (maxsize - |p|) / (eps + maxsize - minsize) (Eq.6)

Case analysis per Alg.1 (A(i) = set of partitions node i is assigned to):
  both assigned:
    Case 1  exactly one endpoint is a hub      -> partition of the non-hub
    Case 2  both endpoints are hubs            -> argmax_p C(i, j, p)
    Case 3  both non-hubs, same partition      -> that partition
            both non-hubs, different partition -> DISCARD the edge
  otherwise (Cases 4 & 5, at least one endpoint unassigned):
    argmax_p C(i, j, p), restricted so that an already-assigned NON-hub is
    never replicated (candidates = its single partition).

After the pass, every node present in >1 partition (hubs only, by
construction) is a *shared node*; per Alg.1 lines 17-22 shared nodes are added
to ALL partitions (their memory is synchronized globally by PAC).

Implementation notes: partition membership is a uint64 bitmask per node
(|P| <= 64), partition scores are computed with small (|P|,) numpy kernels,
and the edge loop is plain Python — the same O(|E|) streaming pass as the
paper, ~1e5 edges/s on one core.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.centrality import (
    degree_centrality,
    temporal_centrality,
    top_k_hubs,
)

__all__ = ["PartitionResult", "sep_partition", "streaming_vertex_cut"]

_MAX_PARTS = 64  # uint64 bitmask


@dataclasses.dataclass
class PartitionResult:
    """Output of any partitioner in this package (vertex-cut or edge-cut).

    Attributes:
      num_parts: number of partitions |P|.
      num_nodes: |V| of the input graph.
      edge_part: (E,) int16 — partition id per edge, -1 for discarded edges.
      node_masks: (V,) uint64 — bitmask of partitions each node belongs to
        (AFTER shared-node broadcast, if the algorithm performs one).
      shared_nodes: (S,) int64 — nodes replicated in >1 partition ("shared
        nodes list" of Alg.1); their memory is synchronized by PAC.
      hubs: (V,) bool or None — hub mask used (None for non-SEP algorithms).
      elapsed_s: wall-clock partitioning time (paper Tab.VIII).
      algorithm: name tag.
    """

    num_parts: int
    num_nodes: int
    edge_part: np.ndarray
    node_masks: np.ndarray
    shared_nodes: np.ndarray
    hubs: Optional[np.ndarray]
    elapsed_s: float
    algorithm: str

    def nodes_of(self, p: int) -> np.ndarray:
        """Sorted global node ids belonging to partition ``p``."""
        return np.nonzero((self.node_masks >> np.uint64(p)) & np.uint64(1))[0]

    def node_lists(self) -> list[np.ndarray]:
        return [self.nodes_of(p) for p in range(self.num_parts)]

    def edge_counts(self) -> np.ndarray:
        counts = np.zeros(self.num_parts, dtype=np.int64)
        kept = self.edge_part[self.edge_part >= 0]
        np.add.at(counts, kept, 1)
        return counts

    def node_counts(self) -> np.ndarray:
        return np.array(
            [len(self.nodes_of(p)) for p in range(self.num_parts)],
            dtype=np.int64,
        )


def sep_partition(
    src: np.ndarray,
    dst: np.ndarray,
    t: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    k: float = 0.05,
    beta: float = 0.5,
    lam: float = 1.0,
    eps: float = 1e-6,
    centrality: Optional[np.ndarray] = None,
    shared_to_all: bool = True,
) -> PartitionResult:
    """SEP (Alg.1) with temporal centrality (Eq.1) hub selection.

    Args:
      src, dst, t: the edge stream, chronologically ordered.
      num_nodes: |V|.
      num_parts: |P| (<= 64).
      k: fraction of nodes designated hubs (paper's ``top_k``; 0 disables
        replication entirely, 1 degenerates to HDRF).
      beta: time-decay rate for Eq.1.
      lam: load-balance weight (Eq.6).
      eps: denominator guard (Eq.6).
      centrality: optional precomputed centrality (overrides Eq.1).
      shared_to_all: Alg.1 line 20 — broadcast shared nodes to all partitions.
    """
    if centrality is None:
        centrality = temporal_centrality(src, dst, t, num_nodes, beta=beta)
    hubs = top_k_hubs(centrality, k)
    return streaming_vertex_cut(
        src,
        dst,
        num_nodes,
        num_parts,
        centrality=centrality,
        hubs=hubs,
        lam=lam,
        eps=eps,
        shared_to_all=shared_to_all,
        algorithm=f"sep(k={k},beta={beta})",
    )


def streaming_vertex_cut(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    centrality: Optional[np.ndarray] = None,
    hubs: Optional[np.ndarray] = None,
    lam: float = 1.0,
    eps: float = 1e-6,
    shared_to_all: bool = True,
    algorithm: str = "streaming_vertex_cut",
) -> PartitionResult:
    """The shared streaming engine behind SEP and the HDRF/Greedy baselines.

    ``hubs=None`` means *every* node may replicate (no Case-3 discards) —
    with degree centrality that is exactly HDRF; with uniform centrality it is
    PowerGraph's Greedy heuristic.  A boolean ``hubs`` mask enables the SEP
    hub restriction.
    """
    if num_parts < 1 or num_parts > _MAX_PARTS:
        raise ValueError(f"num_parts must be in [1, {_MAX_PARTS}]")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    num_edges = src.shape[0]
    if centrality is None:
        centrality = degree_centrality(src, dst, num_nodes)

    t0 = time.perf_counter()

    # --- streaming state -------------------------------------------------
    # Partition sets A(i): python-int bitmasks (fast case checks / popcount)
    # mirrored by a bool matrix (vectorized Eq.4-5 scoring).
    assign_mask = [0] * num_nodes
    abits = np.zeros((num_nodes, num_parts), dtype=bool)
    part_edge_sizes = np.zeros(num_parts, dtype=np.float64)  # |p| in Eq.6
    edge_part = np.full(num_edges, -1, dtype=np.int16)
    restrict = hubs is not None
    hub_of = hubs if restrict else None
    cent = centrality
    all_parts = np.arange(num_parts)
    part_bits = [1 << p for p in range(num_parts)]
    full_mask = (1 << num_parts) - 1

    def _score_and_pick(i: int, j: int, cand_bitmask: int) -> int:
        """argmax_p C(i, j, p) over candidate partitions (Eq.2-6)."""
        ci, cj = cent[i], cent[j]
        denom = ci + cj
        theta_i = 0.5 if denom <= 0 else ci / denom
        maxsize = part_edge_sizes.max()
        minsize = part_edge_sizes.min()
        bal = lam * (maxsize - part_edge_sizes) / (eps + maxsize - minsize)
        # C_REP (Eq.4-5): h(i,p) = 1 + (1 - theta(i)) when p in A(i).
        scores = (
            np.where(abits[i], 2.0 - theta_i, 0.0)
            + np.where(abits[j], 1.0 + theta_i, 0.0)
            + bal
        )
        if cand_bitmask != full_mask:
            cand = np.array(
                [p for p in range(num_parts) if cand_bitmask >> p & 1],
                dtype=np.int64,
            )
            return int(cand[int(np.argmax(scores[cand]))])
        return int(np.argmax(scores))

    def _assign(e: int, i: int, j: int, p: int) -> None:
        edge_part[e] = p
        part_edge_sizes[p] += 1.0
        bit = part_bits[p]
        assign_mask[i] |= bit
        assign_mask[j] |= bit
        abits[i, p] = True
        abits[j, p] = True

    for e in range(num_edges):
        i = int(src[e])
        j = int(dst[e])
        mi = assign_mask[i]
        mj = assign_mask[j]
        if mi and mj:
            if restrict:
                hi = bool(hub_of[i])
                hj = bool(hub_of[j])
                if hi != hj:
                    # Case 1: assign to the partition where the NON-hub lives
                    # (non-hubs live in exactly one partition by construction).
                    nm = mj if hi else mi
                    p = nm.bit_length() - 1
                    _assign(e, i, j, p)
                elif hi and hj:
                    # Case 2: both hubs -> best-scoring partition anywhere.
                    p = _score_and_pick(i, j, full_mask)
                    _assign(e, i, j, p)
                else:
                    # Case 3: both non-hubs.
                    if mi == mj:
                        p = mi.bit_length() - 1
                        _assign(e, i, j, p)
                    # else: discard (edge_part stays -1) — the only edge-cut
                    # source in SEP (Thm.2).
            else:
                # HDRF/Greedy: unrestricted replication, never discard; the
                # h terms (Eq.4-5) already pull the edge towards partitions
                # that hold i and/or j.
                p = _score_and_pick(i, j, full_mask)
                _assign(e, i, j, p)
        else:
            # Cases 4 & 5: at most one endpoint is assigned.  For SEP, an
            # assigned NON-hub pins the candidate set to its single partition
            # (non-hubs never replicate — Thm.1); hubs and fresh nodes score
            # over all partitions (paper line 16).  HDRF/Greedy always score
            # over all partitions; their h terms already favor A(i)/A(j).
            cand = full_mask
            if restrict:
                if mi and not hub_of[i]:
                    cand = mi
                elif mj and not hub_of[j]:
                    cand = mj
            p = _score_and_pick(i, j, cand)
            _assign(e, i, j, p)

    # --- epilogue: shared nodes (Alg.1 lines 17-22) -----------------------
    popcnt = np.array([m.bit_count() for m in assign_mask], dtype=np.int64)
    shared = np.nonzero(popcnt > 1)[0].astype(np.int64)
    if shared_to_all and shared.size:
        for i in shared:
            assign_mask[int(i)] = full_mask
    node_masks = np.array(
        [np.uint64(m) for m in assign_mask], dtype=np.uint64
    )
    elapsed = time.perf_counter() - t0

    return PartitionResult(
        num_parts=num_parts,
        num_nodes=num_nodes,
        edge_part=edge_part,
        node_masks=node_masks,
        shared_nodes=shared,
        hubs=(hub_of.copy() if restrict else None),
        elapsed_s=elapsed,
        algorithm=algorithm,
    )
