"""SPEED core: streaming edge partitioning (SEP) + parallel acceleration (PAC).

The paper's primary contribution, as host-side algorithms:
  * ``repro.core.centrality`` — temporal time-decay centrality (Eq.1-2).
  * ``repro.core.sep``        — Alg.1 streaming vertex-cut partitioner.
  * ``repro.core.baselines``  — HDRF / Greedy / Random / LDG / KL.
  * ``repro.core.metrics``    — RF / EC / balance + Thm.1-2 bounds.
  * ``repro.core.pac``        — shuffle-combine, Alg.2 cycle schedule,
                                shared-node memory sync (reference impl).

The accelerator half of PAC (shard_map training) is ``repro.tig.distributed``.
"""

from repro.core.baselines import (
    greedy_partition,
    hdrf_partition,
    kl_partition,
    ldg_partition,
    random_partition,
)
from repro.core.centrality import (
    degree_centrality,
    temporal_centrality,
    top_k_hubs,
)
from repro.core.metrics import (
    edge_cut_fraction,
    partition_stats,
    replication_factor,
    thm1_rf_bound,
    thm2_ec_bound,
)
from repro.core.pac import (
    build_subgraph,
    cycle_schedule,
    derived_speedup,
    make_local_indices,
    shuffle_combine,
    sync_shared_memory,
)
from repro.core.sep import (
    PartitionResult,
    sep_partition,
    streaming_vertex_cut,
    streaming_vertex_cut_reference,
)

__all__ = [
    "PartitionResult",
    "sep_partition",
    "streaming_vertex_cut",
    "streaming_vertex_cut_reference",
    "hdrf_partition",
    "greedy_partition",
    "random_partition",
    "ldg_partition",
    "kl_partition",
    "temporal_centrality",
    "degree_centrality",
    "top_k_hubs",
    "replication_factor",
    "edge_cut_fraction",
    "partition_stats",
    "thm1_rf_bound",
    "thm2_ec_bound",
    "shuffle_combine",
    "build_subgraph",
    "make_local_indices",
    "cycle_schedule",
    "sync_shared_memory",
    "derived_speedup",
]
