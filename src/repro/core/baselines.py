"""Baseline graph partitioners the paper compares against (Tab.I/VI/VII/VIII).

Vertex-cut streaming baselines reuse the SEP engine (``streaming_vertex_cut``):
  * HDRF [14]   — SEP degenerate case: every node replicable, partial-degree
                  centrality (paper §III-B: "when there is no restriction for
                  top_k the algorithm degenerates to HDRF").
  * Greedy [13] — PowerGraph's heuristic: HDRF with uniform centrality
                  (theta == 0.5, i.e. degree-blind).
  * Random [9]  — uniform random edge assignment (Euler-style).

Edge-cut baselines (nodes live in exactly one partition; every edge whose
endpoints land in different partitions is cut — for TIG training those edges
are deleted):
  * LDG [10]    — Linear Deterministic Greedy node streaming.
  * KL [8]      — Kernighan-Lin, via recursive bisection (networkx);
                  the paper's representative *static* (slow, global) method.

METIS [7] is not reproducible offline (no library); KL plays the static-
partitioner role, exactly as in the paper's §III-D comparison.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.centrality import degree_centrality
from repro.core.sep import PartitionResult, streaming_vertex_cut

__all__ = [
    "hdrf_partition",
    "greedy_partition",
    "random_partition",
    "ldg_partition",
    "kl_partition",
    "edge_cut_result_from_node_assignment",
]


def hdrf_partition(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    lam: float = 1.0,
    eps: float = 1e-6,
) -> PartitionResult:
    """HDRF [14]: highest-degree nodes replicate first; no replication cap."""
    cent = degree_centrality(src, dst, num_nodes)
    res = streaming_vertex_cut(
        src,
        dst,
        num_nodes,
        num_parts,
        centrality=cent,
        hubs=None,
        lam=lam,
        eps=eps,
        algorithm="hdrf",
    )
    return res


def greedy_partition(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    lam: float = 1.0,
) -> PartitionResult:
    """PowerGraph Greedy [13]: degree-blind vertex-cut streaming."""
    cent = np.ones(num_nodes, dtype=np.float64)
    return streaming_vertex_cut(
        src,
        dst,
        num_nodes,
        num_parts,
        centrality=cent,
        hubs=None,
        lam=lam,
        algorithm="greedy",
    )


def random_partition(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    seed: int = 0,
) -> PartitionResult:
    """Uniform random edge assignment [9]: high RF, perfect edge balance."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    num_edges = len(src)
    edge_part = rng.integers(0, num_parts, size=num_edges).astype(np.int16)
    node_masks = np.zeros(num_nodes, dtype=np.uint64)
    one = np.uint64(1)
    np.bitwise_or.at(node_masks, np.asarray(src, np.int64),
                     one << edge_part.astype(np.uint64))
    np.bitwise_or.at(node_masks, np.asarray(dst, np.int64),
                     one << edge_part.astype(np.uint64))
    pop = np.array([int(m).bit_count() for m in node_masks])
    shared = np.nonzero(pop > 1)[0].astype(np.int64)
    return PartitionResult(
        num_parts=num_parts,
        num_nodes=num_nodes,
        edge_part=edge_part,
        node_masks=node_masks,
        shared_nodes=shared,
        hubs=None,
        elapsed_s=time.perf_counter() - t0,
        algorithm="random",
    )


def edge_cut_result_from_node_assignment(
    src: np.ndarray,
    dst: np.ndarray,
    node_part: np.ndarray,
    num_parts: int,
    elapsed_s: float,
    algorithm: str,
) -> PartitionResult:
    """Package an edge-cut partitioning (one partition per node).

    Edges whose endpoints disagree are cut (edge_part = -1): in the paper's
    training pipeline such edges are deleted, exactly like SEP's Case-3
    discards — which is how edge-cut partitioners plug into PAC unchanged.
    """
    node_part = np.asarray(node_part, dtype=np.int64)
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    same = node_part[src] == node_part[dst]
    edge_part = np.where(same, node_part[src], -1).astype(np.int16)
    node_masks = (np.uint64(1) << node_part.astype(np.uint64)).astype(
        np.uint64
    )
    return PartitionResult(
        num_parts=num_parts,
        num_nodes=len(node_part),
        edge_part=edge_part,
        node_masks=node_masks,
        shared_nodes=np.zeros(0, dtype=np.int64),
        hubs=None,
        elapsed_s=elapsed_s,
        algorithm=algorithm,
    )


def ldg_partition(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    capacity_slack: float = 1.1,
) -> PartitionResult:
    """Linear Deterministic Greedy [10] (node-stream, edge-cut).

    Nodes arrive in first-appearance order; each is placed in the partition
    maximizing |N(v) ∩ p| * (1 - |p|/C) with capacity C = slack * |V|/|P|.
    """
    t0 = time.perf_counter()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    # Build adjacency (undirected) via CSR for neighbor lookups.
    import scipy.sparse as sp

    ones = np.ones(len(src), dtype=np.int8)
    adj = sp.coo_matrix(
        (np.concatenate([ones, ones]),
         (np.concatenate([src, dst]), np.concatenate([dst, src]))),
        shape=(num_nodes, num_nodes),
    ).tocsr()
    inter = np.empty(len(src) * 2, dtype=np.int64)
    inter[0::2] = src
    inter[1::2] = dst
    _, first_idx = np.unique(inter, return_index=True)
    order = inter[np.sort(first_idx)]
    node_part = np.full(num_nodes, -1, dtype=np.int64)
    sizes = np.zeros(num_parts, dtype=np.float64)
    cap = capacity_slack * num_nodes / num_parts
    for v in order:
        lo, hi = adj.indptr[v], adj.indptr[v + 1]
        nbrs = adj.indices[lo:hi]
        assigned = node_part[nbrs]
        counts = np.zeros(num_parts, dtype=np.float64)
        valid = assigned[assigned >= 0]
        if valid.size:
            np.add.at(counts, valid, 1.0)
        scores = counts * (1.0 - sizes / cap)
        p = int(np.argmax(scores))
        node_part[v] = p
        sizes[p] += 1.0
    node_part[node_part < 0] = np.argmin(sizes)
    return edge_cut_result_from_node_assignment(
        src, dst, node_part, num_parts,
        time.perf_counter() - t0, "ldg",
    )


def kl_partition(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    num_parts: int,
    *,
    seed: int = 0,
    max_iter: int = 10,
) -> PartitionResult:
    """Kernighan-Lin [8] recursive bisection (static, edge-cut, slow).

    num_parts must be a power of two.  This is the paper's Tab.VI-VIII
    static-partitioning baseline: good edge-cut, poor edge balance (KL
    balances *nodes*, not edges), and orders-of-magnitude slower than SEP.
    """
    import networkx as nx

    if num_parts & (num_parts - 1):
        raise ValueError("kl_partition requires a power-of-two num_parts")
    t0 = time.perf_counter()
    g = nx.Graph()
    g.add_nodes_from(range(num_nodes))
    g.add_edges_from(zip(np.asarray(src).tolist(), np.asarray(dst).tolist()))
    node_part = np.zeros(num_nodes, dtype=np.int64)

    def _bisect(nodes: list, base: int, span: int, depth_seed: int) -> None:
        if span == 1 or len(nodes) < 2:
            return
        sub = g.subgraph(nodes)
        a, b = nx.algorithms.community.kernighan_lin_bisection(
            sub, max_iter=max_iter, seed=depth_seed
        )
        a, b = list(a), list(b)
        for n in b:
            node_part[n] += span // 2
        _bisect(a, base, span // 2, depth_seed + 1)
        _bisect(b, base + span // 2, span // 2, depth_seed + 2)

    _bisect(list(range(num_nodes)), 0, num_parts, seed)
    return edge_cut_result_from_node_assignment(
        src, dst, node_part, num_parts,
        time.perf_counter() - t0, "kl",
    )
