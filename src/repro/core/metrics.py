"""Partition-quality metrics and theoretical bounds (paper Eq.7-11, Tab.VI).

    RF = total node replicas / total nodes                     (Eq.7)
    EC = total edge cuts between partitions / total edges      (Eq.8)

Theorems (worst-case bounds for SEP):

    Thm.1:  RF < k|P| + (1 - k)                                (Eq.9)
    Thm.2:  EC <= (1/|E|) * sum_{q=0}^{|V|(1-k)-1}
                    m * (k + q/|V|)^{1/(1-alpha)}              (Eq.11)

where m is the minimum degree and alpha the power-law skew (Eq.10, from
Cohen et al. [18]).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sep import PartitionResult

__all__ = [
    "PartitionStats",
    "replication_factor",
    "edge_cut_fraction",
    "partition_stats",
    "thm1_rf_bound",
    "thm2_ec_bound",
    "fit_power_law_alpha",
]


def replication_factor(res: PartitionResult, denominator: str = "placed"
                       ) -> float:
    """Eq.7 — average number of copies per node (counting all replicas).

    denominator="placed" (default, the operational metric): nodes never
    touched by any edge are excluded — they hold no memory and live on no
    device.  denominator="all" uses |V|, matching Thm.1's statement exactly.
    """
    pop = np.array(
        [int(m).bit_count() for m in res.node_masks], dtype=np.int64
    )
    if denominator == "all":
        n = res.num_nodes
    else:
        n = int((pop > 0).sum())
    if n == 0:
        return 0.0
    return float(pop.sum()) / n


def edge_cut_fraction(res: PartitionResult) -> float:
    """Eq.8 — fraction of edges lost to cuts/discards (edge_part == -1)."""
    e = len(res.edge_part)
    if e == 0:
        return 0.0
    return float((res.edge_part < 0).sum()) / e


@dataclasses.dataclass
class PartitionStats:
    """The Tab.VI row for one partitioning."""

    algorithm: str
    num_parts: int
    edge_cut: float            # "Total Cut" (fraction)
    edge_std: float            # "Edge Std."
    replication_factor: float
    avg_node_portion: float    # "Avg. Portion" — mean |V_p| / |V|
    node_std: float            # "Node Std."
    num_shared: int
    elapsed_s: float

    def row(self) -> dict:
        return dataclasses.asdict(self)


def partition_stats(res: PartitionResult) -> PartitionStats:
    edge_counts = res.edge_counts().astype(np.float64)
    node_counts = res.node_counts().astype(np.float64)
    placed = np.array(
        [int(m).bit_count() > 0 for m in res.node_masks]
    ).sum()
    denom = max(int(placed), 1)
    return PartitionStats(
        algorithm=res.algorithm,
        num_parts=res.num_parts,
        edge_cut=edge_cut_fraction(res),
        edge_std=float(edge_counts.std()),
        replication_factor=replication_factor(res),
        avg_node_portion=float(node_counts.mean()) / denom,
        node_std=float(node_counts.std()),
        num_shared=int(len(res.shared_nodes)),
        elapsed_s=res.elapsed_s,
    )


def thm1_rf_bound(k: float, num_parts: int) -> float:
    """Eq.9 — worst-case replication factor of SEP."""
    return k * num_parts + (1.0 - k)


def thm2_ec_bound(
    num_nodes: int,
    num_edges: int,
    k: float,
    m: float,
    alpha: float,
) -> float:
    """Eq.11 — worst-case edge-cut of SEP on a power-law graph.

    Args:
      m: minimum node degree.
      alpha: power-law exponent (> 1), per Cohen et al. (Eq.10).
    """
    if alpha <= 1.0:
        raise ValueError("power-law alpha must exceed 1")
    q = np.arange(int(num_nodes * (1.0 - k)))
    vals = m * np.power(k + q / num_nodes, 1.0 / (1.0 - alpha))
    return float(vals.sum()) / max(num_edges, 1)


def fit_power_law_alpha(degrees: np.ndarray, d_min: int = 1) -> float:
    """MLE power-law exponent: alpha = 1 + n / sum(ln(d / d_min))."""
    d = degrees[degrees >= d_min].astype(np.float64)
    if len(d) == 0:
        return 2.5
    return 1.0 + len(d) / float(np.log(d / (d_min - 0.5)).sum())
