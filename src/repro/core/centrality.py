"""Node centrality for temporal interaction graphs (paper Eq.1-2).

The SEP partitioner ranks nodes by *temporal centrality*: the sum of
exponentially time-decayed weights of all edges historically incident to the
node,

    Cent(i) = sum_{t in T(i)} exp(beta * (t - t_max))          (Eq.1)

so that recently-active nodes dominate.  ``beta`` in (0, 1) controls the decay
rate.  The top ``k * |V|`` nodes by centrality become *hubs* — the only nodes
SEP is allowed to replicate across partitions.

For the theoretical edge-cut bound (Thm.2) the paper substitutes plain degree
for centrality; ``degree_centrality`` provides that variant (it is also what
HDRF effectively uses).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "temporal_centrality",
    "degree_centrality",
    "top_k_hubs",
    "normalized_theta",
]


def temporal_centrality(
    src: np.ndarray,
    dst: np.ndarray,
    t: np.ndarray,
    num_nodes: int,
    *,
    beta: float = 0.5,
    normalize_time: bool = True,
) -> np.ndarray:
    """Exponential time-decay centrality (paper Eq.1).

    Args:
      src, dst: int arrays of shape (E,) — edge endpoints.
      t: float array of shape (E,) — edge timestamps (any monotone unit).
      num_nodes: |V|.
      beta: decay rate, scalar hyper-parameter in (0, 1).
      normalize_time: if True, timestamps are rescaled to [0, 1] before the
        decay so ``beta`` has a dataset-independent meaning.  The paper uses
        raw timestamps; rescaling is an order-preserving reparameterisation of
        ``beta`` and keeps ``exp`` in a sane numeric range for datasets whose
        clocks are in (milli)seconds.

    Returns:
      float64 array of shape (num_nodes,) — Cent(i) per node.
    """
    if len(t) == 0:
        return np.zeros(num_nodes, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    t_max = float(t.max())
    if normalize_time:
        t_min = float(t.min())
        span = max(t_max - t_min, 1e-12)
        w = np.exp(beta * (t - t_max) / span)
    else:
        w = np.exp(beta * (t - t_max))
    cent = np.zeros(num_nodes, dtype=np.float64)
    np.add.at(cent, np.asarray(src, dtype=np.int64), w)
    np.add.at(cent, np.asarray(dst, dtype=np.int64), w)
    return cent


def degree_centrality(
    src: np.ndarray, dst: np.ndarray, num_nodes: int
) -> np.ndarray:
    """Plain degree (multi-edge counted) — the Thm.2 / HDRF centrality."""
    cent = np.zeros(num_nodes, dtype=np.float64)
    np.add.at(cent, np.asarray(src, dtype=np.int64), 1.0)
    np.add.at(cent, np.asarray(dst, dtype=np.int64), 1.0)
    return cent


def top_k_hubs(centrality: np.ndarray, k: float) -> np.ndarray:
    """Boolean hub mask: the ``ceil(k * |V|)`` nodes with largest centrality.

    ``k`` is the paper's ``top_k`` hyper-parameter expressed as a *fraction*
    in [0, 1] (the paper's tables quote it in percent).  ``k == 0`` means no
    node may replicate; ``k == 1`` degenerates SEP to HDRF (paper §III-B).
    """
    n = centrality.shape[0]
    mask = np.zeros(n, dtype=bool)
    if k <= 0.0 or n == 0:
        return mask
    n_hubs = min(n, int(np.ceil(k * n)))
    if n_hubs >= n:
        mask[:] = True
        return mask
    # argpartition: indices of the n_hubs largest centralities.
    idx = np.argpartition(centrality, n - n_hubs)[n - n_hubs:]
    mask[idx] = True
    return mask


def normalized_theta(cent_i: float, cent_j: float) -> float:
    """theta(i) = Cent(i) / (Cent(i) + Cent(j)) = 1 - theta(j)   (Eq.2)."""
    denom = cent_i + cent_j
    if denom <= 0.0:
        return 0.5
    return cent_i / denom
