from repro.data.pipeline import LMDataConfig, packed_batches, synthetic_corpus

__all__ = ["LMDataConfig", "packed_batches", "synthetic_corpus"]
