"""LM token pipeline: synthetic corpus generation, packing, sharded batches.

Offline container -> no real corpora; the synthetic stream is a mixture of
Zipfian unigrams and repeated n-gram "phrases" (so models have learnable
structure and loss curves behave like language, not noise).  The pipeline
yields fixed-shape (B, S+1) packed sequences; the launcher shards them over
("pod","data").
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["LMDataConfig", "synthetic_corpus", "packed_batches"]


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_phrases: int = 512
    phrase_len: int = 8
    phrase_prob: float = 0.5
    zipf: float = 1.3


def synthetic_corpus(cfg: LMDataConfig) -> Iterator[np.ndarray]:
    """Infinite stream of token chunks (np.int32 arrays)."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab
    phrases = rng.integers(1, v, size=(cfg.n_phrases, cfg.phrase_len))
    # phrase popularity is zipfian too
    ranks = np.arange(1, cfg.n_phrases + 1, dtype=np.float64)
    probs = ranks ** -cfg.zipf
    probs /= probs.sum()
    while True:
        out = []
        n = 0
        target = cfg.seq_len * 4
        while n < target:
            if rng.uniform() < cfg.phrase_prob:
                pid = rng.choice(cfg.n_phrases, p=probs)
                out.append(phrases[pid])
                n += cfg.phrase_len
            else:
                k = int(rng.integers(2, 16))
                toks = (rng.zipf(cfg.zipf + 0.2, k) % (v - 1)) + 1
                out.append(toks)
                n += k
        yield np.concatenate(out).astype(np.int32)


def packed_batches(cfg: LMDataConfig) -> Iterator[dict]:
    """Pack the stream into (B, S) token/target batches (next-token LM)."""
    stream = synthetic_corpus(cfg)
    buf = np.zeros(0, dtype=np.int32)
    need = cfg.global_batch * (cfg.seq_len + 1)
    while True:
        while len(buf) < need:
            buf = np.concatenate([buf, next(stream)])
        chunk, buf = buf[:need], buf[need:]
        seqs = chunk.reshape(cfg.global_batch, cfg.seq_len + 1)
        yield {
            "tokens": seqs[:, :-1].copy(),
            "targets": seqs[:, 1:].copy(),
        }
