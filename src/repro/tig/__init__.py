"""TIG substrate: temporal-interaction-graph models + PAC training.

Modules:
  * ``graph``      — TemporalGraph container, chronological split.
  * ``data``       — synthetic paper-shaped datasets + JODIE csv loader.
  * ``sampler``    — host-side most-recent-K temporal neighbor index.
  * ``time_encode``— TGAT functional time encoding.
  * ``modules``    — MSG/UPD/attention building blocks (raw JAX).
  * ``models``     — Jodie/DyRep/TGN/TIGE as one general architecture.
  * ``batching``   — fixed-shape chronological batch construction.
  * ``stream``     — out-of-core shard format, chunked JODIE ingestion,
                     chunked device staging, epoch prefetcher.
  * ``protocol``   — the evaluation-protocol subsystem: chronological
                     splits as zero-copy stream views + the
                     replay-to-warm-memory val/test scoring driver shared
                     by every trainer.
  * ``train``      — single-device + out-of-core sharded trainers.
  * ``distributed``— PAC device half (vmap simulation / shard_map SPMD).
  * ``evaluation`` — AP / AUROC metrics (numpy).
"""

from repro.tig.graph import TemporalGraph, chronological_split
from repro.tig.models import TIGConfig
from repro.tig.protocol import ProtocolSplits, run_protocol, split_views
from repro.tig.stream import EpochPrefetcher, ShardedStream

__all__ = ["TemporalGraph", "chronological_split", "TIGConfig",
           "ShardedStream", "EpochPrefetcher",
           "ProtocolSplits", "run_protocol", "split_views"]
