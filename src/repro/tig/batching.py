"""Host-side batch construction for TIG training (fixed-shape, jit-ready).

Batches are built chronologically.  Temporal neighbors of (src, dst, neg)
come from the vectorized ``ChronoNeighborIndex`` built once per stream:
every batch samples neighbors *as of its own batch boundary*, so neighbors
strictly precede the batch and no future information leaks (paper
Challenge 1).  The whole plan — padding, negatives, neighbor gathers — is
pure numpy array work; there is no per-edge interpreter loop anywhere.

All ids in produced batches are LOCAL (device) ids; -1 marks padding.  The
edge-feature table handed to the device gets one extra zero row at index E
so -1 neighbor edge indices can be remapped on device.

``build_batch_program`` emits the batches pre-stacked as (steps, ...) arrays
— the layout ``repro.tig.engine``'s scanned epoch consumes directly.
``build_batches`` unstacks the same plan into a list of per-batch dicts for
callers that still step batch by batch.

With ``plan="device"`` the pre-sampled neighbor grids are omitted: the
staged grid shrinks to raw edge records (src, dst, t, feature row ids) and
the engine samples neighbors inside the scanned step from the stream's
device-resident T-CSR.  ``plan="host"`` (the default) stays the bit-parity
oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.tig.models import TIGConfig
from repro.tig.sampler import ChronoNeighborIndex, NeighborSnapshot

__all__ = ["LocalStream", "build_batch_program", "build_batches",
           "concat_batch_programs", "stack_batches", "unstack_batches",
           "make_tables"]


@dataclasses.dataclass
class LocalStream:
    """A device-local edge stream (already localized node ids).

    ``eidx`` indexes into the local edge-feature table (E_local rows).
    """

    src: np.ndarray
    dst: np.ndarray
    t: np.ndarray
    eidx: np.ndarray
    num_local_nodes: int
    labels: Optional[np.ndarray] = None

    @property
    def num_edges(self) -> int:
        return len(self.src)


def make_tables(edge_feat: np.ndarray, node_feat: np.ndarray) -> dict:
    """Device tables with trailing zero dump rows (for -1 remapping)."""
    e = np.concatenate([edge_feat,
                        np.zeros((1, edge_feat.shape[1]), edge_feat.dtype)])
    n = np.concatenate([node_feat,
                        np.zeros((1, node_feat.shape[1]), node_feat.dtype)])
    return {"efeat": e, "nfeat": n}


def _padded(x: np.ndarray, steps: int, b: int, fill) -> np.ndarray:
    """(E, ...) -> (steps, b, ...) chronological grid, tail ``fill``-padded."""
    out = np.full((steps * b,) + x.shape[1:], fill, dtype=x.dtype)
    out[: len(x)] = x
    return out.reshape((steps, b) + x.shape[1:])


def build_batch_program(
    stream: LocalStream,
    cfg: TIGConfig,
    rng: np.random.Generator,
    history: Optional[NeighborSnapshot] = None,
    neg_pool: Optional[np.ndarray] = None,
    index: Optional[ChronoNeighborIndex] = None,
    plan: str = "host",
) -> tuple[dict, NeighborSnapshot]:
    """Fully pre-staged epoch plan: a (steps, ...) batch pytree.

    Args:
      history: neighbor index state carried over from an earlier stream
        (e.g. train -> val continuation); defaults to an empty history.
      neg_pool: candidate local ids for negative sampling (defaults to the
        stream's destination nodes — the JODIE/TGN convention).
      index: pre-built neighbor index for this stream (e.g. the chunked
        out-of-core build, or one reused across epochs); mutually
        exclusive with ``history`` and validated against the stream/cfg
        shape.  Defaults to a fresh one-shot build.
      plan: ``"host"`` pre-samples the (steps, b, k) neighbor grids here
        (the bit-parity oracle); ``"device"`` ships only the raw edge
        records — the engine samples each batch's neighbors on device from
        the stream's exported T-CSR (``ChronoNeighborIndex.device_export``)
        via ``kernels.ops.neighbor_sample``.

    Returns ``(batches, final_history)`` where ``batches`` maps each
    ``models.step_loss`` key to a (steps, batch, ...) array and
    ``final_history`` is the neighbor index state after the whole stream.
    """
    if plan not in ("host", "device"):
        raise ValueError(f"plan={plan!r}: expected 'host' or 'device'")
    b, k = cfg.batch_size, cfg.num_neighbors
    if neg_pool is None or len(neg_pool) == 0:
        neg_pool = np.unique(stream.dst)
    n_edges = stream.num_edges
    steps = max(1, -(-n_edges // b))

    if index is None:
        index = ChronoNeighborIndex(
            stream.src, stream.dst, stream.t, stream.eidx,
            stream.num_local_nodes, k, b, history=history)
    else:
        if history is not None:
            raise ValueError("pass history to the index build, not both")
        if (index.num_nodes, index.k, index.batch_size) != \
                (stream.num_local_nodes, k, b):
            raise ValueError("index shape does not match stream/cfg")
        if index.num_batches != steps:
            # a different-length stream would alias into neighboring nodes'
            # (node, batch) key ranges and sample silently-wrong neighbors
            raise ValueError(
                f"index covers {index.num_batches} batches, stream has "
                f"{steps}")

    src = _padded(stream.src, steps, b, -1).astype(np.int32)
    dst = _padded(stream.dst, steps, b, -1).astype(np.int32)
    t = _padded(stream.t.astype(np.float32), steps, b, 0.0)
    eidx = _padded(stream.eidx, steps, b, -1).astype(np.int32)
    neg = rng.choice(neg_pool, size=(steps, b)).astype(np.int32)
    valid = _padded(np.ones(n_edges, dtype=bool), steps, b, False)

    batches = {"src": src, "dst": dst, "neg": neg,
               "t": t, "eidx": eidx, "valid": valid}
    if stream.labels is not None:
        batches["labels"] = _padded(stream.labels, steps, b, -1)

    if plan == "device":
        # raw edge records only: the scanned step samples neighbors from
        # the device-resident T-CSR at its own batch index
        return batches, index.final_snapshot()

    # neighbors as of each row's own batch boundary (strictly-before-batch)
    batch_of = np.broadcast_to(np.arange(steps)[:, None], (steps, b))
    n_l = cfg.n_layers
    for role, ids in (("src", src), ("dst", dst), ("neg", neg)):
        alive = (ids >= 0) & valid
        clean = np.where(alive, ids, 0)
        if n_l == 1:
            nb, nt, ne = index.sample(clean.ravel(), batch_of.ravel())
            nb = nb.reshape(steps, b, k)
            nt = nt.reshape(steps, b, k)
            ne = ne.reshape(steps, b, k)
            nb[~alive] = -1
            ne[~alive] = -1
        else:
            # (steps, L, b, k) grids — scan-layer l gets the (L-1-l)-th
            # most-recent K-window, matching the device sampler's layout
            # (engine.sample_batch_neighbors) row for row
            grids = [index.sample(clean.ravel(), batch_of.ravel(),
                                  window=w)
                     for w in range(n_l - 1, -1, -1)]
            nb = np.stack([g[0].reshape(steps, b, k) for g in grids], 1)
            nt = np.stack([g[1].reshape(steps, b, k) for g in grids], 1)
            ne = np.stack([g[2].reshape(steps, b, k) for g in grids], 1)
            dead = ~alive[:, None, :, None]
            nb = np.where(dead, -1, nb)
            ne = np.where(dead, -1, ne)
        batches[f"nbr_{role}"] = nb.astype(np.int32)
        batches[f"nbrt_{role}"] = nt.astype(np.float32)
        batches[f"nbre_{role}"] = ne.astype(np.int32)

    return batches, index.final_snapshot()


def concat_batch_programs(
    programs: list[dict],
) -> tuple[dict, np.ndarray]:
    """Concatenate per-device (steps_k, ...) batch pytrees into ONE flat
    grid plus per-device row offsets — the transfer-minimal PAC layout.

    Each device later reads its rows ``offset[k] + s % steps_k`` on device
    (engine ``wrap_steps`` gather), so the flat grid carries only real
    batches: ``sum_k steps_k`` rows instead of ``N_dev * lockstep_steps``.

    Returns ``(flat, offsets)`` with ``offsets`` int32 (N_dev,).
    """
    lengths = np.array([len(p["src"]) for p in programs], dtype=np.int64)
    offsets = np.concatenate(
        [[0], np.cumsum(lengths)[:-1]]).astype(np.int32)
    flat = {k: np.concatenate([p[k] for p in programs])
            for k in programs[0]}
    return flat, offsets


def pad_batch_programs(programs: list[dict], rows_cap: int) -> dict:
    """Stack per-device (rows_k, ...) batch pytrees into one zero-padded
    (N_held, rows_cap, ...) grid — the row-range-SHARDED PAC layout.

    Companion to ``concat_batch_programs``: instead of one flat replicated
    grid + offsets, every device owns its OWN leading row — shard_map can
    then partition the grid over the "part" axis so each host stages and
    transfers only its local devices' rows.  ``rows_cap`` is the global
    ``max_k n_batches_k`` (uniform blocks are a shard_map requirement);
    padding rows are zeros and are never gathered, because the device-side
    wrap reads row ``s % n_batches_k < rows_cap`` only.
    """
    out = {}
    for key in programs[0]:
        parts = []
        for p in programs:
            v = np.asarray(p[key])
            if len(v) > rows_cap:
                raise ValueError(
                    f"batch program has {len(v)} rows > rows_cap={rows_cap}")
            pad = [(0, rows_cap - len(v))] + [(0, 0)] * (v.ndim - 1)
            parts.append(np.pad(v, pad))
        out[key] = np.stack(parts)
    return out


def build_batches(
    stream: LocalStream,
    cfg: TIGConfig,
    rng: np.random.Generator,
    history: Optional[NeighborSnapshot] = None,
    neg_pool: Optional[np.ndarray] = None,
    *,
    return_history: bool = False,
):
    """Chronological fixed-shape batches with pre-sampled neighbors, as a
    list of per-batch numpy dicts matching ``models.step_loss``.

    With ``return_history=True`` also returns the post-stream
    ``NeighborSnapshot`` for continuing into a later stream.
    """
    stacked, final = build_batch_program(stream, cfg, rng, history, neg_pool)
    batches = unstack_batches(stacked)
    return (batches, final) if return_history else batches


def stack_batches(batches: list[dict]) -> dict:
    """Stack per-step batch dicts into (steps, ...) arrays for lax.scan."""
    keys = batches[0].keys()
    return {k: np.stack([b[k] for b in batches]) for k in keys}


def unstack_batches(stacked: dict) -> list[dict]:
    """Inverse of ``stack_batches``: (steps, ...) pytree -> list of dicts."""
    steps = len(next(iter(stacked.values())))
    return [{k: v[s] for k, v in stacked.items()} for s in range(steps)]
