"""Host-side batch construction for TIG training (fixed-shape, jit-ready).

Batches are built chronologically.  For every batch we first *sample* the
temporal neighbors of (src, dst, neg) from the ring-buffer index — neighbors
strictly precede the batch — and only then *update* the index with the
batch's edges, so no future information leaks (paper Challenge 1).

All ids in produced batches are LOCAL (device) ids; -1 marks padding.  The
edge-feature table handed to the device gets one extra zero row at index E
so -1 neighbor edge indices can be remapped on device.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.tig.models import TIGConfig
from repro.tig.sampler import RecentNeighborBuffer

__all__ = ["LocalStream", "build_batches", "stack_batches", "make_tables"]


@dataclasses.dataclass
class LocalStream:
    """A device-local edge stream (already localized node ids).

    ``eidx`` indexes into the local edge-feature table (E_local rows).
    """

    src: np.ndarray
    dst: np.ndarray
    t: np.ndarray
    eidx: np.ndarray
    num_local_nodes: int
    labels: Optional[np.ndarray] = None

    @property
    def num_edges(self) -> int:
        return len(self.src)


def make_tables(edge_feat: np.ndarray, node_feat: np.ndarray) -> dict:
    """Device tables with trailing zero dump rows (for -1 remapping)."""
    e = np.concatenate([edge_feat,
                        np.zeros((1, edge_feat.shape[1]), edge_feat.dtype)])
    n = np.concatenate([node_feat,
                        np.zeros((1, node_feat.shape[1]), node_feat.dtype)])
    return {"efeat": e, "nfeat": n}


def build_batches(
    stream: LocalStream,
    cfg: TIGConfig,
    rng: np.random.Generator,
    sampler: Optional[RecentNeighborBuffer] = None,
    neg_pool: Optional[np.ndarray] = None,
) -> list[dict]:
    """Chronological fixed-shape batches with pre-sampled neighbors.

    Args:
      sampler: ring-buffer index; mutated in place (pass a fresh one per
        epoch/evaluation continuation).  Defaults to a new empty buffer.
      neg_pool: candidate local ids for negative sampling (defaults to the
        stream's destination nodes — the JODIE/TGN convention).

    Returns a list of numpy batch dicts matching ``models.step_loss``.
    """
    b, k = cfg.batch_size, cfg.num_neighbors
    if sampler is None:
        sampler = RecentNeighborBuffer(stream.num_local_nodes, k)
    if neg_pool is None or len(neg_pool) == 0:
        neg_pool = np.unique(stream.dst)
    n_edges = stream.num_edges
    num_batches = max(1, -(-n_edges // b))
    batches = []
    for bi in range(num_batches):
        lo, hi = bi * b, min((bi + 1) * b, n_edges)
        size = hi - lo
        pad = b - size

        def padded(x, fill):
            out = np.full((b,) + x.shape[1:], fill, dtype=x.dtype)
            out[:size] = x[lo:hi]
            return out

        src = padded(stream.src, -1).astype(np.int32)
        dst = padded(stream.dst, -1).astype(np.int32)
        t = padded(stream.t.astype(np.float32), 0.0)
        eidx = padded(stream.eidx, -1)
        neg = rng.choice(neg_pool, size=b).astype(np.int32)
        valid = np.zeros(b, dtype=bool)
        valid[:size] = True

        batch = {
            "src": src, "dst": dst, "neg": neg,
            "t": t, "eidx": eidx.astype(np.int32), "valid": valid,
        }
        if stream.labels is not None:
            batch["labels"] = padded(stream.labels, -1)

        # neighbors BEFORE this batch touches the index
        for role, ids in (("src", src), ("dst", dst), ("neg", neg)):
            clean = np.where((ids >= 0) & valid, ids, 0)
            nb, nt, ne = sampler.sample(clean)
            dead = ~((ids >= 0) & valid)
            nb[dead] = -1
            ne[dead] = -1
            batch[f"nbr_{role}"] = nb.astype(np.int32)
            batch[f"nbrt_{role}"] = nt.astype(np.float32)
            batch[f"nbre_{role}"] = ne.astype(np.int32)

        sampler.update(stream.src[lo:hi], stream.dst[lo:hi],
                       stream.t[lo:hi], stream.eidx[lo:hi])
        batches.append(batch)
    return batches


def stack_batches(batches: list[dict]) -> dict:
    """Stack per-step batch dicts into (steps, ...) arrays for lax.scan."""
    keys = batches[0].keys()
    return {k: np.stack([b[k] for b in batches]) for k in keys}
