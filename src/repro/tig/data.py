"""TIG datasets: shape-faithful synthetic generators + JODIE-format loader.

The paper's seven datasets (Tab.II) are not redistributable offline, so we
provide generators that match their *shape*: bipartite interaction streams
(user -> item) with power-law degree distributions, bursty repeat behaviour,
optional dynamic labels (state-change indicators), and the paper's node/edge
ratios at a configurable scale.  ``load_jodie_csv`` ingests the standard
``ml_<name>.csv`` format so the real datasets drop in unchanged.

Presets mirror Tab.II at 1/50-ish scale (full-scale shapes are exercised by
the dry-run, not by CPU training):

    name          nodes   edges    d_e  labels     paper original
    wikipedia-s   1_000   15_000   172  yes        9_227 / 157_474
    reddit-s      1_100   67_000   172  yes        10_984 / 672_447
    mooc-s          720   41_000   172  yes        7_144 / 411_749
    lastfm-s        200  130_000   172  no         1_980 / 1_293_103
    ml25m-s       4_400  500_000   100  no         221_588 / 25_000_095
    dgraphfin-s  97_000   86_000   100  yes(4)     4_889_537 / 4_300_999
    taobao-s    103_000 2_000_000  100  yes        5_149_747 / 100_135_088
"""

from __future__ import annotations

import os
from typing import Optional

import numpy as np

from repro.tig.graph import TemporalGraph

__all__ = ["synthetic_tig", "load_jodie_csv", "PRESETS"]

PRESETS: dict[str, dict] = {
    # scale-reduced mirrors of paper Tab.II
    "wikipedia-s": dict(num_users=250, num_items=750, num_edges=15_000,
                        d_e=172, d_n=172, labeled=True, classes=2),
    "reddit-s": dict(num_users=300, num_items=800, num_edges=67_000,
                     d_e=172, d_n=172, labeled=True, classes=2),
    "mooc-s": dict(num_users=600, num_items=120, num_edges=41_000,
                   d_e=172, d_n=172, labeled=True, classes=2),
    "lastfm-s": dict(num_users=100, num_items=100, num_edges=130_000,
                     d_e=172, d_n=172, labeled=False, classes=0),
    "ml25m-s": dict(num_users=1_600, num_items=2_800, num_edges=500_000,
                    d_e=1, d_n=100, labeled=False, classes=0),
    "dgraphfin-s": dict(num_users=49_000, num_items=48_000, num_edges=86_000,
                        d_e=11, d_n=100, labeled=True, classes=4),
    "taobao-s": dict(num_users=52_000, num_items=51_000, num_edges=2_000_000,
                     d_e=4, d_n=100, labeled=True, classes=16),
    # tiny graphs for unit tests / quickstart
    "tiny": dict(num_users=40, num_items=60, num_edges=1_200,
                 d_e=16, d_n=16, labeled=True, classes=2),
    "small": dict(num_users=150, num_items=250, num_edges=6_000,
                  d_e=32, d_n=32, labeled=True, classes=2),
}


def _rewire_repeats_reference(
    users: np.ndarray, items: np.ndarray, repeat: np.ndarray
) -> np.ndarray:
    """Per-edge ``prev_item`` chain (the original O(E) interpreted loop;
    kept as the parity oracle of ``_rewire_repeats``)."""
    out = items.copy()
    prev_item: dict[int, int] = {}
    for e in range(len(users)):
        u = int(users[e])
        if repeat[e] and u in prev_item:
            out[e] = prev_item[u]
        prev_item[u] = out[e]
    return out


def _rewire_repeats(
    users: np.ndarray, items: np.ndarray, repeat: np.ndarray
) -> np.ndarray:
    """Vectorized repeat-rewire: each repeat edge takes the item of its
    user's most recent NON-repeat (anchor) edge.

    The sequential chain ``prev_item[u]`` always resolves to the item of
    the user's last anchor edge (first occurrence, or ``~repeat``): repeat
    edges copy the chain value and anchors reset it.  So a stable sort by
    user followed by a per-group forward-fill of anchor positions
    (``np.maximum.accumulate`` — safe across group boundaries because a
    group's first row is always an anchor) reproduces the loop
    bit-identically with no per-edge Python.
    """
    ne = len(users)
    if ne == 0:
        return items.copy()
    order = np.argsort(users, kind="stable")
    u_s = users[order]
    first = np.empty(ne, dtype=bool)
    first[0] = True
    first[1:] = u_s[1:] != u_s[:-1]
    anchor = first | ~repeat[order]
    fill = np.maximum.accumulate(
        np.where(anchor, np.arange(ne, dtype=np.int64), 0))
    out = np.empty_like(items)
    out[order] = items[order][fill]
    return out


def synthetic_tig(
    name: str = "tiny",
    *,
    seed: int = 0,
    scale: float = 1.0,
    zipf_users: float = 1.6,
    zipf_items: float = 1.4,
    repeat_prob: float = 0.6,
) -> TemporalGraph:
    """Generate a bipartite power-law temporal interaction stream.

    Behavioural model (matches the empirics TIG papers rely on):
      * user activity and item popularity are zipfian,
      * with probability ``repeat_prob`` a user re-interacts with one of its
        recent items (temporal locality -> the recency bias Eq.1 exploits),
      * timestamps arrive as a Poisson-ish process with daily burstiness,
      * dynamic labels flip rarely (state-change indicators, JODIE-style).
    """
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; options: {list(PRESETS)}")
    p = PRESETS[name]
    rng = np.random.default_rng(seed)
    nu = max(int(p["num_users"] * scale), 2)
    ni = max(int(p["num_items"] * scale), 2)
    ne = max(int(p["num_edges"] * scale), 10)
    n = nu + ni

    users = rng.zipf(zipf_users, ne) % nu
    items = rng.zipf(zipf_items, ne) % ni

    # temporal locality: rewire a fraction of interactions to the user's
    # previous item (generates the repeat-interaction bursts of real logs).
    repeat = rng.uniform(size=ne) < repeat_prob
    items = _rewire_repeats(users, items, repeat)

    src = users.astype(np.int64)
    dst = (nu + items).astype(np.int64)

    # bursty timestamps: piecewise-intensity Poisson over ~30 "days"
    day = rng.integers(0, 30, ne)
    within = rng.exponential(1.0, ne)
    t = np.sort(day * 86_400.0 + within.cumsum() / within.sum() * 86_400.0)

    edge_feat = rng.normal(0, 1, (ne, p["d_e"])).astype(np.float32)
    node_feat = np.zeros((n, p["d_n"]), dtype=np.float32)  # paper: zeros

    labels = None
    if p["labeled"]:
        # rare state changes of the source user
        labels = np.full(ne, 0, dtype=np.int64)
        flip = rng.uniform(size=ne) < 0.005 * p["classes"]
        labels[flip] = rng.integers(1, max(p["classes"], 2), flip.sum())

    return TemporalGraph(
        src=src, dst=dst, t=t,
        edge_feat=edge_feat, node_feat=node_feat,
        labels=labels, name=name,
    )


def load_jodie_csv(
    path: str,
    *,
    d_n: int = 172,
    name: Optional[str] = None,
) -> TemporalGraph:
    """Load the standard JODIE/TGN ``ml_<name>.csv`` interaction format:

        user_id, item_id, timestamp, state_label, feat_0, ..., feat_k

    Item ids are offset to live after user ids (bipartite convention).
    Parsing goes through the chunked block reader (``repro.tig.stream``),
    which tolerates integer timestamps, missing label columns, and
    ragged/header-only feature columns (short rows zero-padded to the
    sniffed width — never a silent ``(E, 0)`` feature slice).  For streams
    too large to materialize, use ``stream.write_jodie_shards`` instead.
    """
    from repro.tig.stream import iter_jodie_blocks

    cols: list[tuple] = list(iter_jodie_blocks(path))
    if not cols:
        raise ValueError(f"{path}: no data rows")
    users = np.concatenate([c[0] for c in cols])
    items = np.concatenate([c[1] for c in cols])
    t = np.concatenate([c[2] for c in cols])
    labels = np.concatenate([c[3] for c in cols])
    feats = np.concatenate([c[4] for c in cols])
    if feats.shape[1] == 0:
        feats = np.zeros((len(users), 1), dtype=np.float32)
    nu = int(users.max()) + 1
    ni = int(items.max()) + 1
    order = np.argsort(t, kind="stable")
    return TemporalGraph(
        src=users[order],
        dst=(nu + items)[order],
        t=t[order],
        edge_feat=feats[order],
        node_feat=np.zeros((nu + ni, d_n), dtype=np.float32),
        labels=labels[order],
        name=name or os.path.basename(path),
    )
