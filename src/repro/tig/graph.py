"""Temporal Interaction Graph container (paper §II-A).

G = (V, E) with E = {(i, j, t)} a chronologically-ordered interaction stream.
Node/edge features default to zero vectors for non-attributed graphs (paper
§II-A); dynamic node labels (state-change indicators) are optional and enable
the node-classification task (Wikipedia/Reddit/MOOC-style).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["TemporalGraph", "chronological_split"]


@dataclasses.dataclass
class TemporalGraph:
    """An edge stream with features.

    Attributes:
      src, dst: (E,) int64 node ids in [0, num_nodes).
      t: (E,) float64 timestamps, non-decreasing.
      edge_feat: (E, d_e) float32.
      node_feat: (num_nodes, d_n) float32.
      labels: optional (E,) int64 dynamic labels of the *source* node at the
        interaction time (the JODIE convention), -1 where unlabeled.
      name: dataset tag.
    """

    src: np.ndarray
    dst: np.ndarray
    t: np.ndarray
    edge_feat: np.ndarray
    node_feat: np.ndarray
    labels: Optional[np.ndarray] = None
    name: str = "tig"

    def __post_init__(self):
        e = len(self.src)
        assert len(self.dst) == e and len(self.t) == e
        assert self.edge_feat.shape[0] == e
        assert (np.diff(self.t) >= 0).all(), "edges must be chronological"

    @property
    def num_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def num_edges(self) -> int:
        return len(self.src)

    @property
    def dim_edge(self) -> int:
        return self.edge_feat.shape[1]

    @property
    def dim_node(self) -> int:
        return self.node_feat.shape[1]

    def slice_edges(self, idx: np.ndarray, name: Optional[str] = None
                    ) -> "TemporalGraph":
        """Sub-stream by edge indices (keeps global node id space)."""
        return TemporalGraph(
            src=self.src[idx],
            dst=self.dst[idx],
            t=self.t[idx],
            edge_feat=self.edge_feat[idx],
            node_feat=self.node_feat,
            labels=None if self.labels is None else self.labels[idx],
            name=name or self.name,
        )

    def stats(self) -> dict:
        return {
            "name": self.name,
            "num_nodes": self.num_nodes,
            "num_edges": self.num_edges,
            "d_n": self.dim_node,
            "d_e": self.dim_edge,
            "classes": (
                0 if self.labels is None
                else int(self.labels[self.labels >= 0].max()) + 1
                if (self.labels >= 0).any() else 0
            ),
        }


def chronological_split(
    g: TemporalGraph,
    train_frac: float = 0.70,
    val_frac: float = 0.15,
) -> tuple[TemporalGraph, TemporalGraph, TemporalGraph, np.ndarray]:
    """70/15/15 chronological edge split (paper §III-A, 'before implementing
    our SEP' — the partitioner only ever sees the training split).

    The boundary math and inductive-node discovery live in
    ``repro.tig.protocol`` (the single protocol layer — trainers use its
    zero-copy stream views); this wrapper materializes ``TemporalGraph``
    slices for callers that need actual sub-graphs, e.g. the partitioner
    input.

    Returns (train, val, test, inductive_nodes): ``inductive_nodes`` are
    nodes that never appear in training — the inductive link-prediction
    evaluation (paper Tab.IV) restricts to edges touching them.
    """
    from repro.tig.protocol import inductive_node_mask, split_bounds

    e = g.num_edges
    n_train, n_val = split_bounds(e, train_frac, val_frac)
    idx = np.arange(e)
    train = g.slice_edges(idx[:n_train], f"{g.name}/train")
    val = g.slice_edges(idx[n_train:n_val], f"{g.name}/val")
    test = g.slice_edges(idx[n_val:], f"{g.name}/test")
    inductive_nodes = np.nonzero(
        inductive_node_mask(g.src[:n_train], g.dst[:n_train],
                            g.num_nodes))[0]
    return train, val, test, inductive_nodes
