"""PAC — distributed parallel training of TIG models (paper §II-C, Alg.2).

The device half of the Parallel Acceleration Component.  One *device epoch*
is a single jitted program per device — the scanned step program of
``repro.tig.engine`` (shared with the single-device baseline) with DDP
gradient ``pmean`` over the "part" axis and Alg.2 cycle semantics
(``cycle_length``), followed here by the PAC-specific epilogue:

    scan over lockstep global steps s in [0, steps_per_epoch):
      1. if s is my cycle start:  reset node memory (Alg.2 line 6-7)
      2. batch = my_batches[s % my_num_batches]   (wrap-around loop)
      3. loss, grads = step_loss(batch)           (TIG model, models.py)
      4. grads = pmean(grads, axis="part")        (DDP gradient sync)
      5. params, opt_state = adamw(...)           (replicated update)
      6. if s is my cycle end:    backup memory   (Alg.2 line 10-11)
    epoch end:
      7. memory <- backup                         (restore complete state)
      8. shared-node sync: all_gather shared rows over "part", each device
         adopts the replica with the largest last-update timestamp
         ("latest", the paper's choice) or the mean.

The SAME function runs under two executors:
  * ``jax.vmap(..., axis_name="part")``  — single-host simulation (tests,
    CPU benchmarks; collectives become batched ops, semantics identical);
  * ``jax.shard_map(..., mesh)``         — real multi-device SPMD (the
    production path; also used by the dry-run on 512 host devices).

Host-side epoch planning (partition -> super-partitions -> localized padded
streams) lives here too, built on ``repro.core.pac``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.pac import (
    CycleSchedule,
    build_subgraph,
    cycle_schedule,
    make_local_indices,
    shuffle_combine,
)
from repro.core.sep import PartitionResult
from repro.optim import Optimizer
from repro.tig.batching import LocalStream, build_batch_program
from repro.tig.engine import scan_train_epoch
from repro.tig.graph import TemporalGraph
from repro.tig.models import TIGConfig, init_params, init_state
from repro.tig.protocol import time_scale_of
from repro.tig.stream import EpochPrefetcher
from repro.tig.train import epoch_rng

__all__ = ["EpochPlan", "plan_epoch", "make_pac_epoch", "pac_train",
           "PACResult"]


# ======================================================================
# host-side epoch planning
# ======================================================================

@dataclasses.dataclass
class EpochPlan:
    """Everything one epoch of PAC needs, stacked over the device axis."""

    batches: dict                 # pytree of (N_dev, steps, ...) arrays
    n_batches: np.ndarray         # (N_dev,) real batches per device
    nfeat_local: np.ndarray       # (N_dev, cap+1, d_n)
    efeat_local: np.ndarray       # (N_dev, e_cap+1, d_e) — per-device edge
                                  # features (§Perf C2: sharded, never the
                                  # full replicated table)
    shared_local: np.ndarray      # (N_dev, S) local rows of shared nodes
    node_lists: list[np.ndarray]  # global ids per device
    capacity: int                 # padded local node count
    edge_capacity: int            # padded local edge count
    steps: int
    edges_per_device: np.ndarray  # (N_dev,)


def plan_epoch(
    g: TemporalGraph,
    node_lists: list[np.ndarray],
    shared_nodes: np.ndarray,
    cfg: TIGConfig,
    rng: np.random.Generator,
    *,
    steps_override: Optional[int] = None,
    time_scale: Optional[float] = None,
) -> EpochPlan:
    """Localize each device's sub-graph and pre-build its padded batch
    stream (with wrap-around replay up to steps_per_epoch)."""
    n_dev = len(node_lists)
    time_scale = time_scale or time_scale_of(g.t)
    local = make_local_indices(node_lists, g.num_nodes)
    cap = local[0].capacity if local else 0

    streams: list[LocalStream] = []
    edges_per_device = np.zeros(n_dev, dtype=np.int64)
    edge_globals: list[np.ndarray] = []
    for k, (nodes, li) in enumerate(zip(node_lists, local)):
        eidx = build_subgraph(g.src, g.dst, nodes, g.num_nodes)
        edges_per_device[k] = len(eidx)
        edge_globals.append(eidx)
        streams.append(
            LocalStream(
                src=li.to_local[g.src[eidx]].astype(np.int64),
                dst=li.to_local[g.dst[eidx]].astype(np.int64),
                t=g.t[eidx] / time_scale,
                # LOCAL edge ids into the device's own feature table
                # (§Perf C2: the paper keeps edge data per GPU, so do we)
                eidx=np.arange(len(eidx), dtype=np.int64),
                num_local_nodes=cap,
                labels=None if g.labels is None else g.labels[eidx],
            )
        )

    sched = cycle_schedule(edges_per_device, cfg.batch_size)
    steps = steps_override or sched.steps_per_epoch

    per_dev_stacked = []
    for k, stream in enumerate(streams):
        real, _ = build_batch_program(stream, cfg, rng)
        # Alg.2 wrap-around: replay from the start; the neighbor index is
        # implicitly reset each cycle because replayed batches reuse the
        # first-cycle samples.
        replay = np.arange(steps) % len(real["src"])
        per_dev_stacked.append({k: v[replay] for k, v in real.items()})
    batches = {
        k: np.stack([d[k] for d in per_dev_stacked])
        for k in per_dev_stacked[0]
    }
    # labels are host-side only (classification head is trained post-hoc)
    batches.pop("labels", None)

    nfeat_local = np.zeros((n_dev, cap + 1, g.dim_node), np.float32)
    for k, li in enumerate(local):
        real_ids = li.globals_[: li.num_real]
        nfeat_local[k, : li.num_real] = g.node_feat[real_ids]

    e_cap = int(edges_per_device.max()) if n_dev else 0
    efeat_local = np.zeros((n_dev, e_cap + 1, g.dim_edge), np.float32)
    for k, eg in enumerate(edge_globals):
        efeat_local[k, : len(eg)] = g.edge_feat[eg]

    shared_local = np.zeros((n_dev, len(shared_nodes)), np.int32)
    for k, li in enumerate(local):
        rows = li.to_local[shared_nodes] if len(shared_nodes) else \
            np.zeros(0, np.int32)
        if len(shared_nodes) and (rows < 0).any():
            raise ValueError(
                "shared nodes must be present on every device "
                "(Alg.1 line 20 shared_to_all)")
        shared_local[k] = rows

    real_batches = np.maximum(1, -(-edges_per_device // cfg.batch_size))
    return EpochPlan(
        batches=batches,
        n_batches=np.minimum(real_batches, steps).astype(np.int32),
        nfeat_local=nfeat_local,
        efeat_local=efeat_local,
        shared_local=shared_local,
        node_lists=list(node_lists),
        capacity=cap,
        edge_capacity=e_cap,
        steps=steps,
        edges_per_device=edges_per_device,
    )


# ======================================================================
# the device-epoch program
# ======================================================================

def device_epoch(
    params,
    opt_state,
    batches,        # pytree of (steps, ...) — this device's stream
    n_batches,      # () int32 — real batches (cycle length)
    nfeat_local,    # (cap+1, d_n)
    efeat,          # (E+1, d_e) replicated
    shared_local,   # (S,) int32
    *,
    cfg: TIGConfig,
    opt: Optimizer,
    steps: int,
    capacity: int,
    sync_mode: Literal["latest", "mean"] = "latest",
    axis: str = "part",
):
    """One epoch on one device (runs under vmap or shard_map over ``axis``).

    The scan itself is the shared engine program (``engine.scan_train_epoch``
    with ``cycle_length`` = this device's real batch count and DDP gradient
    sync over ``axis``); the PAC-specific shared-node memory sync runs as
    the epilogue below.
    """
    del steps  # stream length is carried by the batches pytree itself
    tables = {"efeat": efeat, "nfeat": nfeat_local}
    fresh = init_state(cfg, capacity)

    params, opt_state, state, losses = scan_train_epoch(
        params, opt_state, fresh, batches, tables,
        cfg=cfg, opt=opt, axis=axis, cycle_length=n_batches)

    # shared-node memory synchronization (paper §II-C).
    # §Perf iteration C1: instead of all-gathering the full (N_dev, S, d)
    # replica rows (O(N*S*d) link bytes), gather only the (N_dev, S)
    # timestamps, compute the argmax winner, and combine rows with a
    # winner-masked psum — O(N*S + S*d) bytes, ~d-fold less traffic.
    if shared_local.shape[0] > 0:
        rows_m = state["mem"][shared_local]          # (S, d)
        rows_m2 = state["mem2"][shared_local]
        rows_t = state["last"][shared_local]         # (S,)
        if sync_mode == "latest":
            all_t = jax.lax.all_gather(rows_t, axis)     # (N_dev, S)
            win = jnp.argmax(all_t, axis=0)              # (S,)
            me = jax.lax.axis_index(axis)
            mine = (win == me)[:, None].astype(rows_m.dtype)
            new_m = jax.lax.psum(rows_m * mine, axis)
            new_m2 = jax.lax.psum(rows_m2 * mine, axis)
            new_t = jnp.max(all_t, axis=0)
        else:
            n = jax.lax.psum(1, axis)
            new_m = jax.lax.psum(rows_m, axis) / n
            new_m2 = jax.lax.psum(rows_m2, axis) / n
            new_t = jax.lax.psum(rows_t, axis) / n
        state = {
            **state,
            "mem": state["mem"].at[shared_local].set(new_m),
            "mem2": state["mem2"].at[shared_local].set(new_m2),
            "last": state["last"].at[shared_local].set(new_t),
        }

    return params, opt_state, state, losses


def make_pac_epoch(
    cfg: TIGConfig,
    opt: Optimizer,
    steps: int,
    capacity: int,
    *,
    mesh: Optional[Mesh] = None,
    sync_mode: Literal["latest", "mean"] = "latest",
):
    """Build the jitted epoch executor.

    mesh=None  -> vmap simulation over the leading device axis (single host
                  device; used by CPU tests/benchmarks).
    mesh given -> shard_map over mesh axis "part" (real SPMD; the dry-run
                  compiles this exact program for the production mesh).
    """
    kernel = functools.partial(
        device_epoch, cfg=cfg, opt=opt, steps=steps, capacity=capacity,
        sync_mode=sync_mode,
    )

    if mesh is None:
        vmapped = jax.vmap(
            kernel,
            in_axes=(None, None, 0, 0, 0, 0, 0),
            out_axes=(0, 0, 0, 0),
            axis_name="part",
        )

        @jax.jit
        def run(params, opt_state, batches, n_batches, nfeat_local, efeat,
                shared_local):
            p, o, state, losses = vmapped(
                params, opt_state, batches, n_batches, nfeat_local, efeat,
                shared_local)
            # params/opt_state identical across devices (pmean'd grads)
            p0 = jax.tree.map(lambda x: x[0], p)
            o0 = jax.tree.map(lambda x: x[0], o)
            return p0, o0, state, losses

        return run

    part = P("part")
    rep = P()

    def body(params, opt_state, batches, n_batches, nfeat_local, efeat,
             shared_local):
        squeeze = lambda t: jax.tree.map(lambda x: x[0], t)
        p, o, state, losses = kernel(
            params, opt_state, squeeze(batches), squeeze(n_batches),
            squeeze(nfeat_local), squeeze(efeat), squeeze(shared_local))
        expand = lambda t: jax.tree.map(lambda x: x[None], t)
        return p, o, expand(state), expand(losses)

    smapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(rep, rep, part, part, part, part, part),
        out_specs=(rep, rep, part, part),
    )
    return jax.jit(smapped)


# ======================================================================
# full training driver
# ======================================================================

@dataclasses.dataclass
class PACResult:
    params: dict
    memory_states: dict           # stacked (N_dev, ...) post-sync states
    losses: list                  # per epoch: (N_dev, steps_e) arrays
    derived_speedup: float
    edges_per_device: np.ndarray
    plan: EpochPlan
    metrics: Optional[dict] = None   # run_protocol output (eval_graph given)

    def mean_loss_per_epoch(self) -> np.ndarray:
        return np.array([float(l.mean()) for l in self.losses])


def pac_train(
    g_train: TemporalGraph,
    partition: PartitionResult,
    cfg: TIGConfig,
    *,
    num_devices: int,
    epochs: int = 3,
    lr: float = 1e-3,
    seed: int = 0,
    shuffle_parts: bool = True,
    sync_mode: Literal["latest", "mean"] = "latest",
    mesh: Optional[Mesh] = None,
    prefetch: bool = True,
    eval_graph: Optional[TemporalGraph] = None,
    eval_node_class: bool = False,
) -> PACResult:
    """Train a TIG model with SEP partitions + PAC (the paper's pipeline).

    ``partition`` may have more parts than devices (|P| > N): parts are then
    shuffle-combined into N super-partitions before every epoch (Fig.7).

    With ``prefetch`` (the default) cycle e+1's host planning — shuffle-
    combine, localization, batch grids — and its host->device transfer run
    on a worker thread while cycle e's scan executes; per-epoch RNG streams
    keep results bit-identical to serial planning.

    ``eval_graph`` (the FULL chronological stream, of which ``g_train`` is
    the train split) routes the trained parameters through the shared
    evaluation-protocol driver (``protocol.run_protocol`` — the same code
    path as ``train_single`` / ``train_sharded(protocol=True)``) and
    attaches the resulting val/test metrics to ``PACResult.metrics``.
    """
    from repro.optim import adamw

    small_parts = partition.node_lists()
    time_scale = time_scale_of(g_train.t)

    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)

    def build(ep: int) -> EpochPlan:
        rng_ep = epoch_rng(seed, ep, 11)
        if shuffle_parts and len(small_parts) > num_devices:
            node_lists = shuffle_combine(small_parts, num_devices, rng_ep)
        elif len(small_parts) == num_devices:
            node_lists = small_parts
        else:
            node_lists = shuffle_combine(
                small_parts, num_devices, np.random.default_rng(seed))
        return plan_epoch(g_train, node_lists, partition.shared_nodes,
                          cfg, rng_ep, time_scale=time_scale)

    def to_device(plan: EpochPlan):
        return plan, (
            {k: jnp.asarray(v) for k, v in plan.batches.items()},
            jnp.asarray(plan.n_batches),
            jnp.asarray(plan.nfeat_local),
            jnp.asarray(plan.efeat_local),
            jnp.asarray(plan.shared_local),
        )

    pf = EpochPrefetcher(build, epochs, to_device=to_device,
                         enabled=prefetch)
    all_losses = []
    epoch_fn = None
    last_plan = None
    compiled_key = None
    for ep in range(epochs):
        plan, dev = pf.get(ep)
        key = (plan.steps, plan.capacity, plan.edge_capacity)
        if epoch_fn is None or key != compiled_key:
            epoch_fn = make_pac_epoch(
                cfg, opt, plan.steps, plan.capacity, mesh=mesh,
                sync_mode=sync_mode)
            compiled_key = key
        params, opt_state, states, losses = epoch_fn(
            params, opt_state, *dev)
        all_losses.append(np.asarray(losses))
        last_plan = plan

    from repro.core.pac import derived_speedup as dsp

    metrics = None
    if eval_graph is not None:
        from repro.tig.train import evaluate_params

        metrics = evaluate_params(eval_graph, cfg, params, seed=seed,
                                  eval_node_class=eval_node_class)

    return PACResult(
        params=params,
        memory_states=jax.tree.map(np.asarray, states),
        losses=all_losses,
        derived_speedup=dsp(last_plan.edges_per_device),
        edges_per_device=last_plan.edges_per_device,
        plan=last_plan,
        metrics=metrics,
    )
