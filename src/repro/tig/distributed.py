"""PAC — distributed parallel training of TIG models (paper §II-C, Alg.2).

The device half of the Parallel Acceleration Component.  One *device epoch*
is a single jitted program per device — the scanned step program of
``repro.tig.engine`` (shared with the single-device baseline) with DDP
gradient ``pmean`` over the "part" axis and Alg.2 cycle semantics
(``cycle_length``), followed here by the PAC-specific epilogue:

    scan over lockstep global steps s in [0, steps_per_epoch):
      1. if s is my cycle start:  reset node memory (Alg.2 line 6-7)
      2. batch = my_batches[s % my_num_batches]   (wrap-around loop)
      3. loss, grads = step_loss(batch)           (TIG model, models.py)
      4. grads = pmean(grads, axis="part")        (DDP gradient sync)
      5. params, opt_state = adamw(...)           (replicated update)
      6. if s is my cycle end:    backup memory   (Alg.2 line 10-11)
    epoch end:
      7. memory <- backup                         (restore complete state)
      8. shared-node sync: all_gather shared rows over "part", each device
         adopts the replica with the largest last-update timestamp
         ("latest", the paper's choice) or the mean.

The SAME function runs under two executors:
  * ``jax.vmap(..., axis_name="part")``  — single-host simulation (tests,
    CPU benchmarks; collectives become batched ops, semantics identical);
  * ``jax.shard_map(..., mesh)``         — real multi-device SPMD (the
    production path; also used by the dry-run on 512 host devices).

Host-side epoch planning (partition -> super-partitions -> localized padded
streams) lives here too, built on ``repro.core.pac``.

§Perf C3 — transfer-minimal batch plane.  The Alg.2 wrap-around (step 2
above) runs ON DEVICE: ``plan_epoch`` emits each device's *real* batch grid
only, concatenated into one flat pytree plus per-device row offsets, and
the scanned epoch gathers batch ``offset + s % n_batches`` with
``lax.dynamic_index_in_dim``.  The previous host-side scheme — replaying
every grid to the global lockstep length with ``v[replay]`` — shipped
``N_dev * steps_per_epoch`` batch rows per epoch; the flat plan ships
``sum_k real_batches_k``, an ``N*steps/sum(real)``-fold reduction in host
grid bytes and host->device traffic that grows with partition imbalance.
The replay layout is kept as the bit-exact parity oracle
(``host_replay=True``).  ``plan_epoch`` also localizes directly from
``ShardedStream`` row-range chunks (one shard of ids+features in host
memory at a time), so ``pac_train`` runs end-to-end without a materialized
``TemporalGraph``.

§Perf C4 — pod-scale row-range sharding.  ``layout="sharded"`` re-cuts the
same plan by per-device row ranges: the grid becomes a zero-padded
(N_dev, rows_cap, ...) stack and the T-CSR export stays per-device
(unoffset ``indptr`` + padded per-device event rows), so ``make_pac_epoch``
can PARTITION both over the mesh's "part" axis instead of replicating
them — per-device H2D drops from O(sum all devices) to O(own rows).  On a
process-spanning mesh (``launch.mesh.make_tig_mesh`` over
``jax.process_count() * local_device_count`` devices) ``pac_train`` plans
only the local devices' rows per host (``local_ranks``) and stages them
with ``make_array_from_process_local_data`` (``stream.stage_partitioned``),
so HOST grid bytes also stay O(local devices); the Alg.2 shared-node
memory sync (all_gather/psum over "part") then genuinely spans hosts.
The replicated flat layout remains the single-host bit-parity oracle
(``grid_layout="replicated"``).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Literal, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.pac import (
    CycleSchedule,
    build_subgraph,
    cycle_schedule,
    make_local_indices,
    shuffle_combine,
    subgraph_mask,
)
from repro.core.sep import PartitionResult
from repro.optim import Optimizer
from repro.tig.batching import (
    LocalStream,
    build_batch_program,
    concat_batch_programs,
    pad_batch_programs,
)
from repro.tig.cache import lru_get
from repro.tig.engine import donate_args as _donate, scan_train_epoch
from repro.tig.graph import TemporalGraph
from repro.tig.models import TIGConfig, init_params, init_state
from repro.tig.protocol import time_scale_of
from repro.tig.sampler import ChronoNeighborIndex
from repro.tig.stream import (
    EpochPrefetcher,
    ShardedStream,
    stage_partitioned,
    stage_replicated,
)
from repro.faults import FaultInjector, HostLossError, is_host_loss
from repro.tig.train import epoch_rng

__all__ = ["EpochPlan", "plan_epoch", "make_pac_epoch", "make_pac_sync",
           "sync_shared_memory", "pac_train", "PACResult",
           "globalize_memory"]

StreamSource = Union[TemporalGraph, ShardedStream]


# ======================================================================
# host-side epoch planning
# ======================================================================

@dataclasses.dataclass
class EpochPlan:
    """Everything one epoch of PAC needs.

    Default (transfer-minimal) layout: ``batches`` is a FLAT pytree of
    (sum_k n_batches_k, ...) arrays — each device's real batch grid only,
    concatenated — and ``offsets`` holds each device's start row; the
    device epoch gathers batch ``offsets[k] + s % n_batches[k]`` on device
    (Alg.2 wrap-around without host replay).  With ``host_replay=True``
    (the parity oracle) ``batches`` is the legacy (N_dev, steps, ...)
    stack, replayed to the lockstep length on the host, and ``offsets`` is
    ``None``.

    ``layout="sharded"`` (pod scale) re-cuts the flat grid by per-device
    row ranges: ``batches`` is a zero-padded (N_held, rows_cap, ...) stack
    (rows_cap = global max n_batches_k, a shard_map uniform-block
    requirement), ``offsets`` is all-zero, and a device plan's ``tcsr``
    keeps per-device UNOFFSET ``indptr`` rows plus per-device padded event
    rows — every array mappable over the "part" axis.  With
    ``local_ranks`` only those devices' rows are materialized (N_held =
    len(local_ranks)); the scalar schedule (``n_batches``, ``offsets``,
    ``steps``, capacities) stays GLOBAL so every process plans the same
    lockstep epoch.
    """

    batches: dict                 # flat (sum real, ...) / (N_dev, steps, ...)
                                  # / sharded (N_held, rows_cap, ...)
    n_batches: np.ndarray         # (N_dev,) real batches per device
    nfeat_local: np.ndarray       # (N_held, cap+1, d_n)
    efeat_local: np.ndarray       # (N_held, e_cap+1, d_e) — per-device edge
                                  # features (§Perf C2: sharded, never the
                                  # full replicated table)
    shared_local: np.ndarray      # (N_dev, S) local rows of shared nodes
    node_lists: list[np.ndarray]  # global ids per device
    capacity: int                 # padded local node count
    edge_capacity: int            # padded local edge count
    steps: int
    edges_per_device: np.ndarray  # (N_dev,)
    offsets: Optional[np.ndarray] = None   # (N_dev,) flat-grid start rows
    host_replay: bool = False
    tcsr: Optional[dict] = None   # device plan: {"indptr": (N_dev, cap+1),
                                  # "nbr"/"t"/"eidx"/"bat": flat events} —
                                  # or all (N_held, ...) when sharded
    layout: str = "replicated"    # "replicated" | "sharded"
    local_ranks: Optional[np.ndarray] = None  # devices materialized here

    def grid_bytes(self) -> int:
        """Host bytes of the batch grids (what the epoch must transfer)."""
        return int(sum(np.asarray(v).nbytes for v in self.batches.values()))

    def tcsr_bytes(self) -> int:
        """Host bytes of the exported T-CSR (0 for host-sampled plans)."""
        if self.tcsr is None:
            return 0
        return int(sum(np.asarray(v).nbytes for v in self.tcsr.values()))

    def plan_bytes(self) -> int:
        """Total host->device plan bytes: batch grids + (device plan only)
        the T-CSR the sampler reads instead of pre-sampled grids."""
        return self.grid_bytes() + self.tcsr_bytes()

    def device_input_bytes(self) -> int:
        """Grid + T-CSR bytes ONE device receives over H2D.

        Replicated layouts ship the full flat grid (and flat event
        buffer) to every device; the sharded and host-replay layouts map
        the leading axis over devices, so each device receives only its
        own (uniform, padded) row."""
        if self.layout == "sharded" or self.host_replay:
            held = len(np.asarray(next(iter(self.batches.values()))))
            return self.plan_bytes() // max(held, 1)
        return self.plan_bytes()


def _localize_in_memory(
    g: TemporalGraph,
    node_lists: list[np.ndarray],
    local,
    cap: int,
    time_scale: float,
    ranks: list[int],
):
    """Per-device localized streams + feature gathers from a materialized
    ``TemporalGraph`` (the original in-memory path).

    ``ranks`` selects which devices' streams/features to MATERIALIZE (a
    host in a multi-process run builds only its own devices' rows; edge
    COUNTS stay global so the lockstep schedule agrees everywhere).
    Streams/indexes are ``None`` for unmaterialized devices; feature rows
    hold ``len(ranks)`` entries in rank order."""
    n_dev = len(node_lists)
    held = set(ranks)
    streams: list[Optional[LocalStream]] = []
    indexes: list[Optional[ChronoNeighborIndex]] = []
    edges_per_device = np.zeros(n_dev, dtype=np.int64)
    edge_globals: dict[int, np.ndarray] = {}
    for k, (nodes, li) in enumerate(zip(node_lists, local)):
        eidx = build_subgraph(g.src, g.dst, nodes, g.num_nodes)
        edges_per_device[k] = len(eidx)
        if k not in held:
            streams.append(None)
            indexes.append(None)
            continue
        edge_globals[k] = eidx
        streams.append(
            LocalStream(
                src=li.to_local[g.src[eidx]].astype(np.int64),
                dst=li.to_local[g.dst[eidx]].astype(np.int64),
                t=g.t[eidx] / time_scale,
                # LOCAL edge ids into the device's own feature table
                # (§Perf C2: the paper keeps edge data per GPU, so do we)
                eidx=np.arange(len(eidx), dtype=np.int64),
                num_local_nodes=cap,
                labels=None if g.labels is None else g.labels[eidx],
            )
        )
        indexes.append(None)   # build_batch_program's one-shot build

    nfeat_local = np.zeros((len(ranks), cap + 1, g.dim_node), np.float32)
    for row, k in enumerate(ranks):
        li = local[k]
        real_ids = li.globals_[: li.num_real]
        nfeat_local[row, : li.num_real] = g.node_feat[real_ids]

    e_cap = int(edges_per_device.max()) if n_dev else 0
    efeat_local = np.zeros((len(ranks), e_cap + 1, g.dim_edge), np.float32)
    for row, k in enumerate(ranks):
        eg = edge_globals[k]
        efeat_local[row, : len(eg)] = g.edge_feat[eg]
    return streams, indexes, edges_per_device, nfeat_local, efeat_local


def _localize_sharded(
    shards: ShardedStream,
    node_lists: list[np.ndarray],
    local,
    cap: int,
    cfg: TIGConfig,
    time_scale: float,
    ranks: list[int],
):
    """Per-device localized streams + feature gathers straight from
    ``tig-shards-v1`` row-range chunks — the graph is never materialized.

    One chunked pass over ``edge_chunks(features=True)`` classifies each
    shard's edges against every device's membership (vectorized
    ``subgraph_mask``), localizes ids, and gathers that shard's feature
    rows; host memory holds one shard of ids+features plus the per-device
    localized streams (O(E_k) ids + O(E_k) feature rows — the working set
    the device needs anyway, never the global table).  The per-device
    temporal neighbor index is built with the chunked two-pass T-CSR
    (``ChronoNeighborIndex.from_chunks``) over the same localized pieces —
    arrays identical to the one-shot build on the concatenated stream.

    ``ranks`` as in ``_localize_in_memory``: per-device streams, features
    and indexes materialize only for those devices (the chunk pass still
    CLASSIFIES every device's edges — the counts drive the global
    schedule — but unmaterialized devices never accumulate id/feature
    pieces, keeping the host working set O(local devices)).
    """
    n_dev = len(node_lists)
    held = set(ranks)
    members = [li.to_local >= 0 for li in local]
    pieces: list[list[tuple]] = [[] for _ in range(n_dev)]
    feat_parts: list[list[np.ndarray]] = [[] for _ in range(n_dev)]
    cursors = np.zeros(n_dev, dtype=np.int64)

    for src, dst, t, _eidx, efeat in shards.edge_chunks(features=True):
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        for k, li in enumerate(local):
            keep = subgraph_mask(members[k], src, dst)
            m = int(keep.sum())
            if m == 0:
                continue
            # LOCAL edge ids into the device's own feature table: rows are
            # appended in stream order, so ids are the running cursor
            eidx_local = np.arange(cursors[k], cursors[k] + m,
                                   dtype=np.int64)
            cursors[k] += m
            if k not in held:
                continue
            pieces[k].append((
                li.to_local[src[keep]].astype(np.int64),
                li.to_local[dst[keep]].astype(np.int64),
                np.asarray(t, np.float64)[keep] / time_scale,
                eidx_local,
            ))
            feat_parts[k].append(efeat[keep])

    streams: list[Optional[LocalStream]] = [None] * n_dev
    indexes: list[Optional[ChronoNeighborIndex]] = [None] * n_dev
    edges_per_device = cursors.copy()
    e_cap = int(edges_per_device.max()) if n_dev else 0
    efeat_local = np.zeros((len(ranks), e_cap + 1, shards.dim_edge),
                           np.float32)
    for row, k in enumerate(ranks):
        chunks = pieces[k]
        cat = lambda i: (  # noqa: E731
            np.concatenate([c[i] for c in chunks]) if chunks
            else np.zeros(0, np.int64 if i != 2 else np.float64))
        streams[k] = LocalStream(
            src=cat(0), dst=cat(1), t=cat(2), eidx=cat(3),
            num_local_nodes=cap, labels=None,
        )
        # an edge-less device degenerates to one padding batch whose index
        # the one-shot build handles (from_chunks would report 0 batches)
        indexes[k] = (ChronoNeighborIndex.from_chunks(
            chunks, cap, cfg.num_neighbors, cfg.batch_size)
            if chunks else None)
        if feat_parts[k]:
            efeat_local[row, : edges_per_device[k]] = \
                np.concatenate(feat_parts[k])
        # release this device's chunk pieces eagerly: the concatenated
        # stream + T-CSR index own fresh arrays, keeping the originals
        # alive would double the id-column working set
        feat_parts[k] = []
        pieces[k] = []

    nfeat_local = np.zeros((len(ranks), cap + 1, shards.dim_node),
                           np.float32)
    nfeat = shards.node_feat()          # memory-mapped (or zeros)
    for row, k in enumerate(ranks):
        li = local[k]
        real_ids = li.globals_[: li.num_real]
        nfeat_local[row, : li.num_real] = np.asarray(nfeat[real_ids],
                                                     np.float32)
    return streams, indexes, edges_per_device, nfeat_local, efeat_local


def plan_epoch(
    source: StreamSource,
    node_lists: list[np.ndarray],
    shared_nodes: np.ndarray,
    cfg: TIGConfig,
    rng: np.random.Generator,
    *,
    steps_override: Optional[int] = None,
    time_scale: Optional[float] = None,
    host_replay: bool = False,
    plan: str = "host",
    layout: str = "replicated",
    local_ranks=None,
) -> EpochPlan:
    """Localize each device's sub-graph and pre-build its batch stream.

    ``source`` is an in-memory ``TemporalGraph`` or an out-of-core
    ``ShardedStream`` (row-range localization, the graph never
    materializes).  By default the plan is transfer-minimal: only real
    batches are emitted (flat grid + per-device offsets; Alg.2 wrap-around
    happens on device).  ``host_replay=True`` reproduces the legacy
    host-side replay up to ``steps_per_epoch`` — kept as the bit-exact
    parity oracle.

    ``plan="device"`` additionally drops the pre-sampled neighbor grids:
    each device ships only its localized RAW edge stream and the scanned
    step samples neighbors on device from a per-device T-CSR.  The
    per-device ``device_export``s compose into ONE flat event buffer
    (each device's ``indptr`` offset by the preceding devices' lengths),
    so ``EpochPlan.tcsr`` carries a mapped (N_dev, cap+1) ``indptr``
    plus unmapped flat ``nbr`` / ``t`` / ``eidx`` / ``bat`` arrays — no
    per-device padding to the largest partition.  ``plan="host"`` (the
    default) is the bit-parity oracle; ``host_replay`` implies it.

    ``layout="sharded"`` (pod scale) cuts the same plan by per-device row
    ranges instead: the grid is a zero-padded (N_held, rows_cap, ...)
    stack, the T-CSR stays per-device (unoffset ``indptr``, events padded
    to the largest export) — both mappable over "part" so each device
    transfers only its own rows.  ``local_ranks`` (sharded only) limits
    materialization to this process's devices: batch programs, features
    and T-CSRs are built for those ranks only, while edge counts and the
    per-device RNG seeds are drawn for ALL ranks so every process derives
    the identical global schedule.  Batch-program negatives draw from
    per-device child seeds (split upfront from ``rng``) — device k's
    stream is reproducible no matter which subset of devices a host
    plans.
    """
    if plan not in ("host", "device"):
        raise ValueError(f"plan={plan!r}: expected 'host' or 'device'")
    if host_replay and plan == "device":
        raise ValueError(
            "host_replay is the host-planned parity oracle; it cannot be "
            "combined with plan='device'")
    if layout not in ("replicated", "sharded"):
        raise ValueError(
            f"layout={layout!r}: expected 'replicated' or 'sharded'")
    if host_replay and layout == "sharded":
        raise ValueError(
            "host_replay IS the legacy replicated-schedule oracle; use "
            "layout='sharded' without it")
    n_dev = len(node_lists)
    if local_ranks is not None:
        if layout != "sharded":
            raise ValueError(
                "local_ranks requires layout='sharded' (the replicated "
                "flat grid needs every device's rows)")
        ranks = [int(r) for r in np.asarray(local_ranks).ravel()]
        if ranks != sorted(set(ranks)) or not ranks \
                or ranks[0] < 0 or ranks[-1] >= n_dev:
            raise ValueError(f"local_ranks={ranks}: expected sorted unique "
                             f"ranks within [0, {n_dev})")
    else:
        ranks = list(range(n_dev))
    local = make_local_indices(node_lists, source.num_nodes)
    cap = local[0].capacity if local else 0

    # one child seed per device, split upfront: device k's batch stream
    # (negative draws) is a pure function of (rng, k), independent of
    # which devices this process materializes
    seeds = rng.integers(0, 2**63, size=n_dev) if n_dev else []

    if isinstance(source, ShardedStream):
        if time_scale is None:
            # one 8-byte/edge column pass — the same cost every consumer
            # of a sharded stream already pays (protocol.split_views)
            time_scale = time_scale_of(source.column("t"))
        streams, indexes, edges_per_device, nfeat_local, efeat_local = \
            _localize_sharded(source, node_lists, local, cap, cfg,
                              time_scale, ranks)
    else:
        time_scale = time_scale or time_scale_of(source.t)
        streams, indexes, edges_per_device, nfeat_local, efeat_local = \
            _localize_in_memory(source, node_lists, local, cap, time_scale,
                                ranks)

    sched = cycle_schedule(edges_per_device, cfg.batch_size)
    steps = steps_override or sched.steps_per_epoch

    programs = []                  # aligned with ranks
    exports: list[dict] = []       # aligned with ranks (device plan)
    for k in ranks:
        stream = streams[k]
        idx = indexes[k]
        if plan == "device" and idx is None:
            # the host path defers to build_batch_program's one-shot build;
            # the device plan needs the index itself to export its T-CSR
            # (an edge-less stream yields the empty index: all -1 samples)
            idx = ChronoNeighborIndex(
                stream.src, stream.dst, stream.t, stream.eidx,
                cap, cfg.num_neighbors, cfg.batch_size)
        if plan == "device":
            exports.append(idx.device_export(depth=cfg.n_layers))
        real, _ = build_batch_program(
            stream, cfg, np.random.default_rng(int(seeds[k])),
            # an empty stream pads to one batch, which the zero-batch
            # index would fail shape validation against
            index=idx if (idx is not None and stream.num_edges) else None,
            plan=plan)
        # labels are host-side only (classification head trained post-hoc)
        real.pop("labels", None)
        programs.append(real)

    # real batch counts are GLOBAL (the lockstep schedule): recover the
    # unmaterialized devices' counts from the cycle schedule and check the
    # built programs agree with it
    real_batches = np.asarray(sched.batches, dtype=np.int64)
    for row, k in enumerate(ranks):
        assert len(programs[row]["src"]) == real_batches[k], \
            (k, len(programs[row]["src"]), real_batches[k])
    n_batches = np.minimum(real_batches, steps).astype(np.int32)

    tcsr = None
    if plan == "device":
        if layout == "sharded":
            # per-device rows, UNOFFSET indptr: each device addresses its
            # own event segment, padded to the largest export so shard_map
            # can map the leading axis (pad rows are never addressed —
            # indptr bounds stay within the real segment)
            # GLOBAL event cap, derivable from edge counts alone (export
            # length = 2 endpoint events per edge + K*depth front pad), so
            # a host planning only its own ranks pads identically
            ev_cap = int((2 * edges_per_device
                          + cfg.num_neighbors * cfg.n_layers).max())
            for k, e in zip(ranks, exports):
                assert len(e["nbr"]) == 2 * edges_per_device[k] + \
                    cfg.num_neighbors * cfg.n_layers, (k, len(e["nbr"]))
            pad = lambda v: np.pad(v, (0, ev_cap - len(v)))  # noqa: E731
            tcsr = {
                "indptr": np.stack([e["indptr"] for e in exports]),
                **{key: np.stack([pad(e[key]) for e in exports])
                   for key in ("nbr", "t", "eidx", "bat")},
            }
        else:
            lens = [len(e["nbr"]) for e in exports]
            bases = np.cumsum([0] + lens)[:-1]
            tcsr = {
                "indptr": np.stack([e["indptr"] + np.int32(b)
                                    for e, b in zip(exports, bases)]),
                **{key: np.concatenate([e[key] for e in exports])
                   for key in ("nbr", "t", "eidx", "bat")},
            }

    if host_replay:
        # legacy Alg.2 wrap-around ON HOST: replay from the start; the
        # neighbor index is implicitly reset each cycle because replayed
        # batches reuse the first-cycle samples.
        per_dev = [{kk: v[np.arange(steps) % len(p["src"])]
                    for kk, v in p.items()} for p in programs]
        batches = {kk: np.stack([d[kk] for d in per_dev])
                   for kk in per_dev[0]}
        offsets = None
    else:
        # ship ONLY the real batches (trimmed to the lockstep length when
        # steps_override cuts an epoch short); the device gathers
        # offsets[k] + s % n_batches[k] inside the scan.
        trimmed = [{kk: v[: n_batches[k]] for kk, v in p.items()}
                   for k, p in zip(ranks, programs)]
        if layout == "sharded":
            # row-range-sharded: every device owns row k of a padded
            # stack — offsets are all zero and the grid maps over "part"
            rows_cap = int(n_batches.max()) if n_dev else 0
            batches = pad_batch_programs(trimmed, rows_cap)
            offsets = np.zeros(n_dev, np.int32)
        else:
            batches, offsets = concat_batch_programs(trimmed)

    shared_local = np.zeros((n_dev, len(shared_nodes)), np.int32)
    for k, li in enumerate(local):
        rows = li.to_local[shared_nodes] if len(shared_nodes) else \
            np.zeros(0, np.int32)
        if len(shared_nodes) and (rows < 0).any():
            raise ValueError(
                "shared nodes must be present on every device "
                "(Alg.1 line 20 shared_to_all)")
        shared_local[k] = rows

    e_cap = int(edges_per_device.max()) if n_dev else 0
    return EpochPlan(
        batches=batches,
        n_batches=n_batches,
        nfeat_local=nfeat_local,
        efeat_local=efeat_local,
        shared_local=shared_local,
        node_lists=list(node_lists),
        capacity=cap,
        edge_capacity=e_cap,
        steps=steps,
        edges_per_device=edges_per_device,
        offsets=offsets,
        host_replay=host_replay,
        tcsr=tcsr,
        layout=layout,
        local_ranks=None if local_ranks is None
        else np.asarray(ranks, np.int64),
    )


# ======================================================================
# the device-epoch program
# ======================================================================

def device_epoch(
    params,
    opt_state,
    batches,        # flat (sum real, ...) pytree — or (steps, ...) replayed
    offset,         # () int32 — this device's start row in the flat grid
    n_batches,      # () int32 — real batches (cycle length)
    nfeat_local,    # (cap+1, d_n)
    efeat,          # (E+1, d_e) replicated
    shared_local,   # (S,) int32
    tcsr_indptr=None,   # (cap+1,) int32 — this device's T-CSR row bounds
    tcsr_events=None,   # flat event arrays (shared across devices)
    *,
    cfg: TIGConfig,
    opt: Optimizer,
    steps: int,
    capacity: int,
    sync_mode: Literal["latest", "mean"] = "latest",
    axis: str = "part",
    host_replay: bool = False,
    sync_epilogue: bool = True,
):
    """One epoch on one device (runs under vmap or shard_map over ``axis``).

    The scan itself is the shared engine program (``engine.scan_train_epoch``
    with ``cycle_length`` = this device's real batch count and DDP gradient
    sync over ``axis``); the PAC-specific shared-node memory sync runs as
    the ``sync_shared_memory`` epilogue.  ``sync_epilogue=False`` returns
    the PRE-sync epoch-end state instead — the scan-only half of the
    overlap boundary, whose caller dispatches ``make_pac_sync`` separately
    so the collectives drain behind the next epoch.

    Default mode is the transfer-minimal plan: ``batches`` holds only real
    batches and the scan gathers ``offset + s % n_batches`` for each of the
    ``steps`` lockstep steps (Alg.2 wrap-around ON DEVICE).  With
    ``host_replay`` (the parity oracle) ``batches`` is this device's grid
    already replayed to ``steps`` rows on the host.

    With ``tcsr_indptr`` / ``tcsr_events`` (a device-sampled plan,
    ``plan_epoch(plan="device")``) the batch grid carries raw edge records
    and the scanned step samples its neighbor grids on device: the
    device's ``indptr`` window addresses its own segment of the shared
    flat event buffer (replicated layout — per-device exports are
    concatenated with offset ``indptr``s) or, with the row-range-sharded
    layout, its OWN padded event rows with unoffset ``indptr`` (the
    executor maps both over the device axis, so either way this function
    sees one device's ``(cap+1,)`` indptr + the events it may address).
    """
    tables = {"efeat": efeat, "nfeat": nfeat_local}
    fresh = init_state(cfg, capacity)
    tcsr = None
    if tcsr_indptr is not None:
        tcsr = {"indptr": tcsr_indptr, **tcsr_events}

    if host_replay:
        # stream length is carried by the batches pytree itself
        params, opt_state, state, losses = scan_train_epoch(
            params, opt_state, fresh, batches, tables,
            cfg=cfg, opt=opt, axis=axis, cycle_length=n_batches, tcsr=tcsr)
    else:
        params, opt_state, state, losses = scan_train_epoch(
            params, opt_state, fresh, batches, tables,
            cfg=cfg, opt=opt, axis=axis, cycle_length=n_batches,
            wrap_steps=steps, wrap_offset=offset, tcsr=tcsr)

    if sync_epilogue:
        state = sync_shared_memory(state, shared_local,
                                   sync_mode=sync_mode, axis=axis)

    return params, opt_state, state, losses


def sync_shared_memory(
    state,
    shared_local,   # (S,) int32 — this device's rows of the shared nodes
    *,
    sync_mode: Literal["latest", "mean"] = "latest",
    axis: str = "part",
):
    """Shared-node memory synchronization (paper §II-C) for ONE device's
    epoch-end state — runs under vmap or shard_map over ``axis``.

    §Perf iteration C1: instead of all-gathering the full (N_dev, S, d)
    replica rows (O(N*S*d) link bytes), gather only the (N_dev, S)
    timestamps, compute the argmax winner, and combine rows with a
    winner-masked psum — O(N*S + S*d) bytes, ~d-fold less traffic.

    Factored out of ``device_epoch`` so the overlap boundary can dispatch
    it as a SEPARATE program (``make_pac_sync``) right after the scan-only
    epoch program: the cross-host collectives then drain while the next
    epoch stages and dispatches, instead of serializing inside one fused
    program.  The fused path (``device_epoch(sync_epilogue=True)``) calls
    this same function, so the two boundaries share the sync math.
    """
    if shared_local.shape[0] == 0:
        return state
    rows_m = state["mem"][shared_local]          # (S, d)
    rows_m2 = state["mem2"][shared_local]
    rows_t = state["last"][shared_local]         # (S,)
    if sync_mode == "latest":
        all_t = jax.lax.all_gather(rows_t, axis)     # (N_dev, S)
        win = jnp.argmax(all_t, axis=0)              # (S,)
        me = jax.lax.axis_index(axis)
        mine = (win == me)[:, None].astype(rows_m.dtype)
        new_m = jax.lax.psum(rows_m * mine, axis)
        new_m2 = jax.lax.psum(rows_m2 * mine, axis)
        new_t = jnp.max(all_t, axis=0)
    else:
        n = jax.lax.psum(1, axis)
        new_m = jax.lax.psum(rows_m, axis) / n
        new_m2 = jax.lax.psum(rows_m2, axis) / n
        new_t = jax.lax.psum(rows_t, axis) / n
    return {
        **state,
        "mem": state["mem"].at[shared_local].set(new_m),
        "mem2": state["mem2"].at[shared_local].set(new_m2),
        "last": state["last"].at[shared_local].set(new_t),
    }


def make_pac_epoch(
    cfg: TIGConfig,
    opt: Optimizer,
    steps: int,
    capacity: int,
    *,
    mesh: Optional[Mesh] = None,
    sync_mode: Literal["latest", "mean"] = "latest",
    host_replay: bool = False,
    device_plan: bool = False,
    grid_layout: str = "replicated",
    sync_epilogue: bool = True,
):
    """Build the jitted epoch executor.

    mesh=None  -> vmap simulation over the leading device axis (single host
                  device; used by CPU tests/benchmarks).
    mesh given -> shard_map over mesh axis "part" (real SPMD; the dry-run
                  compiles this exact program for the production mesh; the
                  mesh may SPAN PROCESSES — ``launch.mesh.make_tig_mesh``
                  — in which case the grid/feature in_specs place each
                  device's rows on its owning host and the shared-node
                  sync collectives run across hosts).

    ``grid_layout="replicated"`` (the single-host oracle): the flat batch
    grid is UNMAPPED (vmap ``in_axes=None`` / shard_map replicated) —
    every device holds the ``sum_k n_batches_k`` real rows and gathers its
    own window; still far smaller than a replayed ``N_dev * steps`` grid
    whenever partitions are imbalanced.  ``grid_layout="sharded"`` (pod
    scale) instead maps the (N_dev, rows_cap, ...) padded grid — and a
    device plan's per-device T-CSR events — over "part": per-device H2D
    is O(own rows) and no host ever needs another host's rows
    (``plan_epoch(layout="sharded")`` emits this layout).  With
    ``host_replay`` the legacy per-device replayed grids are mapped over
    the device axis.

    With ``device_plan`` the executor takes two extra operands — the
    (N_dev, cap+1) mapped T-CSR ``indptr`` and the event arrays (flat
    replicated, or per-device mapped when sharded) — and the scanned step
    samples neighbor grids on device (``plan_epoch(plan="device")`` emits
    both).  Note the vmap simulation then routes sampling through
    whatever backend ``cfg`` selects; the Pallas path is written for the
    per-device shard_map/SPMD layout.

    ``sync_epilogue=False`` builds the SCAN-ONLY half of the async epoch
    boundary: the program returns the pre-sync epoch-end states (the
    caller dispatches ``make_pac_sync`` on them separately so the
    shared-node collectives drain behind the next epoch), and its
    per-epoch plan operands — batch grids, feature tables, T-CSR — are
    DONATED (non-CPU backends): the staging path re-materializes them
    every epoch, so XLA may reuse their device buffers in place.  The
    fused single-program path (``sync_epilogue=True``, the default) is
    the bit-parity oracle for the split boundary.
    """
    if grid_layout not in ("replicated", "sharded"):
        raise ValueError(f"grid_layout={grid_layout!r}")
    if host_replay and grid_layout == "sharded":
        raise ValueError("host_replay implies the replicated schedule")
    sharded = grid_layout == "sharded"
    grid_mapped = host_replay or sharded
    kernel = functools.partial(
        device_epoch, cfg=cfg, opt=opt, steps=steps, capacity=capacity,
        sync_mode=sync_mode, host_replay=host_replay,
        sync_epilogue=sync_epilogue,
    )
    # donated plan buffers (scan-only boundary): batches=2, nfeat=5,
    # efeat=6 (+ the T-CSR operands, 8/9) are re-staged every epoch and
    # consumed exactly once; shared_local (7) is NOT donated — the
    # separate sync program reads it after the scan.  The fused oracle
    # keeps its operands intact.
    donate = () if sync_epilogue else _donate(
        2, 5, 6, *((8, 9) if device_plan else ()))

    if mesh is None:
        in_axes = [None, None, 0 if grid_mapped else None, 0, 0, 0, 0, 0]
        if device_plan:
            # indptr always mapped; events mapped only when sharded
            in_axes += [0, 0 if sharded else None]
        vmapped = jax.vmap(
            kernel,
            in_axes=tuple(in_axes),
            out_axes=(0, 0, 0, 0),
            axis_name="part",
        )

        def run(params, opt_state, batches, offsets, n_batches,
                nfeat_local, efeat, shared_local, *tcsr_args):
            p, o, state, losses = vmapped(
                params, opt_state, batches, offsets, n_batches,
                nfeat_local, efeat, shared_local, *tcsr_args)
            # params/opt_state identical across devices (pmean'd grads)
            p0 = jax.tree.map(lambda x: x[0], p)
            o0 = jax.tree.map(lambda x: x[0], o)
            return p0, o0, state, losses

        return jax.jit(run, donate_argnums=donate)

    part = P("part")
    rep = P()

    def body(params, opt_state, batches, offsets, n_batches, nfeat_local,
             efeat, shared_local, *tcsr_args):
        squeeze = lambda t: jax.tree.map(lambda x: x[0], t)
        extra = ()
        if tcsr_args:
            extra = (squeeze(tcsr_args[0]),
                     squeeze(tcsr_args[1]) if sharded else tcsr_args[1])
        p, o, state, losses = kernel(
            params, opt_state,
            squeeze(batches) if grid_mapped else batches,
            squeeze(offsets), squeeze(n_batches),
            squeeze(nfeat_local), squeeze(efeat), squeeze(shared_local),
            *extra)
        expand = lambda t: jax.tree.map(lambda x: x[None], t)
        return p, o, expand(state), expand(losses)

    in_specs = (rep, rep, part if grid_mapped else rep,
                part, part, part, part, part)
    if device_plan:
        in_specs += (part, part if sharded else rep)
    smapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(rep, rep, part, part),
    )
    return jax.jit(smapped, donate_argnums=donate)


def make_pac_sync(
    *,
    sync_mode: Literal["latest", "mean"] = "latest",
    mesh: Optional[Mesh] = None,
):
    """Build the standalone jitted shared-node sync program —
    ``(states, shared_local) -> states`` over stacked (N_dev, ...) inputs.

    The separable half of the async epoch boundary: ``pac_train`` with
    ``epoch_boundary="overlap"`` dispatches this right after the
    scan-only epoch program and does NOT block on it, so the cross-host
    ``all_gather``/``psum`` collectives drain while the worker thread
    stages epoch e+1's plan and the main thread dispatches its scan.
    Executors mirror ``make_pac_epoch``: vmap simulation (``mesh=None``)
    or shard_map over the mesh's "part" axis.  The math is the same
    ``sync_shared_memory`` the fused oracle runs.
    """
    kernel = functools.partial(sync_shared_memory, sync_mode=sync_mode)
    if mesh is None:
        return jax.jit(jax.vmap(kernel, in_axes=(0, 0), out_axes=0,
                                axis_name="part"))

    part = P("part")

    def body(state, shared_local):
        squeeze = lambda t: jax.tree.map(lambda x: x[0], t)  # noqa: E731
        out = kernel(squeeze(state), squeeze(shared_local))
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(part, part), out_specs=part))


# ======================================================================
# full training driver
# ======================================================================

def globalize_memory(
    states,
    plan: EpochPlan,
    num_nodes: int,
    cfg: TIGConfig,
    *,
    time_rescale: float = 1.0,
) -> dict:
    """Merge PAC's stacked (N_dev, ...) post-sync memories into one
    global-row state suitable for the evaluation protocol.

    Each device contributes its real local rows (local id = rank in the
    sorted node list, as ``make_local_indices`` assigns them); a node
    hosted by several devices resolves by the paper's "latest" rule — the
    replica with the largest last-update time wins (first host wins ties).
    ``time_rescale`` converts the plan-scale "last" timestamps into the
    consumer's units (train-split scale -> protocol full-stream scale).
    Pending-message buffers are not carried over: PAC's cycle-end backup
    already treats (mem, mem2, last) as the state of record.
    """
    d = int(np.asarray(states["mem"]).shape[-1])
    mem = np.zeros((num_nodes + 1, d), np.float32)
    mem2 = np.zeros((num_nodes + 1, d), np.float32)
    last = np.zeros((num_nodes + 1,), np.float32)
    written = np.zeros(num_nodes + 1, dtype=bool)
    for k, nodes in enumerate(plan.node_lists):
        nodes = np.sort(np.asarray(nodes, np.int64))
        n = len(nodes)
        m = np.asarray(states["mem"][k][:n])
        m2 = np.asarray(states["mem2"][k][:n])
        l = np.asarray(states["last"][k][:n]) * np.float32(time_rescale)
        take = (~written[nodes]) | (l > last[nodes])
        tgt = nodes[take]
        mem[tgt], mem2[tgt], last[tgt] = m[take], m2[take], l[take]
        written[tgt] = True
    fresh = init_state(cfg, num_nodes)
    return {**fresh, "mem": jnp.asarray(mem), "mem2": jnp.asarray(mem2),
            "last": jnp.asarray(last)}


@dataclasses.dataclass
class PACResult:
    params: dict
    memory_states: dict           # stacked (N_dev, ...) post-sync states
    losses: list                  # per epoch: (N_dev, steps_e) arrays
    derived_speedup: float
    edges_per_device: np.ndarray
    plan: EpochPlan
    metrics: Optional[dict] = None   # run_protocol output (eval_graph given)

    def mean_loss_per_epoch(self) -> np.ndarray:
        return np.array([float(l.mean()) for l in self.losses])


def stage_replicated_tree(tree, mesh):
    """Replicate every leaf of a pytree across all devices of ``mesh`` —
    cross-process safe (params/optimizer state at the start of a
    multi-process PAC run; epoch outputs then keep the placement)."""
    return jax.tree.map(lambda x: stage_replicated(x, mesh), tree)


_PAC_PROGRAMS_MAX = 8    # per-call LRU of compiled epoch executors

# Module-level LRU of the multihost host-read gather (jit identity that
# reshards fully replicated).  One wrapper per MESH, persistent across
# ``pac_train`` calls: rebuilding it per call discarded its trace cache,
# so every call re-traced per distinct loss shape (``steps`` varies
# across epochs) — the same retrace leak the epoch-program LRU fixes.
_GATHER_PROGRAMS: dict = {}
_GATHER_PROGRAMS_MAX = 8


def _replicating_gather(mesh: Mesh):
    return lru_get(
        _GATHER_PROGRAMS, mesh, _GATHER_PROGRAMS_MAX,
        lambda: jax.jit(lambda t: t,
                        out_shardings=NamedSharding(mesh, P())))


def pac_train(
    g_train: StreamSource,
    partition: PartitionResult,
    cfg: TIGConfig,
    *,
    num_devices: int,
    epochs: int = 3,
    lr: float = 1e-3,
    seed: int = 0,
    shuffle_parts: bool = True,
    sync_mode: Literal["latest", "mean"] = "latest",
    mesh: Optional[Mesh] = None,
    prefetch: bool = True,
    depth: int = 1,
    epoch_boundary: Literal["overlap", "serial"] = "overlap",
    host_replay: bool = False,
    plan: str = "device",
    grid_layout: Optional[str] = None,
    eval_graph: Optional[StreamSource] = None,
    eval_node_class: bool = False,
    eval_warm: Literal["memory", "replay", "restart"] = "memory",
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    resume: bool = False,
    faults: Optional[FaultInjector] = None,
) -> PACResult:
    """Train a TIG model with SEP partitions + PAC (the paper's pipeline).

    ``g_train`` is the train split — an in-memory ``TemporalGraph`` or an
    out-of-core ``ShardedStream`` (per-device localization then runs
    straight off the row-range shards; the graph never materializes).

    ``partition`` may have more parts than devices (|P| > N): parts are then
    shuffle-combined into N super-partitions before every epoch (Fig.7).

    With ``prefetch`` (the default) cycle e+1's host planning — shuffle-
    combine, localization, batch grids — and its host->device transfer run
    on a worker thread while cycle e's scan executes (``depth`` host plans
    may run ahead; device staging stays single-slot); per-epoch RNG
    streams keep results bit-identical to serial planning.
    ``host_replay=True`` selects the legacy host-side wrap-around replay
    plan (the parity oracle for the transfer-minimal device-side wrap,
    bit-identical).

    ``epoch_boundary="overlap"`` (the default) makes the boundary itself
    asynchronous: the epoch runs as a SCAN-ONLY program (plan buffers
    donated), the Alg.2 shared-node memory sync is dispatched as a
    separate program the main thread never blocks on (its cross-host
    collectives drain behind epoch e+1's staging and scan), and the
    per-epoch loss read becomes an async device->host copy collected once
    after the loop.  ``"serial"`` is the fused-program oracle — scan+sync
    in one program, blocking ``fetch`` per epoch — and is bit-identical
    (the parity suite asserts exact equality of losses/params/memory/
    metrics).  Disable pipelining entirely with ``prefetch=False`` /
    ``depth=0`` + ``epoch_boundary="serial"`` when debugging.

    ``grid_layout`` picks the grid/T-CSR placement: ``"sharded"`` (the
    default whenever a ``mesh`` is given) row-range-shards the batch grid
    and per-device T-CSR over "part" so each device transfers only its
    own rows; ``"replicated"`` (the default for the vmap simulation, and
    the bit-parity oracle) ships every device the flat grid.  On a mesh
    spanning processes (``launch.mesh.make_tig_mesh``) each process
    additionally PLANS only its own devices' rows
    (``plan_epoch(local_ranks=...)``) and stages them with
    ``make_array_from_process_local_data`` — host grid bytes and H2D stay
    O(local devices) per host, and the Alg.2 shared-node memory sync
    genuinely crosses hosts.  Every process must call ``pac_train`` with
    identical arguments (standard SPMD contract).

    ``plan="device"`` (the default) ships each device only its raw-edge
    stream plus T-CSR and samples neighbor grids inside the scanned step
    (bit-identical to host planning); ``plan="host"`` keeps the
    pre-sampled grids.  ``host_replay=True`` implies host planning — it
    IS the legacy host-side oracle.

    ``eval_graph`` (the FULL chronological stream — ``TemporalGraph`` or
    ``ShardedStream`` — of which ``g_train`` is the train split) routes the
    trained parameters through the shared evaluation-protocol driver
    (``protocol.run_protocol``, the same code path as ``train_single`` /
    ``train_sharded(protocol=True)``), REUSING PAC's synchronized node
    memories: the per-device post-sync states are merged back to global
    rows (latest-timestamp rule, ``globalize_memory``) and val/test are
    scored from that warm state — the device replay of the train split is
    skipped, so ``metrics["train_ap"]`` is NaN.  Results attach to
    ``PACResult.metrics``.  ``eval_warm`` picks where that warm state
    comes from: ``"memory"`` (the default — PAC's synchronized memories,
    above), ``"replay"`` (the plain protocol oracle: replay the train
    split), or ``"restart"`` (TIGER-style: fit a restarter head on
    collected embeddings, rebuild memory in O(N) — the restarter is also
    saved next to the checkpoints when ``ckpt_dir`` is set, so an elastic
    relaunch can warm memory without any replay).

    Fault tolerance: ``ckpt_dir`` + ``ckpt_every=k`` atomically saves
    ``{params, opt_state, states}`` every k epochs (process 0 writes;
    every process joins the gather).  ``resume=True`` restores
    params/opt_state from the newest complete step and continues from the
    following epoch — bit-identical to an uninterrupted run, because each
    epoch's plan RNG and memory init depend only on ``(seed, ep)``.
    Resuming past the final epoch re-emits a fresh-memory result (saved
    states may be shaped for a different device count, so they are not
    reloaded).  ``faults`` (default: parsed from ``$REPRO_FAULTS``)
    deterministically injects failures at the named sites (``host_kill``,
    ``staging_oom``, ``prefetch_worker``, ``sync_fail``); in a multi-host
    run, any failure that classifies as a lost peer (``is_host_loss``)
    is re-raised as ``HostLossError`` so ``launch.pac_cluster`` can
    re-form the world over the survivors.
    """
    from repro.optim import adamw

    if plan not in ("host", "device"):
        raise ValueError(f"plan={plan!r}: expected 'host' or 'device'")
    if epoch_boundary not in ("overlap", "serial"):
        raise ValueError(f"epoch_boundary={epoch_boundary!r}: expected "
                         "'overlap' or 'serial'")
    overlap = epoch_boundary == "overlap"
    if host_replay:
        plan = "host"
    if grid_layout is None:
        grid_layout = "replicated" if (mesh is None or host_replay) \
            else "sharded"
    if grid_layout not in ("replicated", "sharded"):
        raise ValueError(f"grid_layout={grid_layout!r}")
    if host_replay and grid_layout == "sharded":
        raise ValueError("host_replay implies grid_layout='replicated'")
    if eval_warm not in ("memory", "replay", "restart"):
        raise ValueError(f"eval_warm={eval_warm!r}: expected 'memory', "
                         "'replay' or 'restart'")
    if resume and not ckpt_dir:
        raise ValueError("resume=True needs ckpt_dir")
    injector = faults if faults is not None else FaultInjector.from_env()

    # a mesh spanning >1 process: plan + stage only local devices' rows
    mesh_procs = sorted({d.process_index
                         for d in np.asarray(mesh.devices).flat}) \
        if mesh is not None else []
    multihost = len(mesh_procs) > 1
    if multihost:
        from repro.launch.mesh import local_part_ranks
        ranks_np = local_part_ranks(mesh)
    plan_ranks = ranks_np if (multihost and grid_layout == "sharded") \
        else None

    small_parts = partition.node_lists()
    if isinstance(g_train, ShardedStream):
        time_scale = time_scale_of(g_train.column("t"))
    else:
        time_scale = time_scale_of(g_train.t)

    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)
    if multihost:
        # replicate once across the whole (cross-process) mesh; epoch
        # outputs keep the placement, so this happens only at init
        params = stage_replicated_tree(params, mesh)
        opt_state = stage_replicated_tree(opt_state, mesh)

    def build(ep: int) -> EpochPlan:
        injector.fire("prefetch_worker", epoch=ep)
        rng_ep = epoch_rng(seed, ep, 11)
        if shuffle_parts and len(small_parts) > num_devices:
            node_lists = shuffle_combine(small_parts, num_devices, rng_ep)
        elif len(small_parts) == num_devices:
            node_lists = small_parts
        else:
            node_lists = shuffle_combine(
                small_parts, num_devices, np.random.default_rng(seed))
        return plan_epoch(g_train, node_lists, partition.shared_nodes,
                          cfg, rng_ep, time_scale=time_scale,
                          host_replay=host_replay, plan=plan,
                          layout=grid_layout, local_ranks=plan_ranks)

    def to_device(ep_plan: EpochPlan):
        injector.fire("staging_oom")
        offsets = ep_plan.offsets if ep_plan.offsets is not None else \
            np.zeros(num_devices, np.int32)
        if not multihost:
            # single process: jnp.asarray suffices for every layout (jit
            # reshards at dispatch; all devices are addressable)
            dev = [
                {k: jnp.asarray(v) for k, v in ep_plan.batches.items()},
                jnp.asarray(offsets),
                jnp.asarray(ep_plan.n_batches),
                jnp.asarray(ep_plan.nfeat_local),
                jnp.asarray(ep_plan.efeat_local),
                jnp.asarray(ep_plan.shared_local),
            ]
            if ep_plan.tcsr is not None:
                dev.append(jnp.asarray(ep_plan.tcsr["indptr"]))
                dev.append({k: jnp.asarray(v)
                            for k, v in ep_plan.tcsr.items()
                            if k != "indptr"})
            return ep_plan, tuple(dev)

        # multi-process staging: mapped operands assemble the global
        # (N_dev, ...) array from THIS process's rows only (the olmax
        # per-process-slice idiom); plan-global scalars are sliced to the
        # local row range first.  Only a replicated grid layout ships
        # full flat arrays (the cross-host parity oracle).
        held_local = ep_plan.local_ranks is not None
        part = lambda a: stage_partitioned(  # noqa: E731
            np.asarray(a), mesh, num_devices)
        g2l = lambda a: np.asarray(a)[ranks_np]  # noqa: E731
        loc = (lambda a: np.asarray(a)) if held_local else g2l
        sharded_grid = ep_plan.layout == "sharded"
        # the replayed oracle grid is (N_dev, steps, ...) and mapped too
        grid_mapped = sharded_grid or ep_plan.host_replay
        grid_loc = loc if sharded_grid else g2l
        dev = [
            {k: (part(grid_loc(v)) if grid_mapped else
                 stage_replicated(v, mesh))
             for k, v in ep_plan.batches.items()},
            part(g2l(offsets)),
            part(g2l(ep_plan.n_batches)),
            part(loc(ep_plan.nfeat_local)),
            part(loc(ep_plan.efeat_local)),
            part(g2l(ep_plan.shared_local)),
        ]
        if ep_plan.tcsr is not None:
            dev.append(part(loc(ep_plan.tcsr["indptr"])))
            dev.append({k: (part(loc(v)) if sharded_grid else
                            stage_replicated(v, mesh))
                        for k, v in ep_plan.tcsr.items()
                        if k != "indptr"})
        return ep_plan, tuple(dev)

    # LRU of compiled epoch executors, mirroring make_eval_epoch's cache:
    # shuffle-combine draws alternate between a few (steps, capacity,
    # edge_capacity) shapes across epochs — keep each compiled program
    # live (move-to-end on hit) instead of rebuilding the jit wrapper
    # (and its compilation cache) every time the key changes.
    programs: dict = {}

    def epoch_program(ep_plan: EpochPlan):
        from repro.kernels import ops as _kops
        # cfg is fixed per pac_train call, but the executor's compiled
        # shapes also depend on n_layers (per-layer grids) and the
        # lane-padded dims the MXU tier launches — key them explicitly so
        # layer-count or padding-rule changes can't reuse a stale program.
        # The mesh and grid layout are part of the key too: a
        # process-spanning mesh and the vmap simulation (or two meshes /
        # layouts in one process) must never collide on the same program.
        key = (ep_plan.steps, ep_plan.capacity, ep_plan.edge_capacity,
               cfg.n_layers, _kops.lane_pad(cfg.dim),
               _kops.lane_pad(cfg.msg_dim), mesh, grid_layout,
               epoch_boundary)
        return lru_get(
            programs, key, _PAC_PROGRAMS_MAX,
            lambda: make_pac_epoch(
                cfg, opt, ep_plan.steps, ep_plan.capacity, mesh=mesh,
                sync_mode=sync_mode, host_replay=host_replay,
                device_plan=(plan == "device"), grid_layout=grid_layout,
                sync_epilogue=not overlap))

    def sync_program():
        # shape-polymorphic (jit retraces per state/shared shape inside
        # one wrapper), so a single cached program per mesh suffices
        return lru_get(
            programs, ("sync", mesh, sync_mode), _PAC_PROGRAMS_MAX,
            lambda: make_pac_sync(sync_mode=sync_mode, mesh=mesh))

    if multihost:
        # host values of cross-process arrays: reshard to fully
        # replicated (the all-gather over "part"), read the local shard
        gather = _replicating_gather(mesh)

        def fetch(tree):
            return jax.tree.map(
                lambda x: np.asarray(x.addressable_data(0)), gather(tree))

        def drain_local(tree):        # tree already gathered replicated
            return jax.tree.map(
                lambda x: np.asarray(x.addressable_data(0)), tree)
    else:
        def fetch(tree):
            return jax.tree.map(np.asarray, tree)

        drain_local = fetch

    def drain_async(tree):
        """Dispatch the device->host read WITHOUT blocking: reshard to
        replicated (multihost) and start the copy; ``drain_local``
        collects the host values once, after the loop."""
        tree = gather(tree) if multihost else tree
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        return tree

    start_epoch = 0
    if resume:
        step = latest_step(ckpt_dir)
        if step is not None:
            # restore on host (fetch is a collective in multihost: every
            # process joins), then re-stage exactly like the fresh init
            host = restore_checkpoint(ckpt_dir, step, {
                "params": fetch(params), "opt_state": fetch(opt_state)})
            if multihost:
                params = stage_replicated_tree(host["params"], mesh)
                opt_state = stage_replicated_tree(host["opt_state"], mesh)
            else:
                params = jax.tree.map(jnp.asarray, host["params"])
                opt_state = jax.tree.map(jnp.asarray, host["opt_state"])
            start_epoch = step + 1
            print(f"PAC_RESUME: step {step} restored from {ckpt_dir}, "
                  f"continuing at epoch {start_epoch}", flush=True)

    ckpt_writer = (not multihost) or jax.process_index() == 0

    all_losses = []
    last_plan = None
    states = None
    try:
        with EpochPrefetcher(build, epochs, to_device=to_device,
                             enabled=prefetch, depth=depth) as pf:
            for ep in range(start_epoch, epochs):
                injector.fire("host_kill", epoch=ep)
                ep_plan, dev = pf.get(ep)
                if overlap:
                    # scan-only program, then the sync epilogue as a
                    # separate dispatch the main thread never blocks on:
                    # its cross-host collectives drain while the worker
                    # stages epoch e+1 and the next scan is dispatched.
                    # dev[5] is shared_local — the one plan operand the
                    # scan program does not donate.
                    params, opt_state, raw_states, losses = epoch_program(
                        ep_plan)(params, opt_state, *dev)
                    injector.fire("sync_fail", epoch=ep)
                    states = sync_program()(raw_states, dev[5])
                    # deferred host read: async copy now, collect after
                    # the loop
                    all_losses.append(drain_async(losses))
                else:
                    injector.fire("sync_fail", epoch=ep)
                    params, opt_state, states, losses = epoch_program(
                        ep_plan)(params, opt_state, *dev)
                    all_losses.append(fetch(losses))
                last_plan = ep_plan
                if ckpt_dir and ckpt_every and (ep + 1) % ckpt_every == 0:
                    # fetch is collective — all processes call it; only
                    # process 0 touches the filesystem (atomic writes)
                    snap = {"params": fetch(params),
                            "opt_state": fetch(opt_state),
                            "states": fetch(states)}
                    if ckpt_writer:
                        save_checkpoint(ckpt_dir, ep, snap,
                                        metadata={"epoch": ep})
        if overlap:
            all_losses = [drain_local(l) for l in all_losses]

        if last_plan is None:
            # epochs=0 (or resume past the end): nothing trained — still
            # emit a consistent result (plan of the epoch that WOULD have
            # run, fresh stacked memories)
            last_plan = build(0)
            fresh = init_state(cfg, last_plan.capacity)
            states_host = jax.tree.map(
                lambda x: np.broadcast_to(
                    np.asarray(x), (num_devices,) + x.shape).copy(), fresh)
            params_host = fetch(params) if multihost else params
        else:
            # host copies once: globalize_memory / run_protocol / the
            # result run on host or the local default device, so
            # cross-process arrays must be gathered out of the mesh first
            states_host = fetch(states)
            params_host = fetch(params) if multihost else params
    except Exception as exc:
        if multihost and is_host_loss(exc):
            raise HostLossError(
                f"peer lost during PAC training: {exc}") from exc
        raise

    from repro.core.pac import derived_speedup as dsp

    metrics = None
    if eval_graph is not None:
        from repro.tig.batching import make_tables
        from repro.tig.protocol import run_protocol, split_views
        from repro.tig.stream import stage_device_tables

        splits = split_views(eval_graph)
        if isinstance(eval_graph, ShardedStream):
            tables_j = stage_device_tables(eval_graph)
        else:
            tables_j = {k: jnp.asarray(v) for k, v in make_tables(
                eval_graph.edge_feat, eval_graph.node_feat).items()}
        if eval_warm == "memory":
            warm = globalize_memory(
                states_host, last_plan, splits.num_nodes,
                cfg, time_rescale=time_scale / splits.time_scale)
            metrics = run_protocol(
                params_host, cfg, splits, tables_j, seed=seed,
                eval_node_class=eval_node_class, state=warm,
                replay_train=False)
        elif eval_warm == "replay":
            # plain protocol oracle: replay the train split for memory
            metrics = run_protocol(
                params_host, cfg, splits, tables_j, seed=seed,
                eval_node_class=eval_node_class, warm="replay")
        else:  # "restart": TIGER-style replayless memory reconstruction
            from repro.tig.restart import build_restarter, save_restarter

            rst, _ = build_restarter(
                params_host, cfg, splits, tables_j, seed=seed)
            if ckpt_dir and ckpt_writer:
                save_restarter(
                    os.path.join(ckpt_dir, "restarter.npz"), rst)
            metrics = run_protocol(
                params_host, cfg, splits, tables_j, seed=seed,
                eval_node_class=eval_node_class, warm="restart",
                restarter=rst)

    return PACResult(
        params=params_host,
        memory_states=states_host,
        losses=all_losses,
        derived_speedup=dsp(last_plan.edges_per_device),
        edges_per_device=last_plan.edges_per_device,
        plan=last_plan,
        metrics=metrics,
    )
