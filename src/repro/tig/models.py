"""TIG models as instances of one general architecture (paper Fig.6).

The paper trains four backbones — Jodie [1], DyRep [2], TGN [4], TIGE [5] —
through a single Encoder-Decoder template: Memory, Message (MSG),
Aggregation, State Update (UPD), Embedding, and a link Decoder.  Each flavor
selects concrete modules:

    flavor   MSG            AGG    UPD        Embedding
    jodie    id-concat      mean   RNN        time projection
    dyrep    id-concat      mean   RNN        identity (memory read-out)
    tgn      id-concat/MLP  mean   GRU        temporal graph attention
    tige     id-concat/MLP  mean   GRU+RNN    temporal graph attention over
                                   (dual mem) the dual-memory mean

Training semantics follow TGN's *message store*: the raw messages produced by
batch n are **stashed** and only applied to memory at the start of batch n+1,
right before embeddings are computed — so the loss at batch n+1 backpropagates
through the UPD/MSG modules (otherwise they would receive no gradient).
TIGE's published restart mechanism is simplified to its dual-memory reading
(see DESIGN.md §3 — changed assumptions).

All functions are pure; state is a pytree:

    state = {
      "mem":      (N+1, d)   node memory M (row N = dump row for padding),
      "mem2":     (N+1, d)   second memory (TIGE only; zeros otherwise),
      "last":     (N+1,)     last-update timestamps,
      "pend_ids": (2B,)      node rows touched by the previous batch,
      "pend_raw": (2B, dr)   their raw (pre-MSG) messages,
      "pend_t":   (2B,)      their event times,
    }

Batches are fixed-shape with a validity mask; invalid ids are remapped to the
dump row, which is re-zeroed after every update.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.tig.modules import (
    attn_init,
    dense,
    dense_init,
    gru,
    gru_init,
    mlp,
    mlp_init,
    rnn,
    rnn_init,
    stacked_attn_init,
    stacked_temporal_attention,
    temporal_attention,
)
from repro.tig.time_encode import init_time_encoder, time_encode

__all__ = ["TIGConfig", "init_params", "init_state", "step_loss",
           "flush_pending", "embed_nodes", "FLAVORS"]

FLAVORS = ("jodie", "dyrep", "tgn", "tige")


@dataclasses.dataclass(frozen=True)
class TIGConfig:
    """Hyper-parameters of the general TIG architecture."""

    flavor: str = "tgn"
    dim: int = 64              # memory == embedding dim
    dim_time: int = 32
    dim_edge: int = 16
    dim_node: int = 16
    num_neighbors: int = 10    # K most-recent temporal neighbors
    n_heads: int = 2
    message_fn: str = "id"     # "id" (concat) or "mlp"
    dim_msg: int = 64          # MSG output dim when message_fn == "mlp"
    batch_size: int = 200
    n_classes: int = 0         # >0 enables the node-classification head
    use_pallas: bool = False   # route UPD/attention through Pallas kernels
    kernel_backend: str = "auto"  # with use_pallas: "auto" | "pallas" |
                                  # "interpret" (CPU-testable Pallas path)
    # NOTE: new fields append at the END — cache keys use astuple(cfg) and
    # tests index into it positionally.
    n_layers: int = 1          # attention layers (lax.scan over a stacked
                               # layer block when > 1; TGN/TIGE only)

    def __post_init__(self):
        assert self.flavor in FLAVORS, self.flavor
        assert self.kernel_backend in ("auto", "pallas", "interpret"), \
            self.kernel_backend
        assert self.n_layers >= 1, self.n_layers

    @property
    def backend(self) -> str:
        """Kernel backend for this config ("xla" unless use_pallas)."""
        return self.kernel_backend if self.use_pallas else "xla"

    @property
    def raw_msg_dim(self) -> int:
        # [s_self ; s_other ; Phi(dt) ; e_ij]
        return 2 * self.dim + self.dim_time + self.dim_edge

    @property
    def msg_dim(self) -> int:
        return self.dim_msg if self.message_fn == "mlp" else self.raw_msg_dim

    @property
    def uses_attention(self) -> bool:
        return self.flavor in ("tgn", "tige")

    @property
    def updater(self) -> str:
        return "rnn" if self.flavor in ("jodie", "dyrep") else "gru"


# --------------------------------------------------------------------- init

def init_params(key, cfg: TIGConfig) -> dict:
    ks = list(jax.random.split(key, 12))
    p: dict = {"time": init_time_encoder(cfg.dim_time)}
    if cfg.message_fn == "mlp":
        p["msg"] = mlp_init(ks[0], [cfg.raw_msg_dim, cfg.msg_dim, cfg.msg_dim])
    if cfg.updater == "gru":
        p["upd"] = gru_init(ks[1], cfg.msg_dim, cfg.dim)
    else:
        p["upd"] = rnn_init(ks[1], cfg.msg_dim, cfg.dim)
    if cfg.flavor == "tige":
        p["upd2"] = rnn_init(ks[2], cfg.msg_dim, cfg.dim)

    if cfg.uses_attention:
        d_q = cfg.dim + cfg.dim_node + cfg.dim_time
        d_kv = cfg.dim + cfg.dim_edge + cfg.dim_time
        if cfg.n_layers == 1:
            p["attn"] = attn_init(ks[3], d_q, d_kv, cfg.dim, cfg.n_heads)
        else:
            # stacked layer block: every leaf carries a leading (L,) axis so
            # embed_nodes can lax.scan over ONE compiled layer
            p["attn"] = stacked_attn_init(ks[3], cfg.n_layers, d_q, d_kv,
                                          cfg.dim, cfg.n_heads)
    elif cfg.flavor == "jodie":
        p["jodie_w"] = jnp.zeros((cfg.dim,), jnp.float32)
        p["emb"] = dense_init(ks[3], cfg.dim + cfg.dim_node, cfg.dim)
    else:  # dyrep
        p["emb"] = dense_init(ks[3], cfg.dim + cfg.dim_node, cfg.dim)

    p["dec"] = mlp_init(ks[4], [2 * cfg.dim, cfg.dim, 1])
    if cfg.n_classes > 0:
        p["cls"] = mlp_init(ks[5], [cfg.dim, cfg.dim, cfg.n_classes])
    return p


def init_state(cfg: TIGConfig, num_local_nodes: int) -> dict:
    n, b, d = num_local_nodes, cfg.batch_size, cfg.dim
    return {
        "mem": jnp.zeros((n + 1, d), jnp.float32),
        "mem2": jnp.zeros((n + 1, d), jnp.float32),
        "last": jnp.zeros((n + 1,), jnp.float32),
        "pend_ids": jnp.full((2 * b,), n, jnp.int32),
        "pend_raw": jnp.zeros((2 * b, cfg.raw_msg_dim), jnp.float32),
        "pend_t": jnp.zeros((2 * b,), jnp.float32),
    }


# ---------------------------------------------------------------- memory ops

def _read_memory(cfg: TIGConfig, state_mem, state_mem2, ids):
    if cfg.flavor == "tige":
        return 0.5 * (state_mem[ids] + state_mem2[ids])
    return state_mem[ids]


def flush_pending(params: dict, cfg: TIGConfig, state: dict) -> dict:
    """Apply the stashed messages of the previous batch to memory (the
    differentiable half of the TGN message-store trick), then clear them."""
    n_dump = state["mem"].shape[0] - 1
    ids = state["pend_ids"]
    raw = state["pend_raw"]
    ts = state["pend_t"]
    live = ids < n_dump

    msg = mlp(params["msg"], raw) if cfg.message_fn == "mlp" else raw

    if cfg.updater == "gru" and cfg.use_pallas:
        # fused message pipeline: segment-mean + GRU + mem/last scatter in
        # one Pallas launch — O(2B) HBM traffic instead of the O(N)
        # aggregation tables + functional scatter below
        from repro.kernels import ops
        p = params["upd"]
        mem, last, mbar = ops.fused_flush(
            ids, msg, ts, state["mem"], state["last"],
            p["xz"]["w"], p["hz"]["w"], p["xz"]["b"], p["hz"]["b"],
            backend=cfg.kernel_backend)
    else:
        # mean-aggregate messages per node (paper: "simply mean message")
        zeros = jnp.zeros((n_dump + 1, cfg.msg_dim), msg.dtype)
        sums = zeros.at[ids].add(jnp.where(live[:, None], msg, 0.0))
        cnt = jnp.zeros((n_dump + 1,), msg.dtype).at[ids].add(
            live.astype(msg.dtype))
        mbar_tbl = sums / jnp.clip(cnt, 1.0)[:, None]

        mbar = mbar_tbl[ids]                   # (2B, dm)
        upd_fn = gru if cfg.updater == "gru" else rnn
        s_new = upd_fn(params["upd"], mbar, state["mem"][ids])
        mem = state["mem"].at[ids].set(s_new).at[n_dump].set(0.0)
        last = state["last"].at[ids].max(jnp.where(live, ts, 0.0))
        last = last.at[n_dump].set(0.0)

    mem2 = state["mem2"]
    if cfg.flavor == "tige":
        s2_new = rnn(params["upd2"], mbar, state["mem2"][ids])
        mem2 = state["mem2"].at[ids].set(s2_new).at[n_dump].set(0.0)

    b2 = ids.shape[0]
    return {
        "mem": mem,
        "mem2": mem2,
        "last": last,
        "pend_ids": jnp.full((b2,), n_dump, jnp.int32),
        "pend_raw": jnp.zeros_like(raw),
        "pend_t": jnp.zeros_like(ts),
    }


def _stash_messages(cfg: TIGConfig, state: dict, ids_s, ids_d, t, efeat,
                    valid, time_params) -> dict:
    """Compute raw messages for the current batch and stash them (consumed by
    ``flush_pending`` at the start of the next step)."""
    n_dump = state["mem"].shape[0] - 1
    s_i = state["mem"][ids_s]
    s_j = state["mem"][ids_d]
    dt_i = t - state["last"][ids_s]
    dt_j = t - state["last"][ids_d]
    phi_i = time_encode(time_params, dt_i)
    phi_j = time_encode(time_params, dt_j)
    raw_i = jnp.concatenate([s_i, s_j, phi_i, efeat], axis=-1)
    raw_j = jnp.concatenate([s_j, s_i, phi_j, efeat], axis=-1)
    ids = jnp.concatenate([ids_s, ids_d])
    ids = jnp.where(jnp.concatenate([valid, valid]), ids, n_dump)
    return {
        **state,
        "pend_ids": ids.astype(jnp.int32),
        "pend_raw": jnp.concatenate([raw_i, raw_j]),
        "pend_t": jnp.concatenate([t, t]),
    }


# ----------------------------------------------------------------- embedding

def embed_nodes(
    params: dict,
    cfg: TIGConfig,
    state: dict,
    tables: dict,            # {"efeat": (E+1, d_e), "nfeat": (N+1, d_n)}
    ids: jnp.ndarray,        # (B,) local ids (dump row for padding)
    t: jnp.ndarray,          # (B,)
    nbr_ids: jnp.ndarray,    # (B, K) — -1 for empty slots
    nbr_t: jnp.ndarray,      # (B, K)
    nbr_eidx: jnp.ndarray,   # (B, K) — -1 for empty slots
) -> jnp.ndarray:
    """The Embedding module: emb_i(t) from current memory + temporal
    neighborhood (paper Fig.6, right)."""
    n_dump = state["mem"].shape[0] - 1
    s = _read_memory(cfg, state["mem"], state["mem2"], ids)
    nf = tables["nfeat"][ids]
    dt = t - state["last"][ids]

    if cfg.flavor == "jodie":
        # time-projected embedding: (1 + dt*w) ⊙ W[s ; v].  dt enters through
        # log1p so long gaps cannot blow the projection up (timestamps are
        # already mean-gap-normalized upstream).
        base = dense(params["emb"], jnp.concatenate([s, nf], axis=-1))
        dt_n = jnp.log1p(jnp.maximum(dt, 0.0))
        return (1.0 + dt_n[:, None] * params["jodie_w"]) * base
    if cfg.flavor == "dyrep":
        return dense(params["emb"], jnp.concatenate([s, nf], axis=-1))

    # TGN / TIGE: temporal graph attention over K recent neighbors.  The
    # neighbor grids are (B, K) for a single layer or (L, B, K) for the
    # multi-layer fold (one grid per layer; layer l's grid holds the
    # (L-1-l)-th most-recent K-window so the LAST applied layer sees the
    # freshest neighbors — exact n_layers=1 semantics at L=1).
    mask = nbr_ids >= 0
    nids = jnp.where(mask, nbr_ids, n_dump)
    eids = jnp.where(nbr_eidx >= 0, nbr_eidx, tables["efeat"].shape[0] - 1)
    s_nbr = _read_memory(cfg, state["mem"], state["mem2"], nids)
    e_nbr = tables["efeat"][eids]
    # t is (B,): (B, 1) broadcasts against both (B, K) and (L, B, K)
    phi_nbr = time_encode(params["time"],
                          jnp.where(mask, t[:, None] - nbr_t, 0.0))
    phi_self = time_encode(params["time"], jnp.zeros_like(t))
    kv_in = jnp.concatenate([s_nbr, e_nbr, phi_nbr], axis=-1)
    extra = jnp.concatenate([nf, phi_self], axis=-1)
    if nbr_ids.ndim == 3:
        # scan over the stacked layer block: ONE compiled layer, carried
        # query refined per layer (q_in = [h ; nf ; Phi(0)], h0 = memory)
        return stacked_temporal_attention(
            params["attn"], s, extra, kv_in, mask,
            n_heads=cfg.n_heads, backend=cfg.backend)
    q_in = jnp.concatenate([s, extra], axis=-1)
    h = temporal_attention(params["attn"], q_in, kv_in, mask,
                           n_heads=cfg.n_heads, backend=cfg.backend)
    return h


# -------------------------------------------------------------------- step

def step_loss(
    params: dict,
    state: dict,
    batch: dict,
    tables: dict,
    cfg: TIGConfig,
) -> tuple[jnp.ndarray, tuple[dict, dict]]:
    """One training step body: flush pending -> embed -> decode -> loss,
    then stash this batch's messages.  Returns (loss, (new_state, aux)).

    ``batch`` keys: src, dst, neg (B,) int32 local ids (-1 = padding);
    t (B,) f32; efeat (B, d_e); valid (B,) bool; and per role r in
    {src, dst, neg}: nbr_{r} (B,K) ids, nbrt_{r} (B,K) times,
    nbre_{r} (B,K) edge idx — or (L,B,K) each when cfg.n_layers > 1
    (roles concatenate on axis=-2 either way).  Optional: labels (B,)
    int64 (-1 unlabeled).
    """
    n_dump = state["mem"].shape[0] - 1
    valid = batch["valid"]
    remap = lambda x: jnp.where((x >= 0) & valid, x, n_dump).astype(jnp.int32)
    ids_s, ids_d, ids_n = map(remap, (batch["src"], batch["dst"],
                                      batch["neg"]))
    e_dump = tables["efeat"].shape[0] - 1
    efeat = tables["efeat"][jnp.where(batch["eidx"] >= 0,
                                      batch["eidx"], e_dump)]

    # 1) apply previous batch's messages (grads flow into MSG/UPD here)
    state = flush_pending(params, cfg, state)

    # 2) embeddings at time t from the just-updated memory — the three
    # roles share one (3B,)-fused embed call (one attention launch instead
    # of three; row-wise identical math)
    b = ids_s.shape[0]
    ids_all = jnp.concatenate([ids_s, ids_d, ids_n])
    emb_all = embed_nodes(
        params, cfg, state, tables, ids_all,
        jnp.tile(batch["t"], 3),
        jnp.concatenate([batch["nbr_src"], batch["nbr_dst"],
                         batch["nbr_neg"]], axis=-2),
        jnp.concatenate([batch["nbrt_src"], batch["nbrt_dst"],
                         batch["nbrt_neg"]], axis=-2),
        jnp.concatenate([batch["nbre_src"], batch["nbre_dst"],
                         batch["nbre_neg"]], axis=-2),
    )
    embeds = {"src": emb_all[:b], "dst": emb_all[b:2 * b],
              "neg": emb_all[2 * b:]}

    # 3) self-supervised link prediction loss (paper §II-C decoder g) —
    # pos and neg pairs stacked into ONE (2B, 2d) decoder launch
    dec_in = jnp.concatenate([
        jnp.concatenate([embeds["src"], embeds["dst"]], axis=-1),
        jnp.concatenate([embeds["src"], embeds["neg"]], axis=-1)])
    logits = mlp(params["dec"], dec_in)[:, 0]
    pos_logit, neg_logit = logits[:b], logits[b:]
    v = valid.astype(jnp.float32)
    nv = jnp.clip(v.sum(), 1.0)
    bce_pos = jax.nn.softplus(-pos_logit)
    bce_neg = jax.nn.softplus(neg_logit)
    loss = ((bce_pos + bce_neg) * v).sum() / (2.0 * nv)

    # 4) stash this batch's raw messages for the next step
    new_state = _stash_messages(cfg, state, ids_s, ids_d, batch["t"],
                                efeat, valid, params["time"])

    aux = {
        "pos_logit": pos_logit,
        "neg_logit": neg_logit,
        "src_embed": embeds["src"],
        "dst_embed": embeds["dst"],
        "valid": valid,
    }
    return loss, (new_state, aux)
