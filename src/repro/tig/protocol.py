"""Evaluation-protocol subsystem: the single quality path for every trainer.

The paper's downstream claims (Tab.IV link prediction, Tab.V node
classification) are all produced by ONE protocol (§III-A): a chronological
70/15/15 edge split, training on the first 70%, validation-driven model
selection on the next 15%, and final transductive + inductive scoring on the
last 15% with node memory warmed by replaying the earlier splits (params
frozen).  This module owns that protocol end to end so ``train_single``,
``train_sharded``, and ``pac_train`` report through identical code:

  * ``split_bounds`` / ``split_views`` — the chronological split as
    **zero-copy row-range views**: three ``LocalStream``s slicing one set of
    backing id/time columns (numpy basic slicing, no sub-graph copies; for a
    ``ShardedStream`` the per-edge feature table never leaves disk/device),
  * ``inductive_node_mask`` — never-seen-in-train node discovery in one
    chunked pass,
  * ``score_stream`` — forward-only scoring of one chronological stream
    (memory keeps updating) with correctly *valid-aligned* inductive masks,
  * ``run_protocol`` — the replay-to-warm-memory driver: train replays
    through ``engine.make_eval_epoch``, then val/test are scored as scanned
    programs, with ``EpochPrefetcher`` double-buffering split e+1's host
    plan (and device transfer) against split e's scan,
  * ``train_classifier_head`` — the Tab.V dynamic node-classification head
    on frozen interaction-time embeddings.

Splits are views of a shared chronological order, so "train < val < test in
time" holds by construction; the only per-edge allocations are the id/time
columns themselves (8 bytes/edge/column — the feature table is what must
stay out of core, and does).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.tig.batching import LocalStream, build_batch_program, stack_batches
from repro.tig.engine import make_eval_epoch
from repro.tig.evaluation import link_prediction_metrics, roc_auc
from repro.tig.graph import TemporalGraph
from repro.tig.models import TIGConfig, init_state
from repro.tig.stream import EpochPrefetcher, ShardedStream

__all__ = [
    "DEFAULT_CHUNK_EDGES",
    "ProtocolSplits",
    "split_bounds",
    "split_views",
    "inductive_node_mask",
    "time_scale_of",
    "device_batches",
    "score_stream",
    "run_protocol",
    "train_classifier_head",
]

DEFAULT_CHUNK_EDGES = 1 << 20


def time_scale_of(t: np.ndarray) -> float:
    """Mean inter-event gap — timestamps are divided by this so Δt is O(1)
    (keeps Jodie's (1 + Δt·w) projection and Φ's frequency ladder in a sane
    numeric range regardless of the dataset's clock unit)."""
    if len(t) < 2:
        return 1.0
    gaps = np.diff(np.sort(t))
    m = float(gaps.mean())
    return m if m > 0 else 1.0


def split_bounds(
    num_edges: int,
    train_frac: float = 0.70,
    val_frac: float = 0.15,
) -> tuple[int, int]:
    """Row boundaries of the chronological split: rows [0, n_train) train,
    [n_train, n_val_end) validation, [n_val_end, num_edges) test."""
    n_train = int(num_edges * train_frac)
    n_val_end = int(num_edges * (train_frac + val_frac))
    return n_train, n_val_end


def inductive_node_mask(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    *,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
) -> np.ndarray:
    """(N,) bool — nodes that NEVER appear in (src, dst), discovered in one
    chunked pass (works directly on memory-mapped columns: only
    ``chunk_edges`` ids are touched at a time)."""
    seen = np.zeros(num_nodes, dtype=bool)
    for lo in range(0, len(src), chunk_edges):
        seen[np.asarray(src[lo:lo + chunk_edges], np.int64)] = True
        seen[np.asarray(dst[lo:lo + chunk_edges], np.int64)] = True
    return ~seen


@dataclasses.dataclass
class ProtocolSplits:
    """The chronological 70/15/15 protocol split as zero-copy stream views.

    ``train`` / ``val`` / ``test`` are ``LocalStream``s whose arrays are
    slices (views) of one set of backing columns; ``inductive`` marks nodes
    never seen in the train rows; ``neg_pool`` is the full-stream negative
    candidate set (the JODIE/TGN convention).  ``bounds`` are the
    (n_train, n_val_end) row boundaries within [0, num_edges).
    """

    train: LocalStream
    val: LocalStream
    test: LocalStream
    inductive: np.ndarray          # (N,) bool
    neg_pool: np.ndarray
    bounds: tuple[int, int]
    num_nodes: int
    num_edges: int
    time_scale: float
    name: str = "tig"

    @property
    def views(self) -> tuple[LocalStream, LocalStream, LocalStream]:
        return (self.train, self.val, self.test)

    def inductive_edge_mask(self, view: LocalStream) -> np.ndarray:
        """Per-edge mask of ``view``: edge touches a never-seen-in-train
        node (the paper's inductive link-prediction subset)."""
        return self.inductive[view.src] | self.inductive[view.dst]


def split_views(
    source: Union[ShardedStream, TemporalGraph],
    train_frac: float = 0.70,
    val_frac: float = 0.15,
    *,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
) -> ProtocolSplits:
    """Chronological 70/15/15 split of a stream as zero-copy row-range views.

    ``source`` is an in-memory ``TemporalGraph`` or an out-of-core
    ``ShardedStream``.  Only the id/label/time columns are materialized
    (8 bytes/edge each; for shards this is the same cost the trainers
    already pay) — edge features are NOT touched, and the three splits are
    numpy views into the shared columns, not sub-graph copies.  Timestamps
    are rescaled to mean-gap units (``time_scale_of``) exactly as the
    trainers do, so plans built from these views are interchangeable with
    the trainers' own.
    """
    if isinstance(source, ShardedStream):
        src = source.column("src")
        dst = source.column("dst")
        t = source.column("t")
        labels = source.column("label") if source.has_labels else None
        num_nodes, name = source.num_nodes, source.name
    elif isinstance(source, TemporalGraph):
        src = np.asarray(source.src, np.int64)
        dst = np.asarray(source.dst, np.int64)
        t = np.asarray(source.t, np.float64)
        labels = source.labels
        num_nodes, name = source.num_nodes, source.name
    else:
        raise TypeError(
            f"split_views needs a ShardedStream or TemporalGraph, got "
            f"{type(source).__name__}")

    scale = time_scale_of(t)
    t = t / scale
    num_edges = len(src)
    eidx = np.arange(num_edges, dtype=np.int64)
    n_train, n_val_end = split_bounds(num_edges, train_frac, val_frac)

    def view(lo: int, hi: int) -> LocalStream:
        return LocalStream(
            src=src[lo:hi], dst=dst[lo:hi], t=t[lo:hi], eidx=eidx[lo:hi],
            num_local_nodes=num_nodes,
            labels=None if labels is None else labels[lo:hi],
        )

    return ProtocolSplits(
        train=view(0, n_train),
        val=view(n_train, n_val_end),
        test=view(n_val_end, num_edges),
        inductive=inductive_node_mask(src[:n_train], dst[:n_train],
                                      num_nodes, chunk_edges=chunk_edges),
        neg_pool=np.unique(dst),
        bounds=(n_train, n_val_end),
        num_nodes=num_nodes,
        num_edges=num_edges,
        time_scale=scale,
        name=name,
    )


def device_batches(stacked_or_list) -> dict:
    """Accept either a (steps, ...) pytree or a list of per-batch dicts and
    return a jnp (steps, ...) pytree without host-side labels."""
    stacked = stacked_or_list
    if isinstance(stacked, (list, tuple)):
        stacked = stack_batches(list(stacked))
    return {k: jnp.asarray(v) for k, v in stacked.items() if k != "labels"}


def score_stream(
    params,
    cfg: TIGConfig,
    state,
    batches,
    tables_j,
    eval_epoch_fn,
    inductive_edge_mask: Optional[np.ndarray] = None,
    collect_embeddings: bool = False,
    device_batches_j: Optional[dict] = None,
    tcsr: Optional[dict] = None,
):
    """Run a chronological stream through the model (memory keeps updating,
    params frozen) as one scanned program and compute link-prediction
    metrics.

    ``batches`` is a (steps, ...) pytree (or legacy list) that still carries
    the host-side ``valid`` / ``labels`` entries; ``eval_epoch_fn`` comes
    from ``engine.make_eval_epoch``; ``device_batches_j`` optionally hands in
    the already-staged device pytree (e.g. from an ``EpochPrefetcher``
    worker).  ``inductive_edge_mask`` is aligned THROUGH ``valid``: it may
    have one entry per grid row (steps*B — filtered with ``valid``) or one
    per scored edge (``valid.sum()``); any other length raises instead of
    silently truncating against the valid-filtered logits.

    With ``tcsr`` (a staged ``ChronoNeighborIndex.device_export`` dict for
    THIS stream, history included) ``batches`` is a raw-edge
    ``plan="device"`` program: the scan samples each step's neighbor grids
    on device instead of reading pre-staged ones.

    Returns dict with transductive AP/AUROC, inductive AP/AUROC when a mask
    is given, optional collected src embeddings + labels, and the
    post-stream state (for continuing into the next split).
    """
    if isinstance(batches, (list, tuple)):
        batches = stack_batches(list(batches))
    bj = device_batches_j if device_batches_j is not None \
        else device_batches(batches)
    if tcsr is None:
        state, aux = eval_epoch_fn(params, state, bj, tables_j)
    else:
        state, aux = eval_epoch_fn(params, state, bj, tables_j, tcsr=tcsr)

    valid = np.asarray(batches["valid"]).reshape(-1)      # (steps*B,)
    pos = np.asarray(aux["pos_logit"]).reshape(-1)[valid]
    neg = np.asarray(aux["neg_logit"]).reshape(-1)[valid]
    mask = None
    if inductive_edge_mask is not None:
        mask = np.asarray(inductive_edge_mask, dtype=bool).reshape(-1)
        if mask.shape[0] == valid.shape[0]:
            mask = mask[valid]                  # grid-shaped: drop padding
        elif mask.shape[0] != len(pos):
            raise ValueError(
                f"inductive_edge_mask has {mask.shape[0]} entries; expected "
                f"one per scored edge ({len(pos)}) or one per grid row "
                f"({valid.shape[0]})")
    out = link_prediction_metrics(pos, neg, inductive_mask=mask)
    out["state"] = state
    if collect_embeddings:
        if "src_embed" not in aux:
            raise ValueError(
                "collect_embeddings=True needs an eval program built with "
                "make_eval_epoch(cfg, collect_embeddings=True)")
        emb = np.asarray(aux["src_embed"])
        out["embeddings"] = emb.reshape(-1, emb.shape[-1])[valid]
        if "labels" in batches:
            out["labels"] = np.asarray(batches["labels"]).reshape(-1)[valid]
        else:
            out["labels"] = None
    return out


def run_protocol(
    params,
    cfg: TIGConfig,
    splits: ProtocolSplits,
    tables_j: dict,
    *,
    seed: int = 0,
    eval_node_class: bool = False,
    prefetch: bool = True,
    depth: int = 1,
    state=None,
    replay_train: bool = True,
    warm: Optional[str] = None,
    restarter=None,
) -> dict:
    """The replay-to-warm-memory scoring driver (paper Tab.IV/V protocol).

    Replays the train split through the forward-only scanned program to
    build node memory (no parameter updates), then scores val and test —
    each a continuation of the previous split's memory and neighbor
    history.  The three splits run as a 3-stage pipeline: while split e's
    ``lax.scan`` executes, split e+1's host plan is built AND moved to
    device on the ``EpochPrefetcher`` worker (plans are serial on one
    worker, so the neighbor-history handoff and the shared negative-
    sampling RNG see the exact in-order call sequence — prefetch on/off,
    at any pipeline ``depth``, is bit-identical).

    ``warm`` names the memory warm-up strategy explicitly:

      * ``"replay"``  — the oracle: replay the train split on device to
        build memory (the default, equivalent to ``replay_train=True``);
      * ``"state"``   — the caller supplies post-train memory via
        ``state`` (e.g. PAC's synchronized per-device memories merged
        back to global rows; equivalent to ``replay_train=False``);
      * ``"restart"`` — TIGER-style replayless warm-up: memory is
        reconstructed in O(N) by the fitted ``restarter`` bundle
        (``tig.restart.build_restarter``) instead of the O(E) replay.
        Metrics agree with the replay oracle within tolerance, not bits
        (head fit error + the final batch's dropped pending messages).

    With ``warm != "replay"`` the device replay of the train split is
    skipped: only the neighbor history is reconstructed host-side from the
    train rows, and scoring starts directly at val.  ``train_ap`` is then
    NaN.  The legacy ``replay_train`` / ``state`` kwargs remain supported
    (``warm=None`` infers ``"replay"`` or ``"state"`` from them).

    Returns a flat metric dict: ``val_ap``/``val_auc``/``test_ap``/
    ``test_auc`` (+ ``*_ap_inductive``/``*_auc_inductive`` over edges
    touching never-seen-in-train nodes), ``train_ap`` (the replay's own
    score, a sanity signal), and ``node_auroc`` (NaN unless
    ``eval_node_class`` and the stream carries labels).
    """
    if warm is None:
        warm = "replay" if replay_train else "state"
    if warm not in ("replay", "state", "restart"):
        raise ValueError(f"warm={warm!r}: expected 'replay', 'state' or "
                         "'restart'")
    if warm == "restart":
        if restarter is None:
            raise ValueError("warm='restart' needs a fitted restarter "
                             "bundle (tig.restart.build_restarter)")
        from repro.tig.restart import restart_memory

        state = restart_memory(restarter, splits.num_nodes, tables_j)
    elif warm == "state" and state is None:
        raise ValueError("warm='state' needs the post-train memory via "
                         "state=")
    replay_train = warm == "replay"

    rng = np.random.default_rng(seed)
    eval_fn = make_eval_epoch(cfg)
    eval_fn_test = make_eval_epoch(cfg, collect_embeddings=True) \
        if eval_node_class else eval_fn
    views = list(splits.views)
    names = ["train", "val", "test"]
    hist = [None]
    if not replay_train:
        from repro.tig.sampler import ChronoNeighborIndex

        # the host-side half of the train replay: neighbor history as of
        # the end of the train rows (the device half — memory — comes from
        # the caller's ``state``)
        tr = views[0]
        hist[0] = ChronoNeighborIndex(
            tr.src, tr.dst, tr.t, tr.eidx, splits.num_nodes,
            cfg.num_neighbors, cfg.batch_size).final_snapshot()
        views, names = views[1:], names[1:]

    def build(i: int) -> dict:
        batches, hist[0] = build_batch_program(
            views[i], cfg, rng, history=hist[0], neg_pool=splits.neg_pool)
        return batches

    if state is None:
        state = init_state(cfg, splits.num_nodes)
    results = {}
    with EpochPrefetcher(build, len(views),
                         to_device=lambda b: (b, device_batches(b)),
                         enabled=prefetch, depth=depth) as pf:
        for i, view in enumerate(views):
            host, dev = pf.get(i)
            is_test = names[i] == "test"
            res = score_stream(
                params, cfg, state, host, tables_j,
                eval_fn_test if is_test else eval_fn,
                inductive_edge_mask=None if names[i] == "train"
                else splits.inductive_edge_mask(view),
                collect_embeddings=(is_test and eval_node_class),
                device_batches_j=dev,
            )
            state = res["state"]
            results[names[i]] = res

    nan = float("nan")
    va, te = results["val"], results["test"]
    out = {
        "train_ap": results["train"]["ap"] if replay_train else nan,
        "val_ap": va["ap"],
        "val_auc": va["auc"],
        "val_ap_inductive": va.get("ap_inductive", nan),
        "val_auc_inductive": va.get("auc_inductive", nan),
        "test_ap": te["ap"],
        "test_auc": te["auc"],
        "test_ap_inductive": te.get("ap_inductive", nan),
        "test_auc_inductive": te.get("auc_inductive", nan),
        "node_auroc": nan,
    }
    if eval_node_class and te.get("embeddings") is not None \
            and te.get("labels") is not None:
        mx = -1
        for v in splits.views:
            if v.labels is not None and (v.labels >= 0).any():
                mx = max(mx, int(v.labels[v.labels >= 0].max()))
        if mx >= 0:
            out["node_auroc"] = train_classifier_head(
                te["embeddings"], te["labels"], max(mx + 1, 2))
    return out


def train_classifier_head(
    embeds: np.ndarray,
    labels: np.ndarray,
    n_classes: int,
    *,
    seed: int = 0,
    steps: int = 300,
    lr: float = 1e-2,
) -> float:
    """Dynamic node classification (paper Tab.V): train a small MLP head on
    frozen interaction-time embeddings, report AUROC on a chronological
    70/30 split.  Multi-class -> macro one-vs-rest AUROC."""
    from repro.optim import adamw
    from repro.tig.modules import mlp, mlp_init

    keep = labels >= 0
    embeds, labels = embeds[keep], labels[keep]
    n = len(labels)
    if n < 10 or len(np.unique(labels)) < 2:
        return float("nan")
    cut = int(n * 0.7)
    x_tr = jnp.asarray(embeds[:cut])
    y_tr = jnp.asarray(labels[:cut])
    params = mlp_init(jax.random.PRNGKey(seed),
                      [embeds.shape[1], 64, n_classes])
    opt = adamw(lr=lr)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        def loss_fn(p):
            logits = mlp(p, x_tr)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y_tr[:, None], 1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, loss

    for _ in range(steps):
        params, opt_state, _ = step(params, opt_state)

    logits = np.asarray(mlp(params, jnp.asarray(embeds[cut:])))
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    y_te = labels[cut:]
    if n_classes == 2:
        return roc_auc(y_te == 1, probs[:, 1])
    aucs = []
    for c in range(n_classes):
        if (y_te == c).any() and (y_te != c).any():
            aucs.append(roc_auc(y_te == c, probs[:, c]))
    return float(np.mean(aucs)) if aucs else float("nan")
