"""TGAT-style functional time encoding Phi (paper §II-C, [3]).

    Phi(dt) = cos(dt * w + b),   w_k = 1 / 10^{alpha * k / d}

The geometric frequency ladder covers time scales from seconds to months;
``w`` and ``b`` are trainable (initialized to the TGAT values).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["init_time_encoder", "time_encode"]


def init_time_encoder(dim: int, max_scale: float = 9.0) -> dict:
    """Trainable params for a ``dim``-dimensional time encoding."""
    w = 1.0 / np.power(10.0, max_scale * np.arange(dim) / max(dim - 1, 1))
    return {
        "w": jnp.asarray(w, dtype=jnp.float32),
        "b": jnp.zeros((dim,), dtype=jnp.float32),
    }


def time_encode(params: dict, dt: jnp.ndarray) -> jnp.ndarray:
    """Phi(dt): shape (..., dim) for dt of shape (...)."""
    return jnp.cos(dt[..., None] * params["w"] + params["b"])
