"""Single-device TIG training & evaluation (the paper's non-partitioned
baseline — 'Single-GPU' / 'w/o Partitioning' rows of Tab.III/IV).

Epochs run through the device-resident streaming engine
(``repro.tig.engine``): host planning pre-stages the whole chronological
stream as one (steps, ...) batch pytree, and a single jitted ``lax.scan``
executes the epoch on device.  The distributed PAC trainer
(``repro.tig.distributed``) drives the same scan program.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, Optimizer
from repro.tig.batching import (
    LocalStream,
    build_batch_program,
    make_tables,
    stack_batches,
)
from repro.tig.engine import make_eval_epoch, make_train_epoch
from repro.tig.stream import EpochPrefetcher
from repro.tig.evaluation import average_precision, roc_auc
from repro.tig.graph import TemporalGraph
from repro.tig.models import TIGConfig, init_params, init_state, step_loss

__all__ = [
    "graph_as_stream",
    "make_train_step",
    "make_eval_step",
    "train_epoch",
    "evaluate_stream",
    "train_single",
    "train_sharded",
    "train_classifier_head",
    "epoch_rng",
]


def epoch_rng(seed: int, epoch: int, role: int = 0) -> np.random.Generator:
    """Independent generator per (seed, epoch, role) — epoch plans drawn
    from dedicated streams, so prefetched (out-of-order) planning produces
    bit-identical draws to serial planning."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, role, epoch]))


def time_scale_of(t: np.ndarray) -> float:
    """Mean inter-event gap — timestamps are divided by this so Δt is O(1)
    (keeps Jodie's (1 + Δt·w) projection and Φ's frequency ladder in a sane
    numeric range regardless of the dataset's clock unit)."""
    if len(t) < 2:
        return 1.0
    gaps = np.diff(np.sort(t))
    m = float(gaps.mean())
    return m if m > 0 else 1.0


def graph_as_stream(g: TemporalGraph) -> tuple[LocalStream, dict]:
    """Treat the whole graph as one device-local stream (ids unchanged).

    Timestamps are rescaled to mean-gap units (see ``time_scale_of``)."""
    scale = time_scale_of(g.t)
    stream = LocalStream(
        src=g.src.astype(np.int64),
        dst=g.dst.astype(np.int64),
        t=g.t / scale,
        eidx=np.arange(g.num_edges, dtype=np.int64),
        num_local_nodes=g.num_nodes,
        labels=g.labels,
    )
    return stream, make_tables(g.edge_feat, g.node_feat)


def _device_batches(stacked_or_list) -> dict:
    """Accept either a (steps, ...) pytree or a list of per-batch dicts and
    return a jnp (steps, ...) pytree without host-side labels."""
    stacked = stacked_or_list
    if isinstance(stacked, (list, tuple)):
        stacked = stack_batches(list(stacked))
    return {k: jnp.asarray(v) for k, v in stacked.items() if k != "labels"}


def make_train_step(cfg: TIGConfig, opt: Optimizer):
    """jit'd per-batch step (params, opt_state, state, batch, tables) ->
    updated + loss.  The epoch hot path uses ``engine.make_train_epoch``;
    this single-step variant remains for debugging and parity tests."""

    @jax.jit
    def step(params, opt_state, state, batch, tables):
        (loss, (new_state, _aux)), grads = jax.value_and_grad(
            step_loss, has_aux=True
        )(params, state, batch, tables, cfg)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, new_state, loss

    return step


def make_eval_step(cfg: TIGConfig):
    """jit'd forward-only step: returns (new_state, aux) with logits."""

    @jax.jit
    def step(params, state, batch, tables):
        _loss, (new_state, aux) = step_loss(params, state, batch, tables, cfg)
        return new_state, aux

    return step


def train_epoch(params, opt_state, state, batches, tables_j, epoch_fn):
    """One pass over prepared batches as a single scanned device program.

    ``batches`` is a (steps, ...) pytree (or a legacy list of per-batch
    dicts); ``epoch_fn`` comes from ``engine.make_train_epoch``.  Returns
    mean loss over steps.
    """
    bj = _device_batches(batches)
    params, opt_state, state, losses = epoch_fn(
        params, opt_state, state, bj, tables_j)
    return params, opt_state, state, float(jnp.mean(losses))


def evaluate_stream(
    params,
    cfg: TIGConfig,
    state,
    batches,
    tables_j,
    eval_epoch_fn,
    inductive_edge_mask: Optional[np.ndarray] = None,
    collect_embeddings: bool = False,
):
    """Run a chronological stream through the model (memory keeps updating,
    params frozen) as one scanned program and compute link-prediction AP.

    ``batches`` is a (steps, ...) pytree (or legacy list) that still carries
    the host-side ``valid`` / ``labels`` entries; ``eval_epoch_fn`` comes
    from ``engine.make_eval_epoch``.  Returns dict with transductive AP/AUC,
    optional inductive AP (edges touching never-seen-in-train nodes),
    optional collected src embeddings, and the post-stream state (for
    continuing to the next split).
    """
    if isinstance(batches, (list, tuple)):
        batches = stack_batches(list(batches))
    bj = _device_batches(batches)
    state, aux = eval_epoch_fn(params, state, bj, tables_j)

    valid = np.asarray(batches["valid"]).reshape(-1)      # (steps*B,)
    pos = np.asarray(aux["pos_logit"]).reshape(-1)[valid]
    neg = np.asarray(aux["neg_logit"]).reshape(-1)[valid]
    y = np.concatenate([np.ones_like(pos), np.zeros_like(neg)])
    s = np.concatenate([pos, neg])
    out = {
        "ap": average_precision(y, s),
        "auc": roc_auc(y, s),
        "state": state,
    }
    if inductive_edge_mask is not None:
        m = np.asarray(inductive_edge_mask[: len(pos)]).astype(bool)
        if m.any():
            y_i = np.concatenate([np.ones(m.sum()), np.zeros(m.sum())])
            s_i = np.concatenate([pos[m], neg[m]])
            out["ap_inductive"] = average_precision(y_i, s_i)
        else:
            out["ap_inductive"] = float("nan")
    if collect_embeddings:
        if "src_embed" not in aux:
            raise ValueError(
                "collect_embeddings=True needs an eval program built with "
                "make_eval_epoch(cfg, collect_embeddings=True)")
        emb = np.asarray(aux["src_embed"])
        out["embeddings"] = emb.reshape(-1, emb.shape[-1])[valid]
        if "labels" in batches:
            out["labels"] = np.asarray(batches["labels"]).reshape(-1)[valid]
        else:
            out["labels"] = None
    return out


@dataclasses.dataclass
class ShardedResult:
    losses: list[float]
    epoch_seconds: list[float]
    params: dict
    state: dict
    cfg: TIGConfig


def train_sharded(
    shards,
    cfg: TIGConfig,
    *,
    epochs: int = 2,
    lr: float = 1e-3,
    seed: int = 0,
    prefetch: bool = True,
) -> ShardedResult:
    """Out-of-core training over a ``tig-shards-v1`` stream (whole stream
    as the train split; quality evaluation stays with ``train_single``).

    The full data plane is chunked: id columns materialize at 8 bytes/edge,
    the edge-feature table is staged shard-by-shard into a donated device
    buffer (the host never holds all rows), the temporal neighbor index is
    built with the chunked T-CSR merge, and epoch plans are prefetched on
    a worker thread while the previous epoch's scan runs.
    """
    from repro.tig.sampler import ChronoNeighborIndex
    from repro.tig.stream import stage_device_tables

    src = shards.column("src")
    dst = shards.column("dst")
    t = shards.column("t")
    scale = time_scale_of(t)
    stream = LocalStream(
        src=src.astype(np.int64),
        dst=dst.astype(np.int64),
        t=t / scale,
        eidx=np.arange(len(src), dtype=np.int64),
        num_local_nodes=shards.num_nodes,
        labels=None,
    )

    def scaled_chunks():
        for c_src, c_dst, c_t, c_eidx in shards.edge_chunks():
            yield c_src, c_dst, c_t / scale, c_eidx

    # index is epoch-invariant (same stream, no history): chunked build once
    index = ChronoNeighborIndex.from_chunks(
        scaled_chunks, shards.num_nodes, cfg.num_neighbors, cfg.batch_size)

    tables_j = stage_device_tables(shards)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)
    epoch_fn = make_train_epoch(cfg, opt)
    neg_pool = np.unique(stream.dst)

    pf = EpochPrefetcher(
        lambda ep: build_batch_program(
            stream, cfg, epoch_rng(seed, ep, 1), neg_pool=neg_pool,
            index=index)[0],
        epochs,
        to_device=_device_batches,
        enabled=prefetch,
    )
    losses, epoch_secs = [], []
    state = None
    for ep in range(epochs):
        t0 = time.perf_counter()
        batches = pf.get(ep)
        state = init_state(cfg, shards.num_nodes)
        params, opt_state, state, loss = train_epoch(
            params, opt_state, state, batches, tables_j, epoch_fn)
        epoch_secs.append(time.perf_counter() - t0)
        losses.append(loss)

    return ShardedResult(
        losses=losses,
        epoch_seconds=epoch_secs,
        params=params,
        state=state,
        cfg=cfg,
    )


def train_classifier_head(
    embeds: np.ndarray,
    labels: np.ndarray,
    n_classes: int,
    *,
    seed: int = 0,
    steps: int = 300,
    lr: float = 1e-2,
) -> float:
    """Dynamic node classification (paper Tab.V): train a small MLP head on
    frozen interaction-time embeddings, report AUROC on a chronological
    70/30 split.  Multi-class -> macro one-vs-rest AUROC."""
    from repro.tig.modules import mlp, mlp_init

    keep = labels >= 0
    embeds, labels = embeds[keep], labels[keep]
    n = len(labels)
    if n < 10 or len(np.unique(labels)) < 2:
        return float("nan")
    cut = int(n * 0.7)
    x_tr = jnp.asarray(embeds[:cut])
    y_tr = jnp.asarray(labels[:cut])
    params = mlp_init(jax.random.PRNGKey(seed),
                      [embeds.shape[1], 64, n_classes])
    opt = adamw(lr=lr)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        def loss_fn(p):
            logits = mlp(p, x_tr)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y_tr[:, None], 1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, loss

    for _ in range(steps):
        params, opt_state, _ = step(params, opt_state)

    logits = np.asarray(mlp(params, jnp.asarray(embeds[cut:])))
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    y_te = labels[cut:]
    if n_classes == 2:
        return roc_auc(y_te == 1, probs[:, 1])
    aucs = []
    for c in range(n_classes):
        if (y_te == c).any() and (y_te != c).any():
            aucs.append(roc_auc(y_te == c, probs[:, c]))
    return float(np.mean(aucs)) if aucs else float("nan")


def evaluate_params(
    g: TemporalGraph,
    cfg: TIGConfig,
    params: dict,
    *,
    seed: int = 0,
    eval_node_class: bool = False,
) -> dict:
    """Evaluate (PAC-)trained parameters on the standard protocol: replay the
    train split to build memory (no parameter updates), then score val/test
    link prediction (+ optional node classification).  This is how the
    partition-trained rows of Tab.IV/V are produced."""
    from repro.tig.graph import chronological_split

    rng = np.random.default_rng(seed)
    train_g, val_g, test_g, inductive_nodes = chronological_split(g)
    ind = np.zeros(g.num_nodes, dtype=bool)
    ind[inductive_nodes] = True

    stream, tables = graph_as_stream(g)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    n_tr, n_val = train_g.num_edges, val_g.num_edges

    def sub(lo, hi):
        return LocalStream(
            src=stream.src[lo:hi], dst=stream.dst[lo:hi],
            t=stream.t[lo:hi], eidx=stream.eidx[lo:hi],
            num_local_nodes=g.num_nodes,
            labels=None if g.labels is None else g.labels[lo:hi],
        )

    eval_fn = make_eval_epoch(cfg)
    eval_fn_test = make_eval_epoch(cfg, collect_embeddings=True) \
        if eval_node_class else eval_fn
    neg_pool = np.unique(stream.dst)
    state = init_state(cfg, g.num_nodes)

    tr_batches, hist = build_batch_program(
        sub(0, n_tr), cfg, rng, neg_pool=neg_pool)
    res_tr = evaluate_stream(params, cfg, state, tr_batches, tables_j,
                             eval_fn)
    val_batches, hist = build_batch_program(
        sub(n_tr, n_tr + n_val), cfg, rng, history=hist, neg_pool=neg_pool)
    res_val = evaluate_stream(params, cfg, res_tr["state"], val_batches,
                              tables_j, eval_fn)
    test_stream = sub(n_tr + n_val, g.num_edges)
    ind_mask = ind[test_stream.src] | ind[test_stream.dst]
    test_batches, _ = build_batch_program(
        test_stream, cfg, rng, history=hist, neg_pool=neg_pool)
    res_test = evaluate_stream(
        params, cfg, res_val["state"], test_batches, tables_j, eval_fn_test,
        inductive_edge_mask=ind_mask, collect_embeddings=eval_node_class)

    out = {
        "val_ap": res_val["ap"],
        "test_ap": res_test["ap"],
        "test_ap_inductive": res_test.get("ap_inductive", float("nan")),
        "node_auroc": float("nan"),
    }
    if eval_node_class and res_test.get("embeddings") is not None \
            and res_test.get("labels") is not None \
            and g.labels is not None:
        n_classes = int(g.labels[g.labels >= 0].max()) + 1
        out["node_auroc"] = train_classifier_head(
            res_test["embeddings"], res_test["labels"], max(n_classes, 2))
    return out


@dataclasses.dataclass
class SingleResult:
    val_ap: float
    test_ap: float
    test_ap_inductive: float
    node_auroc: float
    epoch_seconds: list[float]
    losses: list[float]
    params: dict
    state: dict
    cfg: TIGConfig


def train_single(
    g: TemporalGraph,
    cfg: TIGConfig,
    *,
    epochs: int = 3,
    lr: float = 1e-3,
    seed: int = 0,
    eval_node_class: bool = False,
    prefetch: bool = True,
) -> SingleResult:
    """The paper's single-device baseline trainer: chronological 70/15/15
    split, memory reset per epoch, val/test continue the epoch-end memory.

    Each epoch is one host-planning pass (vectorized neighbor index + batch
    grid) followed by one scanned device program.  With ``prefetch`` (the
    default) epoch e+1's plan is built — and moved to device — on a worker
    thread while epoch e's scan runs; per-epoch RNG streams make the
    result bit-identical to serial planning."""
    from repro.tig.graph import chronological_split

    train_g, val_g, test_g, inductive_nodes = chronological_split(g)
    ind = np.zeros(g.num_nodes, dtype=bool)
    ind[inductive_nodes] = True

    stream, tables = graph_as_stream(g)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    n_tr = train_g.num_edges
    n_val = val_g.num_edges

    def sub(lo, hi):
        return LocalStream(
            src=stream.src[lo:hi], dst=stream.dst[lo:hi],
            t=stream.t[lo:hi], eidx=stream.eidx[lo:hi],
            num_local_nodes=g.num_nodes,
            labels=None if g.labels is None else g.labels[lo:hi],
        )

    tr_stream = sub(0, n_tr)
    val_stream = sub(n_tr, n_tr + n_val)
    test_stream = sub(n_tr + n_val, g.num_edges)

    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)
    epoch_fn = make_train_epoch(cfg, opt)
    eval_fn = make_eval_epoch(cfg)
    eval_fn_test = make_eval_epoch(cfg, collect_embeddings=True) \
        if eval_node_class else eval_fn

    neg_pool = np.unique(stream.dst)
    epoch_secs, losses = [], []
    best = {"val_ap": -1.0}

    # double-buffered host planning: epoch e+1's train plan is built and
    # device-put on a worker thread while epoch e's scan executes.
    pf = EpochPrefetcher(
        lambda ep: build_batch_program(
            tr_stream, cfg, epoch_rng(seed, ep, 1), neg_pool=neg_pool),
        epochs,
        to_device=lambda plan: (_device_batches(plan[0]), plan[1]),
        enabled=prefetch,
    )
    for ep in range(epochs):
        t0 = time.perf_counter()
        tr_batches, hist = pf.get(ep)
        state = init_state(cfg, g.num_nodes)  # Alg.2: reset at cycle start
        params, opt_state, state, loss = train_epoch(
            params, opt_state, state, tr_batches, tables_j, epoch_fn)
        epoch_secs.append(time.perf_counter() - t0)
        losses.append(loss)

        # validation continues from epoch-end memory + neighbor index
        val_batches, hist_val = build_batch_program(
            val_stream, cfg, epoch_rng(seed, ep, 2), history=hist,
            neg_pool=neg_pool)
        res_val = evaluate_stream(params, cfg, state, val_batches,
                                  tables_j, eval_fn)
        if res_val["ap"] > best["val_ap"]:
            ind_mask = (ind[test_stream.src] | ind[test_stream.dst])
            test_batches, _ = build_batch_program(
                test_stream, cfg, epoch_rng(seed, ep, 3),
                history=hist_val, neg_pool=neg_pool)
            res_test = evaluate_stream(
                params, cfg, res_val["state"], test_batches, tables_j,
                eval_fn_test, inductive_edge_mask=ind_mask,
                collect_embeddings=eval_node_class,
            )
            best = {
                "val_ap": res_val["ap"],
                "test_ap": res_test["ap"],
                "test_ap_inductive": res_test.get("ap_inductive",
                                                  float("nan")),
                "test_res": res_test,
            }

    node_auroc = float("nan")
    if eval_node_class and g.labels is not None:
        res_test = best["test_res"]
        if res_test.get("embeddings") is not None \
                and res_test.get("labels") is not None:
            n_classes = int(g.labels[g.labels >= 0].max()) + 1
            node_auroc = train_classifier_head(
                res_test["embeddings"], res_test["labels"],
                max(n_classes, 2))

    return SingleResult(
        val_ap=best["val_ap"],
        test_ap=best["test_ap"],
        test_ap_inductive=best["test_ap_inductive"],
        node_auroc=node_auroc,
        epoch_seconds=epoch_secs,
        losses=losses,
        params=params,
        state=state,
        cfg=cfg,
    )
