"""Single-device TIG training & evaluation (the paper's non-partitioned
baseline — 'Single-GPU' / 'w/o Partitioning' rows of Tab.III/IV).

The distributed PAC trainer (multi-device) is ``repro.tig.distributed``; it
reuses the step functions defined here.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, Optimizer
from repro.tig.batching import (
    LocalStream,
    build_batches,
    make_tables,
)
from repro.tig.evaluation import average_precision, roc_auc
from repro.tig.graph import TemporalGraph
from repro.tig.models import TIGConfig, init_params, init_state, step_loss
from repro.tig.sampler import RecentNeighborBuffer

__all__ = [
    "graph_as_stream",
    "make_train_step",
    "make_eval_step",
    "train_epoch",
    "evaluate_stream",
    "train_single",
    "train_classifier_head",
]


def time_scale_of(t: np.ndarray) -> float:
    """Mean inter-event gap — timestamps are divided by this so Δt is O(1)
    (keeps Jodie's (1 + Δt·w) projection and Φ's frequency ladder in a sane
    numeric range regardless of the dataset's clock unit)."""
    if len(t) < 2:
        return 1.0
    gaps = np.diff(np.sort(t))
    m = float(gaps.mean())
    return m if m > 0 else 1.0


def graph_as_stream(g: TemporalGraph) -> tuple[LocalStream, dict]:
    """Treat the whole graph as one device-local stream (ids unchanged).

    Timestamps are rescaled to mean-gap units (see ``time_scale_of``)."""
    scale = time_scale_of(g.t)
    stream = LocalStream(
        src=g.src.astype(np.int64),
        dst=g.dst.astype(np.int64),
        t=g.t / scale,
        eidx=np.arange(g.num_edges, dtype=np.int64),
        num_local_nodes=g.num_nodes,
        labels=g.labels,
    )
    return stream, make_tables(g.edge_feat, g.node_feat)


def make_train_step(cfg: TIGConfig, opt: Optimizer):
    """jit'd (params, opt_state, state, batch, tables) -> updated + loss."""

    @jax.jit
    def step(params, opt_state, state, batch, tables):
        (loss, (new_state, _aux)), grads = jax.value_and_grad(
            step_loss, has_aux=True
        )(params, state, batch, tables, cfg)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, new_state, loss

    return step


def make_eval_step(cfg: TIGConfig):
    """jit'd forward-only step: returns (new_state, aux) with logits."""

    @jax.jit
    def step(params, state, batch, tables):
        _loss, (new_state, aux) = step_loss(params, state, batch, tables, cfg)
        return new_state, aux

    return step


def train_epoch(params, opt_state, state, batches, tables_j, step_fn):
    """One pass over prepared batches; returns mean loss."""
    losses = []
    for batch in batches:
        bj = {k: jnp.asarray(v) for k, v in batch.items() if k != "labels"}
        params, opt_state, state, loss = step_fn(
            params, opt_state, state, bj, tables_j)
        losses.append(float(loss))
    return params, opt_state, state, float(np.mean(losses))


def evaluate_stream(
    params,
    cfg: TIGConfig,
    state,
    batches,
    tables_j,
    eval_step,
    inductive_edge_mask: Optional[np.ndarray] = None,
    collect_embeddings: bool = False,
):
    """Run a chronological stream through the model (memory keeps updating,
    params frozen) and compute link-prediction AP.

    Returns dict with transductive AP/AUC, optional inductive AP (edges
    touching never-seen-in-train nodes), optional collected src embeddings,
    and the post-stream state (for continuing to the next split).
    """
    pos_all, neg_all, ind_mask_all, embeds, labels = [], [], [], [], []
    offset = 0
    for batch in batches:
        bj = {k: jnp.asarray(v) for k, v in batch.items() if k != "labels"}
        state, aux = eval_step(params, state, bj, tables_j)
        valid = np.asarray(batch["valid"])
        n = int(valid.sum())
        pos_all.append(np.asarray(aux["pos_logit"])[:n])
        neg_all.append(np.asarray(aux["neg_logit"])[:n])
        if inductive_edge_mask is not None:
            ind_mask_all.append(inductive_edge_mask[offset: offset + n])
        if collect_embeddings:
            embeds.append(np.asarray(aux["src_embed"])[:n])
            if "labels" in batch:
                labels.append(np.asarray(batch["labels"])[:n])
        offset += n
    pos = np.concatenate(pos_all)
    neg = np.concatenate(neg_all)
    y = np.concatenate([np.ones_like(pos), np.zeros_like(neg)])
    s = np.concatenate([pos, neg])
    out = {
        "ap": average_precision(y, s),
        "auc": roc_auc(y, s),
        "state": state,
    }
    if inductive_edge_mask is not None:
        m = np.concatenate(ind_mask_all).astype(bool)
        if m.any():
            y_i = np.concatenate([np.ones(m.sum()), np.zeros(m.sum())])
            s_i = np.concatenate([pos[m], neg[m]])
            out["ap_inductive"] = average_precision(y_i, s_i)
        else:
            out["ap_inductive"] = float("nan")
    if collect_embeddings:
        out["embeddings"] = np.concatenate(embeds) if embeds else None
        out["labels"] = np.concatenate(labels) if labels else None
    return out


def train_classifier_head(
    embeds: np.ndarray,
    labels: np.ndarray,
    n_classes: int,
    *,
    seed: int = 0,
    steps: int = 300,
    lr: float = 1e-2,
) -> float:
    """Dynamic node classification (paper Tab.V): train a small MLP head on
    frozen interaction-time embeddings, report AUROC on a chronological
    70/30 split.  Multi-class -> macro one-vs-rest AUROC."""
    from repro.tig.modules import mlp, mlp_init

    keep = labels >= 0
    embeds, labels = embeds[keep], labels[keep]
    n = len(labels)
    if n < 10 or len(np.unique(labels)) < 2:
        return float("nan")
    cut = int(n * 0.7)
    x_tr = jnp.asarray(embeds[:cut])
    y_tr = jnp.asarray(labels[:cut])
    params = mlp_init(jax.random.PRNGKey(seed),
                      [embeds.shape[1], 64, n_classes])
    opt = adamw(lr=lr)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        def loss_fn(p):
            logits = mlp(p, x_tr)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y_tr[:, None], 1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, loss

    for _ in range(steps):
        params, opt_state, _ = step(params, opt_state)

    logits = np.asarray(mlp(params, jnp.asarray(embeds[cut:])))
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    y_te = labels[cut:]
    if n_classes == 2:
        return roc_auc(y_te == 1, probs[:, 1])
    aucs = []
    for c in range(n_classes):
        if (y_te == c).any() and (y_te != c).any():
            aucs.append(roc_auc(y_te == c, probs[:, c]))
    return float(np.mean(aucs)) if aucs else float("nan")


def evaluate_params(
    g: TemporalGraph,
    cfg: TIGConfig,
    params: dict,
    *,
    seed: int = 0,
    eval_node_class: bool = False,
) -> dict:
    """Evaluate (PAC-)trained parameters on the standard protocol: replay the
    train split to build memory (no parameter updates), then score val/test
    link prediction (+ optional node classification).  This is how the
    partition-trained rows of Tab.IV/V are produced."""
    from repro.tig.graph import chronological_split

    rng = np.random.default_rng(seed)
    train_g, val_g, test_g, inductive_nodes = chronological_split(g)
    ind = np.zeros(g.num_nodes, dtype=bool)
    ind[inductive_nodes] = True

    stream, tables = graph_as_stream(g)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    n_tr, n_val = train_g.num_edges, val_g.num_edges

    def sub(lo, hi):
        return LocalStream(
            src=stream.src[lo:hi], dst=stream.dst[lo:hi],
            t=stream.t[lo:hi], eidx=stream.eidx[lo:hi],
            num_local_nodes=g.num_nodes,
            labels=None if g.labels is None else g.labels[lo:hi],
        )

    eval_fn = make_eval_step(cfg)
    neg_pool = np.unique(stream.dst)
    sampler = RecentNeighborBuffer(g.num_nodes, cfg.num_neighbors)
    state = init_state(cfg, g.num_nodes)

    tr_batches = build_batches(sub(0, n_tr), cfg, rng, sampler, neg_pool)
    res_tr = evaluate_stream(params, cfg, state, tr_batches, tables_j,
                             eval_fn)
    val_batches = build_batches(sub(n_tr, n_tr + n_val), cfg, rng,
                                sampler, neg_pool)
    res_val = evaluate_stream(params, cfg, res_tr["state"], val_batches,
                              tables_j, eval_fn)
    test_stream = sub(n_tr + n_val, g.num_edges)
    ind_mask = ind[test_stream.src] | ind[test_stream.dst]
    test_batches = build_batches(test_stream, cfg, rng, sampler, neg_pool)
    res_test = evaluate_stream(
        params, cfg, res_val["state"], test_batches, tables_j, eval_fn,
        inductive_edge_mask=ind_mask, collect_embeddings=eval_node_class)

    out = {
        "val_ap": res_val["ap"],
        "test_ap": res_test["ap"],
        "test_ap_inductive": res_test.get("ap_inductive", float("nan")),
        "node_auroc": float("nan"),
    }
    if eval_node_class and res_test.get("embeddings") is not None \
            and res_test.get("labels") is not None \
            and g.labels is not None:
        n_classes = int(g.labels[g.labels >= 0].max()) + 1
        out["node_auroc"] = train_classifier_head(
            res_test["embeddings"], res_test["labels"], max(n_classes, 2))
    return out


@dataclasses.dataclass
class SingleResult:
    val_ap: float
    test_ap: float
    test_ap_inductive: float
    node_auroc: float
    epoch_seconds: list[float]
    losses: list[float]
    params: dict
    state: dict
    cfg: TIGConfig


def train_single(
    g: TemporalGraph,
    cfg: TIGConfig,
    *,
    epochs: int = 3,
    lr: float = 1e-3,
    seed: int = 0,
    eval_node_class: bool = False,
) -> SingleResult:
    """The paper's single-device baseline trainer: chronological 70/15/15
    split, memory reset per epoch, val/test continue the epoch-end memory."""
    from repro.tig.graph import chronological_split

    rng = np.random.default_rng(seed)
    train_g, val_g, test_g, inductive_nodes = chronological_split(g)
    ind = np.zeros(g.num_nodes, dtype=bool)
    ind[inductive_nodes] = True

    stream, tables = graph_as_stream(g)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    n_tr = train_g.num_edges
    n_val = val_g.num_edges

    def sub(lo, hi, g_sub):
        return LocalStream(
            src=stream.src[lo:hi], dst=stream.dst[lo:hi],
            t=stream.t[lo:hi], eidx=stream.eidx[lo:hi],
            num_local_nodes=g.num_nodes,
            labels=None if g.labels is None else g.labels[lo:hi],
        )

    tr_stream = sub(0, n_tr, train_g)
    val_stream = sub(n_tr, n_tr + n_val, val_g)
    test_stream = sub(n_tr + n_val, g.num_edges, test_g)

    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt)
    eval_fn = make_eval_step(cfg)

    neg_pool = np.unique(stream.dst)
    epoch_secs, losses = [], []
    best = {"val_ap": -1.0}
    state = init_state(cfg, g.num_nodes)

    for ep in range(epochs):
        t0 = time.perf_counter()
        sampler = RecentNeighborBuffer(g.num_nodes, cfg.num_neighbors)
        batches = build_batches(tr_stream, cfg, rng, sampler, neg_pool)
        state = init_state(cfg, g.num_nodes)  # Alg.2: reset at cycle start
        params, opt_state, state, loss = train_epoch(
            params, opt_state, state, batches, tables_j, step_fn)
        epoch_secs.append(time.perf_counter() - t0)
        losses.append(loss)

        # validation continues from epoch-end memory + neighbor index
        s_val = sampler.copy()
        val_batches = build_batches(val_stream, cfg, rng, s_val, neg_pool)
        res_val = evaluate_stream(params, cfg, state, val_batches,
                                  tables_j, eval_fn)
        if res_val["ap"] > best["val_ap"]:
            ind_mask = (ind[test_stream.src] | ind[test_stream.dst])
            test_batches = build_batches(
                test_stream, cfg, rng, s_val.copy(), neg_pool)
            res_test = evaluate_stream(
                params, cfg, res_val["state"], test_batches, tables_j,
                eval_fn, inductive_edge_mask=ind_mask,
                collect_embeddings=eval_node_class,
            )
            best = {
                "val_ap": res_val["ap"],
                "test_ap": res_test["ap"],
                "test_ap_inductive": res_test.get("ap_inductive",
                                                  float("nan")),
                "test_res": res_test,
            }

    node_auroc = float("nan")
    if eval_node_class and g.labels is not None:
        res_test = best["test_res"]
        if res_test.get("embeddings") is not None \
                and res_test.get("labels") is not None:
            n_classes = int(g.labels[g.labels >= 0].max()) + 1
            node_auroc = train_classifier_head(
                res_test["embeddings"], res_test["labels"],
                max(n_classes, 2))

    return SingleResult(
        val_ap=best["val_ap"],
        test_ap=best["test_ap"],
        test_ap_inductive=best["test_ap_inductive"],
        node_auroc=node_auroc,
        epoch_seconds=epoch_secs,
        losses=losses,
        params=params,
        state=state,
        cfg=cfg,
    )
