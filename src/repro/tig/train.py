"""Single-device TIG training & evaluation (the paper's non-partitioned
baseline — 'Single-GPU' / 'w/o Partitioning' rows of Tab.III/IV).

Epochs run through the device-resident streaming engine
(``repro.tig.engine``): host planning pre-stages the whole chronological
stream as one (steps, ...) batch pytree, and a single jitted ``lax.scan``
executes the epoch on device.  The distributed PAC trainer
(``repro.tig.distributed``) drives the same scan program.

Split and evaluation logic lives in ``repro.tig.protocol`` — chronological
70/15/15 splits are zero-copy stream views, and the val/test scoring of
every trainer (this module's ``train_single`` / ``train_sharded`` and the
PAC path) goes through the same ``run_protocol`` driver.
"""

from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.optim import adamw, Optimizer
from repro.tig.batching import (
    LocalStream,
    build_batch_program,
    make_tables,
)
from repro.tig.engine import make_eval_epoch, make_train_epoch
from repro.tig.graph import TemporalGraph
from repro.tig.models import TIGConfig, init_params, init_state, step_loss
from repro.tig.protocol import (
    DEFAULT_CHUNK_EDGES,
    ProtocolSplits,
    device_batches,
    run_protocol,
    score_stream,
    split_views,
    time_scale_of,
    train_classifier_head,
)
from repro.tig.sampler import ChronoNeighborIndex
from repro.tig.stream import EpochPrefetcher


def _stage_tcsr(index: ChronoNeighborIndex, depth: int = 1) -> dict:
    """Stage a stream's T-CSR (``device_export``) as device arrays — done
    ONCE per run; every epoch's scanned program samples from these buffers
    instead of receiving pre-sampled (steps, B, 3, K) neighbor grids.
    ``depth`` = the model's ``n_layers`` (multi-layer folds gather one
    K-window per layer, so the export front-pads by k*depth)."""
    return {k: jnp.asarray(v)
            for k, v in index.device_export(depth=depth).items()}

__all__ = [
    "graph_as_stream",
    "make_train_step",
    "make_eval_step",
    "train_epoch",
    "evaluate_stream",
    "evaluate_params",
    "train_single",
    "train_sharded",
    "train_classifier_head",
    "time_scale_of",
    "epoch_rng",
]

# the protocol layer owns stream scoring; the old name stays importable
evaluate_stream = score_stream


def epoch_rng(seed: int, epoch: int, role: int = 0) -> np.random.Generator:
    """Independent generator per (seed, epoch, role) — epoch plans drawn
    from dedicated streams, so prefetched (out-of-order) planning produces
    bit-identical draws to serial planning."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, role, epoch]))


def graph_as_stream(g: TemporalGraph) -> tuple[LocalStream, dict]:
    """Treat the whole graph as one device-local stream (ids unchanged).

    Timestamps are rescaled to mean-gap units (see ``time_scale_of``)."""
    scale = time_scale_of(g.t)
    stream = LocalStream(
        src=g.src.astype(np.int64),
        dst=g.dst.astype(np.int64),
        t=g.t / scale,
        eidx=np.arange(g.num_edges, dtype=np.int64),
        num_local_nodes=g.num_nodes,
        labels=g.labels,
    )
    return stream, make_tables(g.edge_feat, g.node_feat)


def make_train_step(cfg: TIGConfig, opt: Optimizer):
    """jit'd per-batch step (params, opt_state, state, batch, tables) ->
    updated + loss.  The epoch hot path uses ``engine.make_train_epoch``;
    this single-step variant remains for debugging and parity tests."""

    @jax.jit
    def step(params, opt_state, state, batch, tables):
        (loss, (new_state, _aux)), grads = jax.value_and_grad(
            step_loss, has_aux=True
        )(params, state, batch, tables, cfg)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, new_state, loss

    return step


def make_eval_step(cfg: TIGConfig):
    """jit'd forward-only step: returns (new_state, aux) with logits."""

    @jax.jit
    def step(params, state, batch, tables):
        _loss, (new_state, aux) = step_loss(params, state, batch, tables, cfg)
        return new_state, aux

    return step


def train_epoch(params, opt_state, state, batches, tables_j, epoch_fn,
                tcsr=None):
    """One pass over prepared batches as a single scanned device program.

    ``batches`` is a (steps, ...) pytree (or a legacy list of per-batch
    dicts); ``epoch_fn`` comes from ``engine.make_train_epoch``.  With
    ``tcsr`` (a staged ``ChronoNeighborIndex.device_export`` dict) the
    batches are a raw-edge ``plan="device"`` program and the scan samples
    neighbor grids on device.  Returns mean loss over steps.
    """
    bj = device_batches(batches)
    if tcsr is None:
        params, opt_state, state, losses = epoch_fn(
            params, opt_state, state, bj, tables_j)
    else:
        params, opt_state, state, losses = epoch_fn(
            params, opt_state, state, bj, tables_j, tcsr=tcsr)
    return params, opt_state, state, float(jnp.mean(losses))


@dataclasses.dataclass
class ShardedResult:
    losses: list[float]
    epoch_seconds: list[float]
    params: dict
    state: dict
    cfg: TIGConfig
    metrics: Optional[dict] = None      # run_protocol output (protocol=True)
    best_epoch: Optional[int] = None
    val_curve: list[float] = dataclasses.field(default_factory=list)


def train_sharded(
    shards,
    cfg: TIGConfig,
    *,
    epochs: int = 2,
    lr: float = 1e-3,
    seed: int = 0,
    prefetch: bool = True,
    depth: int = 1,
    protocol: bool = False,
    patience: int = 2,
    eval_node_class: bool = False,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    plan: str = "device",
) -> ShardedResult:
    """Out-of-core training over a ``tig-shards-v1`` stream.

    The full data plane is chunked: id columns materialize at 8 bytes/edge,
    the edge-feature table is staged shard-by-shard into a donated device
    buffer (the host never holds all rows), the temporal neighbor index is
    built with the chunked T-CSR merge, and epoch plans are prefetched on
    a worker thread while the previous epoch's scan runs (``depth`` epoch
    plans may run ahead on the host; device staging stays single-slot, and
    any depth is bit-identical — disable with ``prefetch=False`` /
    ``depth=0`` when debugging).  With
    ``plan="device"`` (the default) the chunk-built T-CSR is additionally
    exported to device once and epochs ship raw-edge programs — neighbor
    grids are sampled inside the scan; ``plan="host"`` pre-samples them on
    the host (the bit-parity oracle).

    With ``protocol=False`` (the legacy fast path) the whole stream is the
    train split and no evaluation runs.  With ``protocol=True`` the quality
    path runs end-to-end from shards: the 70/15/15 chronological split
    becomes zero-copy row-range views (``protocol.split_views``), training
    sees only the train rows, each epoch scores the val split from the
    epoch-end memory, the best-val parameters (with their epoch-end memory)
    are kept via ``repro.checkpoint`` (patience-based early stop), and the
    final metrics come from ``protocol.run_protocol`` with the restored
    best params —
    identical code (and identical numbers, given identical plans) to
    ``evaluate_params`` on the equivalent in-memory graph.

    ``ckpt_every=k`` additionally writes a periodic fault-tolerance
    checkpoint ``{params, opt_state, state}`` every k epochs (atomic
    tmp+rename; needs ``ckpt_dir``).
    """
    from repro.tig.stream import stage_device_tables

    if plan not in ("host", "device"):
        raise ValueError(f"plan={plan!r}: expected 'host' or 'device'")
    splits: Optional[ProtocolSplits] = None
    if protocol:
        splits = split_views(shards)
        stream = splits.train

        def scaled_chunks():
            for lo in range(0, stream.num_edges, DEFAULT_CHUNK_EDGES):
                hi = min(lo + DEFAULT_CHUNK_EDGES, stream.num_edges)
                yield (stream.src[lo:hi], stream.dst[lo:hi],
                       stream.t[lo:hi], stream.eidx[lo:hi])

        neg_pool = splits.neg_pool
    else:
        src = shards.column("src")
        dst = shards.column("dst")
        t = shards.column("t")
        scale = time_scale_of(t)
        stream = LocalStream(
            src=src.astype(np.int64),
            dst=dst.astype(np.int64),
            t=t / scale,
            eidx=np.arange(len(src), dtype=np.int64),
            num_local_nodes=shards.num_nodes,
            labels=None,
        )

        def scaled_chunks():
            for c_src, c_dst, c_t, c_eidx in shards.edge_chunks():
                yield c_src, c_dst, c_t / scale, c_eidx

        neg_pool = np.unique(stream.dst)

    # index is epoch-invariant (same stream, no history): chunked build once
    index = ChronoNeighborIndex.from_chunks(
        scaled_chunks, shards.num_nodes, cfg.num_neighbors, cfg.batch_size)

    tables_j = stage_device_tables(shards)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)
    epoch_fn = make_train_epoch(cfg, opt)
    eval_fn = make_eval_epoch(cfg)
    train_hist = index.final_snapshot() if protocol else None
    val_mask = splits.inductive_edge_mask(splits.val) if protocol else None

    # device planning: the chunk-built T-CSR (and, under protocol, the val
    # continuation index) is exported/staged once; epochs reuse it
    tcsr_tr = _stage_tcsr(index, cfg.n_layers) \
        if plan == "device" else None
    val_index, tcsr_val = None, None
    if plan == "device" and protocol:
        val_index = ChronoNeighborIndex(
            splits.val.src, splits.val.dst, splits.val.t, splits.val.eidx,
            shards.num_nodes, cfg.num_neighbors, cfg.batch_size,
            history=train_hist)
        tcsr_val = _stage_tcsr(val_index, cfg.n_layers)

    own_tmp = None
    if protocol and ckpt_dir is None:
        own_tmp = tempfile.TemporaryDirectory(prefix="tig_ckpt_")
        ckpt_dir = own_tmp.name

    pf = EpochPrefetcher(
        lambda ep: build_batch_program(
            stream, cfg, epoch_rng(seed, ep, 1), neg_pool=neg_pool,
            index=index, plan=plan)[0],
        epochs,
        to_device=device_batches,
        enabled=prefetch,
        depth=depth,
    )
    losses, epoch_secs, val_curve = [], [], []
    state = None
    best_val, best_epoch, bad = -np.inf, None, 0
    try:
        with pf:
            for ep in range(epochs):
                t0 = time.perf_counter()
                batches = pf.get(ep)
                state = init_state(cfg, shards.num_nodes)
                params, opt_state, state, loss = train_epoch(
                    params, opt_state, state, batches, tables_j, epoch_fn,
                    tcsr=tcsr_tr)
                epoch_secs.append(time.perf_counter() - t0)
                losses.append(loss)
                if ckpt_dir and ckpt_every and (ep + 1) % ckpt_every == 0:
                    # periodic fault-tolerance snapshot: a superset of the
                    # best-val pair (opt state included), written with the
                    # same atomic tmp+rename protocol
                    save_checkpoint(ckpt_dir, ep,
                                    {"params": params,
                                     "opt_state": opt_state,
                                     "state": state},
                                    metadata={"epoch": ep})

                if not protocol:
                    continue
                # validation continues the epoch-end memory + train history
                val_batches, _ = build_batch_program(
                    splits.val, cfg, epoch_rng(seed, ep, 2),
                    history=None if plan == "device" else train_hist,
                    neg_pool=neg_pool, index=val_index, plan=plan)
                res_val = score_stream(params, cfg, state, val_batches,
                                       tables_j, eval_fn,
                                       inductive_edge_mask=val_mask,
                                       tcsr=tcsr_val)
                val_curve.append(res_val["ap"])
                if res_val["ap"] > best_val:
                    best_val, best_epoch, bad = res_val["ap"], ep, 0
                    # params AND their epoch-end memory: the restored pair
                    # is a consistent training point, not best params +
                    # later state
                    save_checkpoint(ckpt_dir, ep,
                                    {"params": params,
                                     "opt_state": opt_state,
                                     "state": state},
                                    metadata={"val_ap": float(res_val["ap"])})
                else:
                    bad += 1
                    if bad >= patience:
                        pf.close()  # drop the in-flight next-epoch plan
                        break

        metrics = None
        if protocol:
            # best_epoch is None when no epoch ran or val AP was NaN
            # throughout (e.g. a degenerate val split) — keep last params
            if best_epoch is not None:
                restored = restore_checkpoint(
                    ckpt_dir, best_epoch,
                    {"params": params, "state": state})
                params, state = restored["params"], restored["state"]
            metrics = run_protocol(
                params, cfg, splits, tables_j, seed=seed,
                eval_node_class=eval_node_class, prefetch=prefetch,
                depth=depth)
    finally:
        if own_tmp is not None:
            own_tmp.cleanup()

    return ShardedResult(
        losses=losses,
        epoch_seconds=epoch_secs,
        params=params,
        state=state,
        cfg=cfg,
        metrics=metrics,
        best_epoch=best_epoch,
        val_curve=val_curve,
    )


def evaluate_params(
    g: TemporalGraph,
    cfg: TIGConfig,
    params: dict,
    *,
    seed: int = 0,
    eval_node_class: bool = False,
) -> dict:
    """Evaluate (PAC-)trained parameters on the standard protocol: replay the
    train split to build memory (no parameter updates), then score val/test
    link prediction (+ optional node classification).  This is how the
    partition-trained rows of Tab.IV/V are produced.

    Thin wrapper over ``protocol.run_protocol`` on zero-copy split views —
    the same driver the sharded quality path reports through."""
    splits = split_views(g)
    tables = make_tables(g.edge_feat, g.node_feat)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    return run_protocol(params, cfg, splits, tables_j, seed=seed,
                        eval_node_class=eval_node_class)


@dataclasses.dataclass
class SingleResult:
    val_ap: float
    test_ap: float
    test_ap_inductive: float
    node_auroc: float
    epoch_seconds: list[float]
    losses: list[float]
    params: dict
    state: dict
    cfg: TIGConfig


def train_single(
    g: TemporalGraph,
    cfg: TIGConfig,
    *,
    epochs: int = 3,
    lr: float = 1e-3,
    seed: int = 0,
    eval_node_class: bool = False,
    prefetch: bool = True,
    depth: int = 1,
    plan: str = "device",
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
) -> SingleResult:
    """The paper's single-device baseline trainer: chronological 70/15/15
    split, memory reset per epoch, val/test continue the epoch-end memory.

    Splits are the protocol layer's zero-copy stream views (no materialized
    sub-graphs).  Each epoch is one host-planning pass (vectorized neighbor
    index + batch grid) followed by one scanned device program.  With
    ``prefetch`` (the default) epoch e+1's plan is built — and moved to
    device — on a worker thread while epoch e's scan runs (``depth`` host
    plans may run ahead; device staging stays single-slot); per-epoch RNG
    streams make the result bit-identical to serial planning at any depth.

    ``plan="device"`` (the default) stages each split's T-CSR once and
    ships raw-edge programs — the scanned step samples its own neighbor
    grids on device (``kernels.ops.neighbor_sample``), shrinking per-epoch
    H2D traffic to the edge records.  ``plan="host"`` keeps the pre-sampled
    grids (the bit-parity oracle: identical metrics, losses, and memory).

    ``ckpt_dir`` + ``ckpt_every=k`` writes a periodic fault-tolerance
    checkpoint ``{params, opt_state, state}`` every k epochs (atomic
    tmp+rename, ``repro.checkpoint``)."""
    if plan not in ("host", "device"):
        raise ValueError(f"plan={plan!r}: expected 'host' or 'device'")
    splits = split_views(g)
    tables = make_tables(g.edge_feat, g.node_feat)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    tr_stream, val_stream, test_stream = splits.views

    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(lr=lr, max_grad_norm=1.0)
    opt_state = opt.init(params)
    epoch_fn = make_train_epoch(cfg, opt)
    eval_fn = make_eval_epoch(cfg)
    eval_fn_test = make_eval_epoch(cfg, collect_embeddings=True) \
        if eval_node_class else eval_fn

    neg_pool = splits.neg_pool
    epoch_secs, losses = [], []
    best = {"val_ap": -1.0}

    # device planning: indexes are epoch-invariant (train sees no history;
    # val/test continue fixed snapshots), so each split's T-CSR is built
    # and staged exactly once — val/test lazily, from the train/val
    # end-of-stream snapshots.
    tr_index = None
    tcsr = {}
    if plan == "device":
        tr_index = ChronoNeighborIndex(
            tr_stream.src, tr_stream.dst, tr_stream.t, tr_stream.eidx,
            g.num_nodes, cfg.num_neighbors, cfg.batch_size)
        tcsr["train"] = _stage_tcsr(tr_index, cfg.n_layers)
    idx = {}

    # double-buffered host planning: epoch e+1's train plan is built and
    # device-put on a worker thread while epoch e's scan executes.
    with EpochPrefetcher(
        lambda ep: build_batch_program(
            tr_stream, cfg, epoch_rng(seed, ep, 1), neg_pool=neg_pool,
            index=tr_index, plan=plan),
        epochs,
        to_device=lambda pr: (device_batches(pr[0]), pr[1]),
        enabled=prefetch,
        depth=depth,
    ) as pf:
        for ep in range(epochs):
            t0 = time.perf_counter()
            tr_batches, hist = pf.get(ep)
            state = init_state(cfg, g.num_nodes)  # Alg.2: reset at start
            params, opt_state, state, loss = train_epoch(
                params, opt_state, state, tr_batches, tables_j, epoch_fn,
                tcsr=tcsr.get("train"))
            epoch_secs.append(time.perf_counter() - t0)
            losses.append(loss)
            if ckpt_dir and ckpt_every and (ep + 1) % ckpt_every == 0:
                # periodic fault-tolerance snapshot (atomic tmp+rename)
                save_checkpoint(ckpt_dir, ep,
                                {"params": params, "opt_state": opt_state,
                                 "state": state},
                                metadata={"epoch": ep})

            # validation continues from epoch-end memory + neighbor index
            if plan == "device" and "val" not in idx:
                idx["val"] = ChronoNeighborIndex(
                    val_stream.src, val_stream.dst, val_stream.t,
                    val_stream.eidx, g.num_nodes, cfg.num_neighbors,
                    cfg.batch_size, history=hist)
                tcsr["val"] = _stage_tcsr(idx["val"], cfg.n_layers)
            val_batches, hist_val = build_batch_program(
                val_stream, cfg, epoch_rng(seed, ep, 2),
                history=None if plan == "device" else hist,
                neg_pool=neg_pool, index=idx.get("val"), plan=plan)
            res_val = score_stream(params, cfg, state, val_batches,
                                   tables_j, eval_fn, tcsr=tcsr.get("val"))
            if res_val["ap"] > best["val_ap"]:
                if plan == "device" and "test" not in idx:
                    idx["test"] = ChronoNeighborIndex(
                        test_stream.src, test_stream.dst, test_stream.t,
                        test_stream.eidx, g.num_nodes, cfg.num_neighbors,
                        cfg.batch_size, history=hist_val)
                    tcsr["test"] = _stage_tcsr(idx["test"], cfg.n_layers)
                test_batches, _ = build_batch_program(
                    test_stream, cfg, epoch_rng(seed, ep, 3),
                    history=None if plan == "device" else hist_val,
                    neg_pool=neg_pool, index=idx.get("test"), plan=plan)
                res_test = score_stream(
                    params, cfg, res_val["state"], test_batches, tables_j,
                    eval_fn_test,
                    inductive_edge_mask=splits.inductive_edge_mask(
                        test_stream),
                    collect_embeddings=eval_node_class,
                    tcsr=tcsr.get("test"),
                )
                best = {
                    "val_ap": res_val["ap"],
                    "test_ap": res_test["ap"],
                    "test_ap_inductive": res_test.get("ap_inductive",
                                                      float("nan")),
                    "test_res": res_test,
                }

    node_auroc = float("nan")
    if eval_node_class and g.labels is not None:
        res_test = best["test_res"]
        if res_test.get("embeddings") is not None \
                and res_test.get("labels") is not None:
            n_classes = int(g.labels[g.labels >= 0].max()) + 1
            node_auroc = train_classifier_head(
                res_test["embeddings"], res_test["labels"],
                max(n_classes, 2))

    return SingleResult(
        val_ap=best["val_ap"],
        test_ap=best["test_ap"],
        test_ap_inductive=best["test_ap_inductive"],
        node_auroc=node_auroc,
        epoch_seconds=epoch_secs,
        losses=losses,
        params=params,
        state=state,
        cfg=cfg,
    )
