"""TIGER-style restarter: reconstruct node memory from embeddings.

``run_protocol`` warms memory by replaying the train stream — O(E) work
that every resume, mid-stream eval, and host-loss recovery re-pays.  TIGER
(arXiv 2302.06057) observes that interaction-time *embeddings* carry
enough information to regress the memory back: train a small head that
maps a node's last collected embedding (plus static features and the time
since that embedding) to its memory row, then "restart" memory anywhere
with one O(N) forward pass.

The pieces here:

  * ``EmbeddingBank``     — per-node latest embedding / event time / seen
                            mask, filled from a chronological stream
                            (later events overwrite earlier ones);
  * ``collect_bank``      — one forward-only replay of the train split
                            with ``collect_embeddings`` that fills a bank
                            AND returns the true replay-warm state (the
                            restarter's supervision + the parity oracle);
  * ``fit_restarter``     — full-batch MSE fit of the head (own trainable
                            Φ time encoder, ``modules.restarter``) on the
                            seen rows: predict mem (and mem2 for TIGE)
                            from [emb ; nfeat ; Φ(t_end - t)];
  * ``restart_memory``    — the payoff: an eval-ready state dict from the
                            bank alone — predicted memory on seen rows,
                            zeros elsewhere, ``last`` = bank event times,
                            fresh (empty) pending-message store;
  * ``build_restarter``   — collect + fit in one call (what ``pac_train``
                            / benchmarks use);
  * ``save_restarter`` / ``load_restarter`` — crash-atomic npz bundle so
                            a recovered process can restart memory without
                            owning the pre-crash replay.

The replay path stays the parity oracle (repo pattern): ``restart_memory``
approximates it — the pending messages of the final train batch are
dropped (they are applied one batch later), and predicted memory carries
the head's fit error — so consumers compare metrics within tolerance, not
bits.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.tig.batching import build_batch_program, stack_batches
from repro.tig.engine import make_eval_epoch
from repro.tig.models import TIGConfig, init_state
from repro.tig.modules import restarter, restarter_init
from repro.tig.time_encode import init_time_encoder, time_encode

__all__ = [
    "EmbeddingBank",
    "Restarter",
    "collect_bank",
    "fit_restarter",
    "restart_memory",
    "build_restarter",
    "save_restarter",
    "load_restarter",
]


def _n_mem(cfg: TIGConfig) -> int:
    return 2 if cfg.flavor == "tige" else 1


@dataclasses.dataclass
class EmbeddingBank:
    """Latest interaction-time embedding per node, host-side.

    ``emb[i]`` is node i's embedding at its most recent event, ``t[i]``
    that event's (rescaled) time, ``seen[i]`` whether any event touched i.
    ``t_end`` is the stream time the bank is warm to (Δt baseline for the
    restarter's time encoding).
    """

    emb: np.ndarray     # (N, d) float32
    t: np.ndarray       # (N,) float32
    seen: np.ndarray    # (N,) bool
    t_end: float = 0.0

    @classmethod
    def empty(cls, num_nodes: int, dim: int) -> "EmbeddingBank":
        return cls(emb=np.zeros((num_nodes, dim), np.float32),
                   t=np.zeros((num_nodes,), np.float32),
                   seen=np.zeros((num_nodes,), bool))

    def update(self, ids: np.ndarray, ts: np.ndarray,
               embs: np.ndarray) -> None:
        """Absorb a chronological run of events (row order = event order):
        the LAST occurrence of each node wins."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        ts = np.asarray(ts, np.float32)
        embs = np.asarray(embs, np.float32)
        # first occurrence in the reversed array = last occurrence forward
        uniq, first_rev = np.unique(ids[::-1], return_index=True)
        rows = len(ids) - 1 - first_rev
        self.emb[uniq] = embs[rows]
        self.t[uniq] = ts[rows]
        self.seen[uniq] = True
        self.t_end = max(self.t_end, float(ts.max()))


@dataclasses.dataclass
class Restarter:
    """A fitted restarter bundle: head params + the bank they were fit on."""

    params: dict            # {"time": Φ params, "head": mlp params}
    cfg: TIGConfig
    bank: EmbeddingBank
    fit_mse: float = float("nan")


def collect_bank(params, cfg: TIGConfig, splits, tables_j, *,
                 seed: int = 0) -> tuple[EmbeddingBank, dict]:
    """One forward-only replay of ``splits.train`` with embedding
    collection: returns ``(bank, replay_state)`` where ``replay_state`` is
    the true post-train memory (the restarter's regression target and the
    replay-warm parity oracle).  This is the amortize-at-train-time cost —
    every later ``restart_memory`` is O(N)."""
    tr = splits.train
    rng = np.random.default_rng(seed)
    batches, _hist = build_batch_program(tr, cfg, rng,
                                         neg_pool=splits.neg_pool)
    if isinstance(batches, (list, tuple)):
        batches = stack_batches(list(batches))
    from repro.tig.protocol import device_batches

    eval_fn = make_eval_epoch(cfg, collect_embeddings=True)
    state, aux = eval_fn(params, init_state(cfg, splits.num_nodes),
                         device_batches(batches), tables_j)

    d = cfg.dim
    valid = np.asarray(batches["valid"]).reshape(-1).astype(bool)
    src = np.asarray(batches["src"]).reshape(-1)
    dst = np.asarray(batches["dst"]).reshape(-1)
    ts = np.asarray(batches["t"]).reshape(-1)
    se = np.asarray(aux["src_embed"]).reshape(-1, d)
    de = np.asarray(aux["dst_embed"]).reshape(-1, d)

    # interleave src/dst per edge so within-batch ordering is the event
    # order for BOTH endpoints (last write per node wins in the bank)
    ids = np.stack([src, dst], axis=1).reshape(-1)
    embs = np.stack([se, de], axis=1).reshape(-1, d)
    times = np.repeat(ts, 2)
    keep = np.repeat(valid, 2)

    bank = EmbeddingBank.empty(splits.num_nodes, d)
    bank.update(ids[keep], times[keep], embs[keep])
    return bank, state


def _head_inputs(rst_params: dict, cfg: TIGConfig, emb, nfeat, dt):
    phi = time_encode(rst_params["time"], jnp.asarray(dt, jnp.float32))
    return jnp.concatenate([jnp.asarray(emb, jnp.float32),
                            jnp.asarray(nfeat, jnp.float32), phi], axis=-1)


def fit_restarter(bank: EmbeddingBank, target_state, cfg: TIGConfig,
                  tables_j, *, seed: int = 0, steps: int = 400,
                  lr: float = 1e-2) -> Restarter:
    """Fit the head by full-batch MSE on the bank's seen rows against the
    replay-warm memory (``target_state``).  Small problem — |seen| rows of
    width d — so a few hundred adamw steps converge in well under a
    replay's wall time."""
    from repro.optim import adamw

    n_mem = _n_mem(cfg)
    d_in = cfg.dim + cfg.dim_node + cfg.dim_time
    key = jax.random.PRNGKey(seed)
    rst_params = {"time": init_time_encoder(cfg.dim_time),
                  "head": restarter_init(key, d_in, cfg.dim, n_mem)}

    rows = np.flatnonzero(bank.seen)
    if rows.size == 0:
        return Restarter(params=rst_params, cfg=cfg, bank=bank)

    mems = [np.asarray(target_state["mem"])[rows]]
    if n_mem == 2:
        mems.append(np.asarray(target_state["mem2"])[rows])
    y = jnp.asarray(np.stack(mems, axis=1))           # (S, n_mem, d)
    emb = jnp.asarray(bank.emb[rows])
    nfeat = jnp.asarray(np.asarray(tables_j["nfeat"])[rows])
    dt = jnp.asarray(np.maximum(bank.t_end - bank.t[rows], 0.0),
                     jnp.float32)

    opt = adamw(lr=lr)
    opt_state = opt.init(rst_params)

    @jax.jit
    def step(p, o):
        def loss_fn(p):
            x = _head_inputs(p, cfg, emb, nfeat, dt)
            pred = restarter(p["head"], x, cfg.dim, n_mem)
            return jnp.mean((pred - y) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, o = opt.apply(grads, o, p)
        return p, o, loss

    loss = jnp.zeros(())
    for _ in range(steps):
        rst_params, opt_state, loss = step(rst_params, opt_state)
    return Restarter(params=rst_params, cfg=cfg, bank=bank,
                     fit_mse=float(loss))


def restart_memory(rst: Restarter, num_nodes: int, tables_j) -> dict:
    """The replayless warm-up: an eval-ready state dict from the bank in
    one O(N) head forward — predicted memory on seen rows, zeros (the
    init value) elsewhere, ``last`` = each node's bank event time, and a
    fresh pending-message store (the final batch's stashed messages are
    the restart's information loss; TIGER accepts the same).  ``tables_j``
    supplies the node-feature table the head consumes."""
    cfg, bank = rst.cfg, rst.bank
    if bank.emb.shape[0] != num_nodes:
        raise ValueError(f"bank holds {bank.emb.shape[0]} nodes, caller "
                         f"expects {num_nodes}")
    n_mem = _n_mem(cfg)
    state = init_state(cfg, num_nodes)
    rows = np.flatnonzero(bank.seen)
    if rows.size == 0:
        return state
    nfeat = np.asarray(tables_j["nfeat"])[rows]
    dt = np.maximum(bank.t_end - bank.t[rows], 0.0)
    x = _head_inputs(rst.params, cfg, bank.emb[rows], nfeat, dt)
    pred = np.asarray(restarter(rst.params["head"], x, cfg.dim, n_mem))
    mem = np.zeros((num_nodes + 1, cfg.dim), np.float32)
    mem[rows] = pred[:, 0]
    last = np.zeros((num_nodes + 1,), np.float32)
    last[rows] = bank.t[rows]
    state = dict(state)
    state["mem"] = jnp.asarray(mem)
    state["last"] = jnp.asarray(last)
    if n_mem == 2:
        mem2 = np.zeros((num_nodes + 1, cfg.dim), np.float32)
        mem2[rows] = pred[:, 1]
        state["mem2"] = jnp.asarray(mem2)
    return state


def build_restarter(params, cfg: TIGConfig, splits, tables_j, *,
                    seed: int = 0, steps: int = 400,
                    lr: float = 1e-2) -> tuple[Restarter, dict]:
    """Collect the train-split embedding bank with ``params`` and fit the
    head.  Returns ``(restarter, replay_state)`` — the second element is
    the true replay-warm memory, kept as the parity oracle."""
    bank, replay_state = collect_bank(params, cfg, splits, tables_j,
                                      seed=seed)
    rst = fit_restarter(bank, replay_state, cfg, tables_j, seed=seed,
                        steps=steps, lr=lr)
    return rst, replay_state


# ------------------------------------------------------------- persistence

def save_restarter(path: str, rst: Restarter) -> str:
    """Crash-atomic npz bundle of the head params + bank (self-describing
    keys: load needs no target tree)."""
    from repro.checkpoint.ckpt import _atomic_write, _flatten

    flat = {"bank|emb": rst.bank.emb, "bank|t": rst.bank.t,
            "bank|seen": rst.bank.seen.astype(np.uint8),
            "bank|t_end": np.float64(rst.bank.t_end),
            "fit_mse": np.float64(rst.fit_mse)}
    for k, v in _flatten(rst.params).items():
        flat[f"params|{k}"] = v
    _atomic_write(path, lambda f: np.savez_compressed(f, **flat))
    return path


def load_restarter(path: str, cfg: TIGConfig) -> Restarter:
    data = np.load(path)
    bank = EmbeddingBank(emb=data["bank|emb"], t=data["bank|t"],
                         seen=data["bank|seen"].astype(bool),
                         t_end=float(data["bank|t_end"]))
    params: dict = {}
    for key in data.files:
        if not key.startswith("params|"):
            continue
        node = params
        parts = key.split("|")[1:]
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = jnp.asarray(data[key])
    return Restarter(params=params, cfg=cfg, bank=bank,
                     fit_mse=float(data["fit_mse"]))
