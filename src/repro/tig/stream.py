"""Chunked streaming data plane: out-of-core shards + epoch prefetch.

Million-node interaction streams must never be fully materialized in host
RAM (ROADMAP: "real-dataset ingestion at paper scale").  This module is the
disk <-> host <-> device plumbing between raw logs and the scanned epoch of
``repro.tig.engine``:

  * a memory-mapped **shard format** for edge streams (below),
  * a **pandas-free block reader** for JODIE/TGN CSVs that ingests
    arbitrarily large files one block at a time (``write_jodie_shards``),
  * **chunked device staging** of the per-edge feature table
    (``stage_device_tables``): the host only ever holds one shard's features;
    rows are written into a donated device buffer shard by shard,
  * an **EpochPrefetcher** that double-buffers host epoch planning: the plan
    for epoch e+1 is built on a worker thread (and optionally moved to
    device) while the ``lax.scan`` for epoch e runs.

Shard format (``tig-shards-v1``)
--------------------------------
A shard directory holds one chronological edge stream split into
fixed-size row ranges::

    <dir>/meta.json            format tag + sizes (see below)
    <dir>/shard_00000.src.npy  int64   (e_s,)   source node ids
    <dir>/shard_00000.dst.npy  int64   (e_s,)   destination node ids
    <dir>/shard_00000.t.npy    float64 (e_s,)   non-decreasing timestamps
    <dir>/shard_00000.label.npy int64  (e_s,)   dynamic labels (optional)
    <dir>/shard_00000.efeat.npy float32 (e_s, d_e) edge features
    <dir>/node_feat.npy        float32 (N, d_n) node features (optional;
                               absent means all-zeros, the paper's default)

``meta.json`` keys: ``format`` ("tig-shards-v1"), ``name``, ``num_nodes``,
``num_edges``, ``num_shards``, ``shard_edges`` (per-shard row counts),
``dim_edge``, ``dim_node``, ``has_labels``.  Every array is a plain ``.npy``
so readers use ``np.load(..., mmap_mode="r")`` — opening a stream touches
only ``meta.json``; array bytes are paged in on demand and never copied
unless a caller materializes them.  Shards are row ranges of ONE
chronological order: shard boundaries carry no semantic meaning and any
multiple-of-batch re-chunking is valid (``ChronoNeighborIndex.from_chunks``
relies on exactly this).
"""

from __future__ import annotations

import dataclasses
import io
import json
import os
import queue
import threading
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

from repro.tig.graph import TemporalGraph

__all__ = [
    "SHARD_FORMAT",
    "ShardedStream",
    "write_graph_shards",
    "write_jodie_shards",
    "iter_jodie_blocks",
    "stage_device_tables",
    "stage_partitioned",
    "stage_replicated",
    "EpochPrefetcher",
]

SHARD_FORMAT = "tig-shards-v1"
DEFAULT_SHARD_EDGES = 262_144


# ======================================================================
# shard container
# ======================================================================

@dataclasses.dataclass
class ShardedStream:
    """A memory-mapped ``tig-shards-v1`` directory (see module docstring)."""

    path: str
    meta: dict

    @classmethod
    def open(cls, path: str) -> "ShardedStream":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if meta.get("format") != SHARD_FORMAT:
            raise ValueError(
                f"{path}: not a {SHARD_FORMAT} directory "
                f"(format={meta.get('format')!r})")
        return cls(path=path, meta=meta)

    # -- sizes ----------------------------------------------------------
    @property
    def num_edges(self) -> int:
        return int(self.meta["num_edges"])

    @property
    def num_nodes(self) -> int:
        return int(self.meta["num_nodes"])

    @property
    def num_shards(self) -> int:
        return int(self.meta["num_shards"])

    @property
    def shard_edges(self) -> list[int]:
        return list(self.meta["shard_edges"])

    @property
    def dim_edge(self) -> int:
        return int(self.meta["dim_edge"])

    @property
    def dim_node(self) -> int:
        return int(self.meta["dim_node"])

    @property
    def has_labels(self) -> bool:
        return bool(self.meta["has_labels"])

    @property
    def name(self) -> str:
        return str(self.meta.get("name", os.path.basename(self.path)))

    def _file(self, s: int, field: str) -> str:
        return os.path.join(self.path, f"shard_{s:05d}.{field}.npy")

    def shard_offsets(self) -> np.ndarray:
        """(S+1,) global edge offset of each shard boundary."""
        return np.concatenate(
            [[0], np.cumsum(self.shard_edges)]).astype(np.int64)

    def load(self, s: int, field: str, *, mmap: bool = True) -> np.ndarray:
        """One column of one shard; ``mmap=True`` returns a read-only map."""
        return np.load(self._file(s, field),
                       mmap_mode="r" if mmap else None)

    def edge_chunks(
        self, *, features: bool = False,
    ) -> Iterator[tuple]:
        """Yield (src, dst, t, eidx) per shard — id columns are materialized
        chunk-sized, ``eidx`` is the global edge index of each row.

        With ``features=True`` each tuple additionally carries the shard's
        (e_s, d_e) float32 edge-feature rows — materialized ONE shard at a
        time, so out-of-core consumers (e.g. PAC's per-device localization)
        never hold the full table."""
        offsets = self.shard_offsets()
        for s in range(self.num_shards):
            src = np.asarray(self.load(s, "src"))
            dst = np.asarray(self.load(s, "dst"))
            t = np.asarray(self.load(s, "t"))
            eidx = np.arange(offsets[s], offsets[s + 1], dtype=np.int64)
            if features:
                efeat = np.asarray(self.load(s, "efeat"), dtype=np.float32)
                yield src, dst, t, eidx, efeat
            else:
                yield src, dst, t, eidx

    def column(self, field: str) -> np.ndarray:
        """Materialize one id/label column across all shards (small: 8 bytes
        per edge — the feature table is what must stay on disk)."""
        return np.concatenate(
            [np.asarray(self.load(s, field))
             for s in range(self.num_shards)])

    def node_feat(self, *, mmap: bool = True) -> np.ndarray:
        f = os.path.join(self.path, "node_feat.npy")
        if os.path.exists(f):
            return np.load(f, mmap_mode="r" if mmap else None)
        return np.zeros((self.num_nodes, self.dim_node), dtype=np.float32)

    def as_graph(self) -> TemporalGraph:
        """Materialize the whole stream (tests / small datasets only)."""
        efeat = np.concatenate(
            [np.asarray(self.load(s, "efeat"))
             for s in range(self.num_shards)])
        return TemporalGraph(
            src=self.column("src"),
            dst=self.column("dst"),
            t=self.column("t"),
            edge_feat=efeat,
            node_feat=np.asarray(self.node_feat(mmap=False)),
            labels=self.column("label") if self.has_labels else None,
            name=self.name,
        )


def _write_meta(out_dir: str, *, name: str, num_nodes: int,
                shard_edges: list[int], dim_edge: int, dim_node: int,
                has_labels: bool) -> ShardedStream:
    meta = {
        "format": SHARD_FORMAT,
        "name": name,
        "num_nodes": int(num_nodes),
        "num_edges": int(sum(shard_edges)),
        "num_shards": len(shard_edges),
        "shard_edges": [int(e) for e in shard_edges],
        "dim_edge": int(dim_edge),
        "dim_node": int(dim_node),
        "has_labels": bool(has_labels),
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return ShardedStream(path=out_dir, meta=meta)


def _save_shard(out_dir: str, s: int, src, dst, t, efeat, label) -> None:
    np.save(os.path.join(out_dir, f"shard_{s:05d}.src.npy"),
            np.asarray(src, np.int64))
    np.save(os.path.join(out_dir, f"shard_{s:05d}.dst.npy"),
            np.asarray(dst, np.int64))
    np.save(os.path.join(out_dir, f"shard_{s:05d}.t.npy"),
            np.asarray(t, np.float64))
    np.save(os.path.join(out_dir, f"shard_{s:05d}.efeat.npy"),
            np.asarray(efeat, np.float32))
    if label is not None:
        np.save(os.path.join(out_dir, f"shard_{s:05d}.label.npy"),
                np.asarray(label, np.int64))


def write_graph_shards(
    g: TemporalGraph,
    out_dir: str,
    *,
    shard_edges: int = DEFAULT_SHARD_EDGES,
) -> ShardedStream:
    """Shard an in-memory ``TemporalGraph`` (synthetic presets, tests)."""
    os.makedirs(out_dir, exist_ok=True)
    sizes = []
    for s, lo in enumerate(range(0, max(g.num_edges, 1), shard_edges)):
        hi = min(lo + shard_edges, g.num_edges)
        _save_shard(
            out_dir, s, g.src[lo:hi], g.dst[lo:hi], g.t[lo:hi],
            g.edge_feat[lo:hi],
            None if g.labels is None else g.labels[lo:hi])
        sizes.append(hi - lo)
    if not np.allclose(g.node_feat, 0.0):
        np.save(os.path.join(out_dir, "node_feat.npy"),
                g.node_feat.astype(np.float32))
    return _write_meta(
        out_dir, name=g.name, num_nodes=g.num_nodes, shard_edges=sizes,
        dim_edge=g.dim_edge, dim_node=g.dim_node,
        has_labels=g.labels is not None)


# ======================================================================
# JODIE CSV block reader (pandas-free, out-of-core)
# ======================================================================

def _sniff_columns(path: str, probe_rows: int = 1000) -> tuple[int, bool]:
    """(feature column count, whether a label column exists), decided from
    the widest of the first data rows — never the header, which in JODIE
    exports sometimes declares feature names the rows don't carry (and
    vice versa)."""
    cols = 0
    with open(path) as f:
        f.readline()  # header
        for _ in range(probe_rows):
            line = f.readline()
            if not line:
                break
            line = line.strip()
            if not line:
                continue
            cols = max(cols, len(line.split(",")))
    return max(cols - 4, 0), cols >= 4


def _sniff_feat_width(path: str, probe_rows: int = 1000) -> int:
    return _sniff_columns(path, probe_rows)[0]


def _parse_jodie_rows(lines: Sequence[str], n_feat: int):
    """Parse CSV data rows robustly: ragged feature columns are zero-padded
    or truncated to ``n_feat``, missing labels default to 0, integer and
    float timestamps both accepted.  Returns (users, items, t, labels,
    feats) numpy columns; blank lines are skipped."""
    users, items, ts, labels = [], [], [], []
    feats = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        parts = line.split(",")
        if len(parts) < 3:
            raise ValueError(f"unparseable JODIE row: {line!r}")
        users.append(int(float(parts[0])))
        items.append(int(float(parts[1])))
        ts.append(float(parts[2]))
        labels.append(int(float(parts[3]))
                      if len(parts) > 3 and parts[3].strip() else 0)
        row = [float(x) if x.strip() else 0.0 for x in parts[4:4 + n_feat]]
        if len(row) < n_feat:
            row.extend([0.0] * (n_feat - len(row)))
        feats.append(row)
    return (
        np.asarray(users, np.int64),
        np.asarray(items, np.int64),
        np.asarray(ts, np.float64),
        np.asarray(labels, np.int64),
        np.asarray(feats, np.float32).reshape(len(users), n_feat),
    )


def _parse_jodie_rows_fast(lines: Sequence[str], n_feat: int):
    """Vectorized parse of a WELL-FORMED block — every data row the same
    width, no empty fields — in one pass through numpy's C CSV tokenizer
    (``np.loadtxt``: the buffer is split/converted in C, no per-line Python
    loop).  Returns None when the block is ragged or irregular; the caller
    then falls back to ``_parse_jodie_rows``, whose per-line loop handles
    zero-padding, empty labels, and width mismatches row by row.  On the
    inputs the fast path accepts, both parsers produce identical columns.
    """
    try:
        a = np.loadtxt(io.StringIO("".join(lines)), delimiter=",",
                       comments=None, ndmin=2, dtype=np.float64)
    except ValueError:
        return None
    if a.size == 0 or a.shape[1] < 3:
        return None                       # <3 columns: let the fallback
    w = a.shape[1]                        # raise its diagnostic
    # nan/inf in the integer-bound columns (ids, label) would cast to
    # INT64_MIN silently; the fallback raises the proper diagnostic
    if not np.isfinite(a[:, :2]).all() or \
            (w > 3 and not np.isfinite(a[:, 3]).all()):
        return None
    n = len(a)
    feats = a[:, 4:4 + n_feat].astype(np.float32)
    if feats.shape[1] < n_feat:
        feats = np.concatenate(
            [feats, np.zeros((n, n_feat - feats.shape[1]), np.float32)],
            axis=1)
    return (
        a[:, 0].astype(np.int64),
        a[:, 1].astype(np.int64),
        a[:, 2],
        a[:, 3].astype(np.int64) if w > 3 else np.zeros(n, np.int64),
        feats.reshape(n, n_feat),
    )


def iter_jodie_blocks(
    path: str,
    *,
    block_rows: int = DEFAULT_SHARD_EDGES,
    n_feat: Optional[int] = None,
    fast: bool = True,
) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                    np.ndarray]]:
    """Stream a JODIE ``ml_<name>.csv`` as (users, items, t, labels, feats)
    blocks of ``block_rows`` rows — at no point is the whole file in RAM.

    With ``fast`` (the default) each well-formed block is parsed in one
    vectorized numpy pass; blocks with ragged/empty fields fall back to the
    robust per-line parser (results are identical either way —
    ``fast=False`` keeps the loop-only path for benchmarking/debugging).
    """
    if n_feat is None:
        n_feat = _sniff_feat_width(path)
    with open(path) as f:
        f.readline()  # header
        while True:
            lines = []
            for _ in range(block_rows):
                line = f.readline()
                if not line:
                    break
                lines.append(line)
            if not lines:
                return
            block = _parse_jodie_rows_fast(lines, n_feat) if fast else None
            if block is None:
                block = _parse_jodie_rows(lines, n_feat)
            if len(block[0]):
                yield block


def write_jodie_shards(
    csv_path: str,
    out_dir: str,
    *,
    shard_edges: int = DEFAULT_SHARD_EDGES,
    d_n: int = 172,
    name: Optional[str] = None,
) -> ShardedStream:
    """Chunked JODIE CSV -> ``tig-shards-v1`` ingestion.

    One pass over the file writing one shard at a time; item ids are stored
    raw during the pass and offset to live after user ids (the bipartite
    convention) by an in-place fix-up once the user count is known.  The
    stream must already be time-sorted (JODIE exports are); out-of-order
    rows raise rather than silently reordering a file that may not fit in
    memory.
    """
    os.makedirs(out_dir, exist_ok=True)
    n_feat, has_labels = _sniff_columns(csv_path)
    sizes: list[int] = []
    max_user = -1
    max_item = -1
    last_t = -np.inf
    s = 0
    for users, items, t, labels, feats in iter_jodie_blocks(
            csv_path, block_rows=shard_edges, n_feat=n_feat):
        if len(t) and (t[0] < last_t or np.any(np.diff(t) < 0)):
            raise ValueError(
                f"{csv_path}: timestamps are not non-decreasing; "
                "sort the export before sharding")
        last_t = float(t[-1])
        max_user = max(max_user, int(users.max()))
        max_item = max(max_item, int(items.max()))
        if feats.shape[1] == 0:
            feats = np.zeros((len(users), 1), dtype=np.float32)
        _save_shard(out_dir, s, users, items, t, feats,
                    labels if has_labels else None)
        sizes.append(len(users))
        s += 1
    if not sizes:
        raise ValueError(f"{csv_path}: no data rows")
    # fix-up pass: dst = num_users + item  (shard-sized memory at a time)
    nu = max_user + 1
    for k in range(s):
        f = os.path.join(out_dir, f"shard_{k:05d}.dst.npy")
        arr = np.load(f)
        np.save(f, arr + nu)
    return _write_meta(
        out_dir, name=name or os.path.basename(csv_path),
        num_nodes=nu + max_item + 1, shard_edges=sizes,
        dim_edge=max(n_feat, 1), dim_node=d_n,
        # a 3-column export (user,item,t) must not fabricate all-zero
        # labels for downstream node classification
        has_labels=has_labels)


# ======================================================================
# chunked device staging
# ======================================================================

def stage_device_tables(shards: ShardedStream) -> dict:
    """Device feature tables from shards WITHOUT a host-side full copy.

    The (E+1, d_e) edge-feature table (trailing zero dump row for -1
    neighbor remapping, as ``batching.make_tables``) is assembled on device:
    a donated buffer is updated shard by shard, so host memory peaks at one
    shard of rows instead of the full table.  Node features are all-zeros
    unless the stream carries a ``node_feat.npy`` (then staged the same
    way, row-chunked).
    """
    import jax
    import jax.numpy as jnp

    update = jax.jit(
        lambda buf, chunk, lo: jax.lax.dynamic_update_slice(
            buf, chunk, (lo, jnp.int32(0))),
        donate_argnums=(0,))

    efeat = jnp.zeros((shards.num_edges + 1, shards.dim_edge), jnp.float32)
    lo = 0
    for s in range(shards.num_shards):
        chunk = np.asarray(shards.load(s, "efeat"), dtype=np.float32)
        efeat = update(efeat, jnp.asarray(chunk),
                       jnp.asarray(lo, jnp.int32))
        lo += len(chunk)

    n = shards.num_nodes
    nf_path = os.path.join(shards.path, "node_feat.npy")
    nfeat = jnp.zeros((n + 1, shards.dim_node), jnp.float32)
    if os.path.exists(nf_path):
        nf = np.load(nf_path, mmap_mode="r")
        step = max(1, DEFAULT_SHARD_EDGES // max(shards.dim_node, 1))
        for lo_ in range(0, n, step):
            chunk = np.asarray(nf[lo_: lo_ + step], dtype=np.float32)
            nfeat = update(nfeat, jnp.asarray(chunk),
                           jnp.asarray(lo_, jnp.int32))
    return {"efeat": efeat, "nfeat": nfeat}


# ======================================================================
# multi-process (pod) staging
# ======================================================================

def stage_partitioned(local_rows: np.ndarray, mesh, n_global: int):
    """Assemble a "part"-sharded global array from THIS process's rows.

    ``local_rows`` holds only the rows of the caller's local devices
    (contiguous on the mesh's "part" axis — ``launch.mesh.make_tig_mesh``
    ordering); each process calls this with its own slice and jax stitches
    the global (n_global, ...) array without any process ever holding the
    full buffer — the olmax per-process-slice idiom, with the gather left
    implicit in the array's sharding instead of an eager ``all_gather``.
    Host bytes and H2D per process stay O(local devices).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    local_rows = np.ascontiguousarray(local_rows)
    spec = PartitionSpec("part", *([None] * (local_rows.ndim - 1)))
    return jax.make_array_from_process_local_data(
        NamedSharding(mesh, spec), local_rows,
        (n_global,) + local_rows.shape[1:])


def stage_replicated(x, mesh):
    """Stage ``x`` fully replicated across every device of ``mesh``
    (including non-addressable ones in a multi-process run)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.device_put(np.asarray(x), NamedSharding(mesh,
                                                       PartitionSpec()))


# ======================================================================
# double-buffered epoch prefetch
# ======================================================================

_STOP = object()     # worker shutdown sentinel


class EpochPrefetcher:
    """Depth-configurable epoch pipeline: host planning and device staging
    run ahead of the consumer on ONE persistent worker thread.

    ``build_fn(epoch)`` calls happen in strict submission order on the
    single worker (stateful planning RNGs see the serial call sequence),
    so results are bit-identical to inline planning for ANY ``depth``.
    ``to_device`` (e.g. ``jax.device_put`` / ``jnp.asarray`` mapping) also
    runs on the worker, behind a SINGLE async staging slot: up to ``depth``
    host plans may be in flight, but at most one staged-but-unclaimed plan
    holds device buffers — the next ``to_device`` starts only once the
    consumer claims the previous one via ``get``.  Device memory stays
    bounded at one epoch's plan (the double-buffer invariant) while deeper
    pipelines absorb plan-time variance on the host side.  numpy and jax
    release the GIL for bulk work, so planning/staging genuinely overlap
    compute.

        with EpochPrefetcher(build, epochs, to_device=stage, depth=2) as pf:
            for ep in range(epochs):
                plan = pf.get(ep)   # plan e ready; e+1, e+2 in flight
                ... run device epoch ...

    ``get(e)`` retrieves plan e and refills the pipeline to ``depth``
    epochs in flight.  Exceptions in the worker surface at the
    corresponding ``get`` (and cancel the pipeline: no further epoch is
    submitted).  ``depth=0`` — or ``enabled=False`` — disables the worker
    entirely and builds inline.

    Also a context manager: ``with EpochPrefetcher(...) as pf:`` closes
    the pipeline on ANY exit — including an exception mid-epoch — so the
    worker thread is joined instead of leaking past the failure.
    """

    def __init__(
        self,
        build_fn: Callable[[int], object],
        num_epochs: int,
        *,
        to_device: Optional[Callable[[object], object]] = None,
        enabled: bool = True,
        depth: int = 1,
    ):
        if depth < 0:
            raise ValueError(f"depth={depth}: expected >= 0")
        self._build = build_fn
        self._to_device = to_device
        self._n = num_epochs
        self._depth = depth if enabled else 0
        self._enabled = self._depth > 0
        self._inbox: queue.Queue = queue.Queue()
        self._futures: dict[int, queue.Queue] = {}
        self._slot = threading.Semaphore(1)     # the device staging slot
        self._closing = threading.Event()
        self._worker: Optional[threading.Thread] = None

    def _worker_loop(self) -> None:
        while True:
            job = self._inbox.get()
            if job is _STOP:
                return
            epoch, out = job
            try:
                plan = self._build(epoch)
                if self._to_device is not None:
                    self._slot.acquire()
                    if self._closing.is_set():
                        # close() raced us awake: the result would be
                        # dropped anyway — skip staging, drain to the stop
                        # sentinel
                        self._slot.release()
                        continue
                    try:
                        plan = self._to_device(plan)
                    except BaseException:
                        self._slot.release()
                        raise
                out.put((True, plan))
            except BaseException as exc:  # noqa: BLE001 — reraised at get()
                out.put((False, exc))

    def _submit(self, epoch: int) -> None:
        if epoch < 0 or epoch >= self._n or epoch in self._futures:
            return
        if self._worker is None:
            self._worker = threading.Thread(
                target=self._worker_loop, daemon=True)
            self._worker.start()
        out: queue.Queue = queue.Queue(maxsize=1)
        self._futures[epoch] = out
        self._inbox.put((epoch, out))

    def _cancel(self) -> None:
        """Drop every not-yet-claimed submission: no further builds start
        (jobs the worker already began complete into orphaned queues)."""
        self._n = 0
        self._futures.clear()
        while True:
            try:
                self._inbox.get_nowait()
            except queue.Empty:
                return

    def close(self) -> None:
        """Stop the pipeline early: pending submissions are dropped, the
        persistent worker is unparked (the staging slot is released so a
        worker waiting to stage cannot deadlock the join) and JOINED in
        bounded time — it finishes at most the job it already started,
        then exits on the stop sentinel.  In-flight results are dropped
        for GC instead of staying pinned (a full epoch plan, possibly on
        device) while the caller moves on (e.g. patience-based early stop
        or an exception unwinding the training loop)."""
        self._closing.set()
        self._cancel()
        worker, self._worker = self._worker, None
        if worker is not None:
            self._slot.release()
            self._inbox.put(_STOP)
            worker.join()

    def __enter__(self) -> "EpochPrefetcher":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def get(self, epoch: int):
        """Block until the plan for ``epoch`` is ready (building it inline
        when the pipeline is disabled) and refill the pipeline to
        ``depth`` epochs in flight."""
        if not self._enabled:
            plan = self._build(epoch)
            if self._to_device is not None:
                plan = self._to_device(plan)
            return plan
        self._submit(epoch)
        out = self._futures.pop(epoch)
        ok, plan = out.get()
        if not ok:
            self._cancel()      # the pipeline is poisoned past this epoch
            raise plan
        if self._to_device is not None:
            self._slot.release()    # claimed: free the staging slot
        for nxt in range(epoch + 1, epoch + 1 + self._depth):
            self._submit(nxt)
        return plan
