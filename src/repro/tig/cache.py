"""One LRU helper for the hand-rolled compiled-program caches.

``engine.make_eval_epoch`` and ``distributed.pac_train`` both keep small
dict caches of jitted epoch programs keyed by (config, shape) tuples.
Python dicts iterate in insertion order, so move-to-end-on-hit +
evict-front gives LRU semantics on a plain dict — no OrderedDict, and the
caches stay introspectable/patchable as plain dicts in tests.
"""

from __future__ import annotations

from typing import Callable, Hashable, MutableMapping, TypeVar

__all__ = ["lru_get"]

T = TypeVar("T")

_MISS = object()


def lru_get(
    cache: MutableMapping[Hashable, T],
    key: Hashable,
    max_size: int,
    build: Callable[[], T],
) -> T:
    """Fetch ``key`` from ``cache`` with LRU eviction, building on miss.

    A hit re-inserts the entry at the back of the iteration order (most
    recent); a miss evicts from the front until the cache is below
    ``max_size``, then stores ``build()``.  ``build`` is only called on a
    miss.
    """
    hit = cache.pop(key, _MISS)
    if hit is _MISS:
        while len(cache) >= max_size:
            cache.pop(next(iter(cache)))
        hit = build()
    cache[key] = hit
    return hit
