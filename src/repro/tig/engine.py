"""Device-resident streaming epoch engine — ONE scan-based step program.

The single source of truth for the TIG hot path.  Both the single-device
baseline (``repro.tig.train``) and the PAC distributed trainer
(``repro.tig.distributed``) drive their epochs through the scanned programs
here instead of dispatching one jitted call per batch from a Python loop:
the whole epoch — flush pending messages, embed, decode, loss, grads,
optimizer — runs as one ``lax.scan`` on device over a pre-staged
(steps, ...) batch pytree, with buffer donation so params/optimizer/memory
update in place.

``scan_train_epoch`` is written once and parameterized by:

  * ``axis``          — ``None`` for single-device; a mapped axis name for
                        DDP (gradients are ``pmean``'d over it before the
                        update), under either ``jax.vmap`` simulation or
                        ``jax.shard_map`` SPMD;
  * ``cycle_length``  — ``None`` for a plain chronological pass; an int
                        array for the paper's Alg.2 loop-within-epoch
                        semantics (reset node memory at each data-cycle
                        start, back it up at each cycle end, restore the
                        last complete backup at epoch end);
  * ``wrap_steps``    — transfer-minimal Alg.2 wrap-around ON DEVICE: the
                        host ships only the ``cycle_length`` *real* batches
                        (at ``wrap_offset`` in a flat shared grid) and the
                        scan gathers batch ``offset + s % cycle_length``
                        with ``lax.dynamic_index_in_dim`` for each of the
                        ``wrap_steps`` lockstep steps, instead of the host
                        replaying the stream to the global lockstep length.

Kernel routing (``cfg.use_pallas`` / ``cfg.kernel_backend``) happens inside
``models.step_loss``: the neighbor-aggregation attention and the GRU memory
update go through ``repro.kernels`` Pallas kernels, with the XLA path as
fallback.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.tig.models import TIGConfig, init_state, step_loss

__all__ = [
    "scan_train_epoch",
    "scan_eval_stream",
    "make_train_epoch",
    "make_eval_epoch",
]


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _donate_args(*argnums: int) -> tuple[int, ...]:
    """Buffer donation saves one params+opt+memory copy per epoch, but CPU
    jit only warns that donation is unimplemented — keep test logs clean."""
    return argnums if jax.default_backend() != "cpu" else ()


# ----------------------------------------------------------------- training

def scan_train_epoch(
    params,
    opt_state,
    state,
    batches,                 # pytree of (steps, ...) arrays
    tables,                  # {"efeat": (E+1, d_e), "nfeat": (N+1, d_n)}
    *,
    cfg: TIGConfig,
    opt: Optimizer,
    axis: Optional[str] = None,
    cycle_length=None,       # () int array or None
    wrap_steps: Optional[int] = None,
    wrap_offset=0,           # () int array — batch-grid start row
):
    """One training epoch as a single scan (traced; jit/vmap/shard_map it).

    Returns ``(params, opt_state, state, losses)`` with ``losses`` of shape
    (steps,).  With ``cycle_length`` set, ``state`` is the backup taken at
    the end of the last *complete* data cycle (paper Alg.2 lines 10-11);
    otherwise it is simply the post-stream state.

    With ``wrap_steps`` (requires ``cycle_length``), ``batches`` holds only
    the REAL batches — this device's ``cycle_length`` rows starting at
    ``wrap_offset`` of a flat grid shared across devices — and the scan
    runs ``wrap_steps`` lockstep steps, gathering batch
    ``wrap_offset + s % cycle_length`` on device.  Identical semantics to
    handing in a host-replayed (wrap_steps, ...) grid, at
    O(cycle_length) instead of O(wrap_steps) host/transfer bytes.
    """
    cycling = cycle_length is not None
    if wrap_steps is not None and not cycling:
        raise ValueError("wrap_steps requires cycle_length")
    fresh = init_state(cfg, state["mem"].shape[0] - 1)

    def step_body(params, opt_state, state, batch):
        (loss, (state, _aux)), grads = jax.value_and_grad(
            step_loss, has_aux=True
        )(params, state, batch, tables, cfg)
        if axis is not None:
            grads = jax.lax.pmean(grads, axis)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, state, loss

    if not cycling:
        def scan_step(carry, batch):
            params, opt_state, state = carry
            params, opt_state, state, loss = step_body(
                params, opt_state, state, batch)
            return (params, opt_state, state), loss

        (params, opt_state, state), losses = jax.lax.scan(
            scan_step, (params, opt_state, state), batches)
        return params, opt_state, state, losses

    n_cycle = jnp.asarray(cycle_length, jnp.int32)

    if wrap_steps is not None:
        offset = jnp.asarray(wrap_offset, jnp.int32)

        def wrap_step(carry, s):
            params, opt_state, state, backup = carry
            batch = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, offset + s % n_cycle, 0, keepdims=False),
                batches)
            is_start = (s % n_cycle) == 0
            state = _tree_where(is_start, fresh, state)
            params, opt_state, state, loss = step_body(
                params, opt_state, state, batch)
            is_end = ((s + 1) % n_cycle) == 0
            backup = _tree_where(is_end, state, backup)
            return (params, opt_state, state, backup), loss

        (params, opt_state, _state, backup), losses = jax.lax.scan(
            wrap_step, (params, opt_state, state, fresh),
            jnp.arange(wrap_steps, dtype=jnp.int32))
        return params, opt_state, backup, losses

    def scan_step(carry, batch):
        params, opt_state, state, backup, s = carry
        # Alg.2 lines 6-7: reset memory at each data-cycle start
        is_start = (s % n_cycle) == 0
        state = _tree_where(is_start, fresh, state)
        params, opt_state, state, loss = step_body(
            params, opt_state, state, batch)
        # Alg.2 lines 10-11: back up memory at each data-cycle end
        is_end = ((s + 1) % n_cycle) == 0
        backup = _tree_where(is_end, state, backup)
        return (params, opt_state, state, backup, s + 1), loss

    carry0 = (params, opt_state, state, fresh, jnp.zeros((), jnp.int32))
    (params, opt_state, _state, backup, _), losses = jax.lax.scan(
        scan_step, carry0, batches)
    # epoch end: restore the latest complete-cycle memory (Alg.2)
    return params, opt_state, backup, losses


def make_train_epoch(cfg: TIGConfig, opt: Optimizer):
    """jit'd single-device epoch: (params, opt_state, state, batches,
    tables) -> (params, opt_state, state, losses), donating the carried
    buffers."""
    fn = functools.partial(scan_train_epoch, cfg=cfg, opt=opt)
    return jax.jit(fn, donate_argnums=_donate_args(0, 1, 2))


# --------------------------------------------------------------- evaluation

def scan_eval_stream(
    params,
    state,
    batches,                 # pytree of (steps, ...) arrays
    tables,
    *,
    cfg: TIGConfig,
    collect_embeddings: bool = False,
):
    """Forward-only scan over a chronological stream (memory keeps
    updating, params frozen).

    Returns ``(state, aux)`` with ``aux`` holding (steps, B)-stacked
    ``pos_logit`` / ``neg_logit``, plus (steps, B, d) ``src_embed`` when
    ``collect_embeddings`` (off by default — the stack is steps*B*d floats,
    only the node-classification protocol needs it).
    """

    def scan_step(state, batch):
        _loss, (state, aux) = step_loss(params, state, batch, tables, cfg)
        out = {"pos_logit": aux["pos_logit"],
               "neg_logit": aux["neg_logit"]}
        if collect_embeddings:
            out["src_embed"] = aux["src_embed"]
        return state, out

    return jax.lax.scan(scan_step, state, batches)


_EVAL_PROGRAMS: dict = {}
_EVAL_PROGRAMS_MAX = 32          # bounded LRU: evict least-recently-USED,
                                 # don't pin every compiled program for
                                 # process lifetime


def make_eval_epoch(cfg: TIGConfig, *, collect_embeddings: bool = False):
    """jit'd eval-stream program: (params, state, batches, tables) ->
    (state, stacked aux).

    Programs are cached per (cfg, collect_embeddings) with LRU eviction
    (hits move to the back of the dict, the front is evicted): per-epoch
    validation during training, the protocol driver's train replay, and
    final scoring all reuse one compiled scan instead of re-tracing a
    fresh ``jax.jit`` wrapper on every call, and an alternating
    train/val/protocol workload cycling through >32 configs can't thrash
    a program it keeps coming back to.

    No buffer donation here: callers legitimately reuse the input state
    (e.g. train_single evaluates val from the epoch-end memory it also
    keeps for the returned result)."""
    key = (dataclasses.astuple(cfg), collect_embeddings)
    fn = _EVAL_PROGRAMS.pop(key, None)
    if fn is None:
        while len(_EVAL_PROGRAMS) >= _EVAL_PROGRAMS_MAX:
            _EVAL_PROGRAMS.pop(next(iter(_EVAL_PROGRAMS)))
        # the key is by VALUE: close over a defensive copy so in-place
        # mutation of the caller's cfg can't desync a cached program
        fn = jax.jit(functools.partial(
            scan_eval_stream, cfg=dataclasses.replace(cfg),
            collect_embeddings=collect_embeddings))
    _EVAL_PROGRAMS[key] = fn   # (re-)insert at the back: most recent
    return fn
