"""Device-resident streaming epoch engine — ONE scan-based step program.

The single source of truth for the TIG hot path.  Both the single-device
baseline (``repro.tig.train``) and the PAC distributed trainer
(``repro.tig.distributed``) drive their epochs through the scanned programs
here instead of dispatching one jitted call per batch from a Python loop:
the whole epoch — flush pending messages, embed, decode, loss, grads,
optimizer — runs as one ``lax.scan`` on device over a pre-staged
(steps, ...) batch pytree, with buffer donation so params/optimizer/memory
update in place.

``scan_train_epoch`` is written once and parameterized by:

  * ``axis``          — ``None`` for single-device; a mapped axis name for
                        DDP (gradients are ``pmean``'d over it before the
                        update), under either ``jax.vmap`` simulation or
                        ``jax.shard_map`` SPMD;
  * ``cycle_length``  — ``None`` for a plain chronological pass; an int
                        array for the paper's Alg.2 loop-within-epoch
                        semantics (reset node memory at each data-cycle
                        start, back it up at each cycle end, restore the
                        last complete backup at epoch end);
  * ``wrap_steps``    — transfer-minimal Alg.2 wrap-around ON DEVICE: the
                        host ships only the ``cycle_length`` *real* batches
                        (at ``wrap_offset`` in a flat shared grid) and the
                        scan gathers batch ``offset + s % cycle_length``
                        with ``lax.dynamic_index_in_dim`` for each of the
                        ``wrap_steps`` lockstep steps, instead of the host
                        replaying the stream to the global lockstep length.

Kernel routing (``cfg.use_pallas`` / ``cfg.kernel_backend``) happens inside
``models.step_loss``: the neighbor-aggregation attention and the GRU memory
update go through ``repro.kernels`` Pallas kernels, with the XLA path as
fallback.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.optim import Optimizer
from repro.tig.cache import lru_get
from repro.tig.models import TIGConfig, init_state, step_loss

__all__ = [
    "sample_batch_neighbors",
    "scan_train_epoch",
    "scan_eval_stream",
    "make_train_epoch",
    "make_eval_epoch",
    "donate_args",
]


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def donate_args(*argnums: int) -> tuple[int, ...]:
    """Buffer donation saves one params+opt+memory copy per epoch (and
    lets the PAC scan-only program consume its per-epoch plan buffers in
    place), but CPU jit only warns that donation is unimplemented — keep
    test logs clean."""
    return argnums if jax.default_backend() != "cpu" else ()


_donate_args = donate_args    # internal alias (pre-PR 9 name)


def sample_batch_neighbors(batch, tcsr, batch_of, cfg: TIGConfig):
    """Augment a raw-edge batch with device-sampled neighbor grids.

    ``batch`` is one (B,)-shaped raw batch (a ``plan="device"`` program
    row); ``tcsr`` the staged ``ChronoNeighborIndex.device_export`` dict;
    ``batch_of`` this row's batch index within its stream.  Adds the nine
    ``nbr_* / nbrt_* / nbre_*`` keys exactly as the host planner would:
    one fused (3B,) sample over src ++ dst ++ neg, with dead rows (padding
    / invalid) redirected to node 0 and their ids/edge rows re-masked to
    -1 afterwards — times are left as sampled, matching the host grid
    bit-for-bit.

    With ``cfg.n_layers > 1`` the grids come back (L, B, K): still ONE
    fused (L*3B,) launch, with per-row windows so layer l's grid holds
    the (L-1-l)-th most-recent K-window (the staged export must have
    ``depth >= n_layers``).  Row l = L-1 (window 0) is bit-identical to
    the single-layer grid.
    """
    k = cfg.num_neighbors
    b = batch["src"].shape[0]
    ids3 = jnp.concatenate([batch["src"], batch["dst"], batch["neg"]])
    alive = (ids3 >= 0) & jnp.tile(batch["valid"], 3)
    clean = jnp.where(alive, ids3, 0).astype(jnp.int32)
    n_l = cfg.n_layers
    if n_l > 1:
        win = jnp.repeat(jnp.arange(n_l - 1, -1, -1, dtype=jnp.int32),
                         3 * b)
        nb, nt, ne = ops.neighbor_sample(
            tcsr, jnp.tile(clean, n_l), batch_of, k,
            backend=cfg.backend, window=win)
        nb = jnp.where(alive[:, None], nb.reshape(n_l, 3 * b, k), -1)
        nt = nt.reshape(n_l, 3 * b, k)
        ne = jnp.where(alive[:, None], ne.reshape(n_l, 3 * b, k), -1)
        out = dict(batch)
        for j, role in enumerate(("src", "dst", "neg")):
            rows = slice(j * b, (j + 1) * b)
            out[f"nbr_{role}"] = nb[:, rows]
            out[f"nbrt_{role}"] = nt[:, rows]
            out[f"nbre_{role}"] = ne[:, rows]
        return out
    nb, nt, ne = ops.neighbor_sample(
        tcsr, clean, batch_of, k, backend=cfg.backend)
    nb = jnp.where(alive[:, None], nb, -1)
    ne = jnp.where(alive[:, None], ne, -1)
    out = dict(batch)
    for j, role in enumerate(("src", "dst", "neg")):
        rows = slice(j * b, (j + 1) * b)
        out[f"nbr_{role}"] = nb[rows]
        out[f"nbrt_{role}"] = nt[rows]
        out[f"nbre_{role}"] = ne[rows]
    return out


# ----------------------------------------------------------------- training

def scan_train_epoch(
    params,
    opt_state,
    state,
    batches,                 # pytree of (steps, ...) arrays
    tables,                  # {"efeat": (E+1, d_e), "nfeat": (N+1, d_n)}
    *,
    cfg: TIGConfig,
    opt: Optimizer,
    axis: Optional[str] = None,
    cycle_length=None,       # () int array or None
    wrap_steps: Optional[int] = None,
    wrap_offset=0,           # () int array — batch-grid start row
    tcsr=None,               # staged device_export dict or None
):
    """One training epoch as a single scan (traced; jit/vmap/shard_map it).

    Returns ``(params, opt_state, state, losses)`` with ``losses`` of shape
    (steps,).  With ``cycle_length`` set, ``state`` is the backup taken at
    the end of the last *complete* data cycle (paper Alg.2 lines 10-11);
    otherwise it is simply the post-stream state.

    With ``wrap_steps`` (requires ``cycle_length``), ``batches`` holds only
    the REAL batches — this device's ``cycle_length`` rows starting at
    ``wrap_offset`` of a flat grid shared across devices — and the scan
    runs ``wrap_steps`` lockstep steps, gathering batch
    ``wrap_offset + s % cycle_length`` on device.  Identical semantics to
    handing in a host-replayed (wrap_steps, ...) grid, at
    O(cycle_length) instead of O(wrap_steps) host/transfer bytes.  The
    pod-scale row-range-sharded layout (``plan_epoch(layout="sharded")``)
    reuses this path with ``wrap_offset == 0``: each device holds only
    its OWN zero-padded (rows_cap, ...) grid slab, and since
    ``s % cycle_length < cycle_length`` the gather never reads a padding
    row.

    With ``tcsr`` (a staged ``ChronoNeighborIndex.device_export`` dict),
    ``batches`` is a raw-edge program (``plan="device"``) and each step
    samples its neighbor grids on device at its batch index — ``s`` for a
    plain pass, ``s % cycle_length`` under replay/wrap-around (each
    replayed row re-samples as of its REAL batch, exactly like the host
    planner's grid for that row).
    """
    cycling = cycle_length is not None
    if wrap_steps is not None and not cycling:
        raise ValueError("wrap_steps requires cycle_length")
    fresh = init_state(cfg, state["mem"].shape[0] - 1)

    def step_body(params, opt_state, state, batch, b_of):
        if tcsr is not None:
            batch = sample_batch_neighbors(batch, tcsr, b_of, cfg)
        (loss, (state, _aux)), grads = jax.value_and_grad(
            step_loss, has_aux=True
        )(params, state, batch, tables, cfg)
        if axis is not None:
            grads = jax.lax.pmean(grads, axis)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, state, loss

    if not cycling:
        steps = jax.tree.leaves(batches)[0].shape[0]

        def scan_step(carry, xs):
            batch, s = xs
            params, opt_state, state = carry
            params, opt_state, state, loss = step_body(
                params, opt_state, state, batch, s)
            return (params, opt_state, state), loss

        (params, opt_state, state), losses = jax.lax.scan(
            scan_step, (params, opt_state, state),
            (batches, jnp.arange(steps, dtype=jnp.int32)))
        return params, opt_state, state, losses

    n_cycle = jnp.asarray(cycle_length, jnp.int32)

    if wrap_steps is not None:
        offset = jnp.asarray(wrap_offset, jnp.int32)

        def wrap_step(carry, s):
            params, opt_state, state, backup = carry
            batch = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, offset + s % n_cycle, 0, keepdims=False),
                batches)
            is_start = (s % n_cycle) == 0
            state = _tree_where(is_start, fresh, state)
            params, opt_state, state, loss = step_body(
                params, opt_state, state, batch, s % n_cycle)
            is_end = ((s + 1) % n_cycle) == 0
            backup = _tree_where(is_end, state, backup)
            return (params, opt_state, state, backup), loss

        (params, opt_state, _state, backup), losses = jax.lax.scan(
            wrap_step, (params, opt_state, state, fresh),
            jnp.arange(wrap_steps, dtype=jnp.int32))
        return params, opt_state, backup, losses

    def scan_step(carry, batch):
        params, opt_state, state, backup, s = carry
        # Alg.2 lines 6-7: reset memory at each data-cycle start
        is_start = (s % n_cycle) == 0
        state = _tree_where(is_start, fresh, state)
        params, opt_state, state, loss = step_body(
            params, opt_state, state, batch, s % n_cycle)
        # Alg.2 lines 10-11: back up memory at each data-cycle end
        is_end = ((s + 1) % n_cycle) == 0
        backup = _tree_where(is_end, state, backup)
        return (params, opt_state, state, backup, s + 1), loss

    carry0 = (params, opt_state, state, fresh, jnp.zeros((), jnp.int32))
    (params, opt_state, _state, backup, _), losses = jax.lax.scan(
        scan_step, carry0, batches)
    # epoch end: restore the latest complete-cycle memory (Alg.2)
    return params, opt_state, backup, losses


def make_train_epoch(cfg: TIGConfig, opt: Optimizer):
    """jit'd single-device epoch: (params, opt_state, state, batches,
    tables) -> (params, opt_state, state, losses), donating the carried
    buffers."""
    fn = functools.partial(scan_train_epoch, cfg=cfg, opt=opt)
    return jax.jit(fn, donate_argnums=_donate_args(0, 1, 2))


# --------------------------------------------------------------- evaluation

def scan_eval_stream(
    params,
    state,
    batches,                 # pytree of (steps, ...) arrays
    tables,
    *,
    cfg: TIGConfig,
    collect_embeddings: bool = False,
    tcsr=None,
):
    """Forward-only scan over a chronological stream (memory keeps
    updating, params frozen).

    Returns ``(state, aux)`` with ``aux`` holding (steps, B)-stacked
    ``pos_logit`` / ``neg_logit``, plus (steps, B, d) ``src_embed`` when
    ``collect_embeddings`` (off by default — the stack is steps*B*d floats,
    only the node-classification protocol needs it).

    With ``tcsr`` (staged ``device_export`` dict) ``batches`` is a
    raw-edge program and each step samples its neighbor grids on device.
    """
    steps = jax.tree.leaves(batches)[0].shape[0]

    def scan_step(state, xs):
        batch, s = xs
        if tcsr is not None:
            batch = sample_batch_neighbors(batch, tcsr, s, cfg)
        _loss, (state, aux) = step_loss(params, state, batch, tables, cfg)
        out = {"pos_logit": aux["pos_logit"],
               "neg_logit": aux["neg_logit"]}
        if collect_embeddings:
            out["src_embed"] = aux["src_embed"]
            # dst too: the restarter's embedding bank needs coverage of
            # nodes that only ever appear as destinations (bipartite TIGs)
            out["dst_embed"] = aux["dst_embed"]
        return state, out

    return jax.lax.scan(scan_step, state,
                        (batches, jnp.arange(steps, dtype=jnp.int32)))


_EVAL_PROGRAMS: dict = {}
_EVAL_PROGRAMS_MAX = 32          # bounded LRU: evict least-recently-USED,
                                 # don't pin every compiled program for
                                 # process lifetime


def make_eval_epoch(cfg: TIGConfig, *, collect_embeddings: bool = False):
    """jit'd eval-stream program: (params, state, batches, tables) ->
    (state, stacked aux).

    Programs are cached per (cfg, collect_embeddings) with LRU eviction
    (hits move to the back of the dict, the front is evicted): per-epoch
    validation during training, the protocol driver's train replay, and
    final scoring all reuse one compiled scan instead of re-tracing a
    fresh ``jax.jit`` wrapper on every call, and an alternating
    train/val/protocol workload cycling through >32 configs can't thrash
    a program it keeps coming back to.

    No buffer donation here: callers legitimately reuse the input state
    (e.g. train_single evaluates val from the epoch-end memory it also
    keeps for the returned result).

    The returned program accepts an optional ``tcsr=`` keyword for
    device-planned (raw-edge) batch programs; passing it traces a second
    variant under the same jit wrapper."""
    # astuple(cfg) already covers every field (n_layers included — it is
    # appended LAST so positional consumers stay valid); the lane-padded
    # dims the MXU tier actually launches are keyed explicitly so a
    # padding-rule change can never alias two different executables
    key = (dataclasses.astuple(cfg),
           (cfg.n_layers, ops.lane_pad(cfg.dim), ops.lane_pad(cfg.msg_dim)),
           collect_embeddings)
    # the key is by VALUE: close over a defensive copy so in-place
    # mutation of the caller's cfg can't desync a cached program
    return lru_get(
        _EVAL_PROGRAMS, key, _EVAL_PROGRAMS_MAX,
        lambda: jax.jit(functools.partial(
            scan_eval_stream, cfg=dataclasses.replace(cfg),
            collect_embeddings=collect_embeddings)))
