"""Neural building blocks for TIG models (raw JAX, functional params).

Implements the module palette of paper Fig.6 — Message (MSG), Aggregation,
State Update (UPD: GRU/RNN cells), Embedding (identity / Jodie time
projection / temporal graph attention) and the link decoder — as pure
``init``/``apply`` function pairs over parameter pytrees.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense",
    "mlp_init", "mlp",
    "gru_init", "gru",
    "rnn_init", "rnn",
    "attn_init", "temporal_attention",
    "stacked_attn_init", "stacked_temporal_attention",
    "restarter_init", "restarter",
]


def dense_init(key, d_in: int, d_out: int, scale: float | None = None) -> dict:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    wkey, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(wkey, (d_in, d_out), jnp.float32) * scale,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def mlp_init(key, dims: Sequence[int]) -> dict:
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"l{i}": dense_init(keys[i], dims[i], dims[i + 1])
        for i in range(len(dims) - 1)
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x)
        if i + 1 < n:
            x = jax.nn.relu(x)
    return x


def restarter_init(key, d_in: int, d_mem: int, n_mem: int = 1,
                   d_hidden: int | None = None) -> dict:
    """TIGER-style restarter head: an MLP that maps a node's last collected
    embedding (++ static features ++ Φ(Δt since that embedding)) back to
    its memory row(s) — ``n_mem`` = 2 regresses TIGE's dual memory in one
    head.  Reconstructing memory this way is O(N) in nodes instead of the
    O(E) stream replay, which is what makes replayless warm-up
    (``run_protocol(warm="restart")``) and host-loss recovery affordable."""
    d_hidden = d_hidden if d_hidden is not None else max(2 * d_mem, d_in)
    return mlp_init(key, [d_in, d_hidden, n_mem * d_mem])


def restarter(p: dict, x: jnp.ndarray, d_mem: int,
              n_mem: int = 1) -> jnp.ndarray:
    """Apply the restarter head: (..., d_in) -> (..., n_mem, d_mem)."""
    out = mlp(p, x)
    return out.reshape(x.shape[:-1] + (n_mem, d_mem))


def gru_init(key, d_in: int, d_h: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "xz": dense_init(k1, d_in, 3 * d_h),
        "hz": dense_init(k2, d_h, 3 * d_h),
    }


def gru(p: dict, x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Standard GRU cell: the paper's default UPD module (TGN/TIGE)."""
    d_h = h.shape[-1]
    gx = dense(p["xz"], x)
    gh = dense(p["hz"], h)
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * n + z * h


def rnn_init(key, d_in: int, d_h: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {"x": dense_init(k1, d_in, d_h), "h": dense_init(k2, d_h, d_h)}


def rnn(p: dict, x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """tanh-RNN cell: Jodie's UPD module."""
    return jnp.tanh(dense(p["x"], x) + dense(p["h"], h))


def attn_init(key, d_node: int, d_kv: int, d_out: int, n_heads: int) -> dict:
    """Temporal graph attention (TGN embedding module, 1 layer).

    Query dim: d_node (node state ++ time enc already concatenated by the
    caller); key/value dim: d_kv (neighbor state ++ edge feat ++ time enc).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_h = d_out
    assert d_h % n_heads == 0
    return {
        "q": dense_init(k1, d_node, d_h),
        "k": dense_init(k2, d_kv, d_h),
        "v": dense_init(k3, d_kv, d_h),
        "o": dense_init(k4, d_node + d_h, d_out),
    }


def temporal_attention(
    p: dict,
    query_in: jnp.ndarray,    # (B, d_node)
    kv_in: jnp.ndarray,       # (B, K, d_kv)
    mask: jnp.ndarray,        # (B, K) bool — True for real neighbors
    n_heads: int = 2,
    backend: str | None = "xla",
) -> jnp.ndarray:
    """Masked single-layer multi-head attention over sampled neighbors.

    ``backend``: "xla" (inline jnp), or "auto"/"pallas"/"interpret" to route
    the fused attention core through ``repro.kernels.ops``.
    """
    nh = n_heads
    b, k, _ = kv_in.shape
    q = dense(p["q"], query_in).reshape(b, nh, -1)           # (B, H, dh)
    kk = dense(p["k"], kv_in).reshape(b, k, nh, -1)          # (B, K, H, dh)
    vv = dense(p["v"], kv_in).reshape(b, k, nh, -1)
    if backend != "xla":
        from repro.kernels import ops
        ctx = ops.temporal_attention(q, kk, vv, mask,
                                     backend=backend).reshape(b, -1)
    else:
        scores = jnp.einsum("bhd,bkhd->bhk", q, kk) / jnp.sqrt(q.shape[-1])
        scores = jnp.where(mask[:, None, :], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        # nodes with zero neighbors: make attention output exactly zero
        any_nbr = mask.any(axis=-1)[:, None, None]
        att = jnp.where(any_nbr, att, 0.0)
        ctx = jnp.einsum("bhk,bkhd->bhd", att, vv).reshape(b, -1)
    return dense(p["o"], jnp.concatenate([query_in, ctx], axis=-1))


def stacked_attn_init(key, n_layers: int, d_node: int, d_kv: int,
                      d_out: int, n_heads: int) -> dict:
    """Per-layer attention params stacked on a leading (L,) axis.

    The ``Stacked``-module idiom: every leaf of ``attn_init``'s pytree gains
    a leading layer axis so ``lax.scan`` can sweep one compiled layer block
    over all L layers instead of unrolling L separate graphs.  Layer 0's
    query is the memory read-out, so every layer maps d_node -> d_out and
    requires d_out == the memory dim (true for the TGN/TIGE embedding).
    """
    layers = [attn_init(k, d_node, d_kv, d_out, n_heads)
              for k in jax.random.split(key, n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def stacked_temporal_attention(
    p_stack: dict,            # attn params, every leaf (L, ...)
    h0: jnp.ndarray,          # (B, d) initial query state (memory read-out)
    extra: jnp.ndarray,       # (B, d_extra) static query tail [nfeat ; Phi(0)]
    kv_in: jnp.ndarray,       # (L, B, K, d_kv) per-layer neighbor features
    mask: jnp.ndarray,        # (L, B, K) bool
    n_heads: int = 2,
    backend: str | None = "xla",
) -> jnp.ndarray:
    """L-layer temporal attention as a fold compiled as ONE layer block.

    ``lax.scan`` carries the refined node state h; each step rebuilds the
    layer's query as ``[h ; extra]`` and attends over that layer's neighbor
    grid.  With L == 1 this is exactly ``temporal_attention`` on
    ``concat([h0, extra])`` — the single-layer path bit for bit.
    """

    def body(h, layer):
        p_l, kv_l, m_l = layer
        q_in = jnp.concatenate([h, extra], axis=-1)
        h = temporal_attention(p_l, q_in, kv_l, m_l,
                               n_heads=n_heads, backend=backend)
        return h, None

    h, _ = jax.lax.scan(body, h0, (p_stack, kv_in, mask))
    return h
