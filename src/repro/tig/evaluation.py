"""Evaluation metrics (numpy; no sklearn offline) + node classification.

Average Precision for temporal link prediction (paper Tab.IV) and AUROC for
dynamic node classification (paper Tab.V).  ``link_prediction_metrics``
assembles the full transductive + inductive metric row from paired
positive/negative logits — the one place the protocol layer's numbers are
computed.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["average_precision", "roc_auc", "link_prediction_metrics"]


def average_precision(y_true: np.ndarray, scores: np.ndarray) -> float:
    """AP = sum_k P(k) * (R(k) - R(k-1)) over descending-score ranking."""
    y_true = np.asarray(y_true).astype(np.float64)
    scores = np.asarray(scores).astype(np.float64)
    order = np.argsort(-scores, kind="stable")
    y = y_true[order]
    tp = np.cumsum(y)
    total_pos = y.sum()
    if total_pos == 0:
        return 0.0
    precision = tp / np.arange(1, len(y) + 1)
    recall = tp / total_pos
    prev_recall = np.concatenate([[0.0], recall[:-1]])
    return float(np.sum(precision * (recall - prev_recall)))


def roc_auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    """AUROC via the Mann-Whitney U statistic (tie-aware through ranks)."""
    y_true = np.asarray(y_true).astype(bool)
    scores = np.asarray(scores).astype(np.float64)
    n_pos = int(y_true.sum())
    n_neg = len(y_true) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    # average ranks (ties averaged)
    order = np.argsort(scores, kind="stable")
    ranks = np.empty(len(scores), dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    # tie correction: average ranks within equal-score groups
    sorted_scores = scores[order]
    uniq, inv, counts = np.unique(sorted_scores, return_inverse=True,
                                  return_counts=True)
    if len(uniq) != len(sorted_scores):
        start = np.concatenate([[0], np.cumsum(counts)[:-1]])
        avg = start + (counts + 1) / 2.0
        ranks[order] = avg[inv]
    r_pos = ranks[y_true].sum()
    u = r_pos - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


def link_prediction_metrics(
    pos_logit: np.ndarray,
    neg_logit: np.ndarray,
    inductive_mask: Optional[np.ndarray] = None,
) -> dict:
    """AP/AUROC over paired positive/negative logits (one negative per
    positive, the JODIE/TGN convention).

    ``inductive_mask`` — one bool per positive/negative pair — restricts a
    second AP/AUROC to the inductive subset (edges touching
    never-seen-in-train nodes, paper Tab.IV); NaN when the subset is empty.
    """
    pos = np.asarray(pos_logit, np.float64).reshape(-1)
    neg = np.asarray(neg_logit, np.float64).reshape(-1)
    y = np.concatenate([np.ones_like(pos), np.zeros_like(neg)])
    s = np.concatenate([pos, neg])
    out = {"ap": average_precision(y, s), "auc": roc_auc(y, s)}
    if inductive_mask is not None:
        m = np.asarray(inductive_mask, dtype=bool).reshape(-1)
        if m.shape[0] != len(pos):
            raise ValueError(
                f"inductive_mask has {m.shape[0]} entries for {len(pos)} "
                "positive/negative pairs")
        if m.any():
            y_i = np.concatenate([np.ones(int(m.sum())),
                                  np.zeros(int(m.sum()))])
            s_i = np.concatenate([pos[m], neg[m]])
            out["ap_inductive"] = average_precision(y_i, s_i)
            out["auc_inductive"] = roc_auc(y_i, s_i)
        else:
            out["ap_inductive"] = float("nan")
            out["auc_inductive"] = float("nan")
    return out
