"""Host-side temporal neighbor sampling (most-recent-K ring buffers).

TIG embedding modules aggregate over a node's *temporal* neighbors — edges
that happened strictly before the current batch (no future leakage).  Like
production TIG systems (TGN's NeighborFinder, TGL's T-CSR sampler), the
neighbor index lives on the host: the jitted device step receives, per batch,
the pre-sampled neighbor ids / times / edge indices and gathers features and
memory rows on device.

``RecentNeighborBuffer`` keeps, per node, a ring buffer of its K most recent
(neighbor id, timestamp, edge index) triples — the "most recent neighbors"
sampling the paper's Eq.1 intuition is built on ("more recent events often
have a greater impact").
"""

from __future__ import annotations

import numpy as np

__all__ = ["RecentNeighborBuffer"]


class RecentNeighborBuffer:
    """Most-recent-K temporal neighbor index (mutable, host-side).

    All arrays use -1 for empty slots.  ``sample`` must be called *before*
    ``update`` for the same batch (neighbors strictly precede the batch).
    """

    def __init__(self, num_nodes: int, k: int):
        self.num_nodes = num_nodes
        self.k = k
        self.nbr = np.full((num_nodes, k), -1, dtype=np.int64)
        self.time = np.full((num_nodes, k), -1.0, dtype=np.float64)
        self.eidx = np.full((num_nodes, k), -1, dtype=np.int64)
        self.ptr = np.zeros(num_nodes, dtype=np.int64)

    def sample(self, nodes: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return the K most recent neighbors of ``nodes``.

        Shapes: (len(nodes), K) each of ids / times / edge indices,
        ordered oldest -> newest, -1-padded.
        """
        nodes = np.asarray(nodes, dtype=np.int64)
        ids = self.nbr[nodes]
        tms = self.time[nodes]
        eix = self.eidx[nodes]
        # roll each row so slots are oldest->newest (ring pointer varies)
        p = self.ptr[nodes] % self.k
        col = (np.arange(self.k)[None, :] + p[:, None]) % self.k
        rows = np.arange(len(nodes))[:, None]
        return ids[rows, col], tms[rows, col], eix[rows, col]

    def update(self, src: np.ndarray, dst: np.ndarray,
               t: np.ndarray, eidx: np.ndarray) -> None:
        """Push each interaction into both endpoints' ring buffers, in order
        (duplicates within the batch are applied sequentially, preserving
        exact chronology even when a node interacts repeatedly)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        eidx = np.asarray(eidx, np.int64)
        nodes = np.concatenate([src, dst])
        others = np.concatenate([dst, src])
        times = np.concatenate([t, t])
        eix = np.concatenate([eidx, eidx])
        order = np.argsort(times, kind="stable")
        for n, o, tt, ee in zip(nodes[order], others[order],
                                times[order], eix[order]):
            slot = self.ptr[n] % self.k
            self.nbr[n, slot] = o
            self.time[n, slot] = tt
            self.eidx[n, slot] = ee
            self.ptr[n] += 1

    def copy(self) -> "RecentNeighborBuffer":
        out = RecentNeighborBuffer(self.num_nodes, self.k)
        out.nbr = self.nbr.copy()
        out.time = self.time.copy()
        out.eidx = self.eidx.copy()
        out.ptr = self.ptr.copy()
        return out
