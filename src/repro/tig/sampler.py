"""Host-side temporal neighbor sampling.

TIG embedding modules aggregate over a node's *temporal* neighbors — edges
that happened strictly before the current batch (no future leakage).  Like
production TIG systems (TGN's NeighborFinder, TGL's T-CSR sampler), the
neighbor index lives on the host: the jitted device step receives, per batch,
the pre-sampled neighbor ids / times / edge indices and gathers features and
memory rows on device.

Two implementations:

``ChronoNeighborIndex`` — the training-path index (TGL-style vectorized
T-CSR).  Built ONCE per stream with ``np.lexsort``: all 2E endpoint events
are sorted by (node, chronological rank) so each node owns one contiguous,
time-sorted segment.  Sampling the K most recent neighbors *as of* any batch
boundary is then pure ``searchsorted`` + slicing — no per-edge Python work
anywhere.  A ``NeighborSnapshot`` captures the index state after a stream so
a later stream (val/test continuation) can pick up the history.

``RecentNeighborBuffer`` — the original mutable ring-buffer index (kept as
the reference oracle for property tests; O(E) Python-interpreted ``update``).
Both produce identical samples: K most recent (id, time, edge) triples per
node, ordered oldest -> newest, front-padded with -1.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence, Union

import numpy as np

__all__ = ["RecentNeighborBuffer", "NeighborSnapshot", "ChronoNeighborIndex"]

Chunk = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def _aligned_chunks(chunks: Iterable[Chunk], align: int) -> Iterable[Chunk]:
    """Re-chunk a (src, dst, t, eidx) stream so every boundary (except the
    final tail) is a multiple of ``align`` — i.e. no batch straddles two
    chunks.  Carries a leftover buffer across input chunks."""
    buf: Chunk | None = None
    for chunk in chunks:
        if buf is not None:
            chunk = tuple(np.concatenate([b, c])
                          for b, c in zip(buf, chunk))  # type: ignore
            buf = None
        n = len(chunk[0])
        keep = (n // align) * align
        if keep:
            yield tuple(c[:keep] for c in chunk)  # type: ignore
        if keep < n:
            buf = tuple(np.asarray(c[keep:]) for c in chunk)  # type: ignore
    if buf is not None and len(buf[0]):
        yield buf


@dataclasses.dataclass
class NeighborSnapshot:
    """Per-node K most recent neighbors after a stream was consumed.

    Layout matches ``RecentNeighborBuffer.sample`` output: rows ordered
    oldest -> newest with empty slots as -1 at the FRONT.
    """

    nbr: np.ndarray    # (N, K) int64, -1 for empty
    time: np.ndarray   # (N, K) float64, -1.0 for empty
    eidx: np.ndarray   # (N, K) int64, -1 for empty

    @property
    def num_nodes(self) -> int:
        return self.nbr.shape[0]

    @property
    def k(self) -> int:
        return self.nbr.shape[1]

    @classmethod
    def empty(cls, num_nodes: int, k: int) -> "NeighborSnapshot":
        return cls(
            nbr=np.full((num_nodes, k), -1, dtype=np.int64),
            time=np.full((num_nodes, k), -1.0, dtype=np.float64),
            eidx=np.full((num_nodes, k), -1, dtype=np.int64),
        )


class ChronoNeighborIndex:
    """Vectorized chronological neighbor index over a full edge stream.

    Endpoint events are ranked exactly as the streaming ring buffer would
    apply them: batch by batch, and within a batch by a stable sort on event
    time (so equal-time src-side events precede dst-side events — the ring
    buffer's ``concatenate([src, dst])`` + stable-argsort order).  Events are
    then sorted by (node, rank) into per-node contiguous segments (T-CSR).

    ``sample`` with a per-row batch index returns, for each queried node, its
    K most recent events among {history} ∪ {stream events in earlier
    batches} — identical to replaying sample/update with a ring buffer.
    """

    def __init__(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        t: np.ndarray,
        eidx: np.ndarray,
        num_nodes: int,
        k: int,
        batch_size: int,
        history: NeighborSnapshot | None = None,
    ):
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        t = np.asarray(t, np.float64)
        eidx = np.asarray(eidx, np.int64)
        n_edges = len(src)
        self.num_nodes = num_nodes
        self.k = k
        self.batch_size = batch_size
        self.num_batches = max(1, -(-n_edges // batch_size)) if n_edges else 0

        edge_i = np.arange(n_edges, dtype=np.int64)
        batch_of = edge_i // batch_size
        # 2E endpoint events: src-side (side 0) then dst-side (side 1)
        ev_node = np.concatenate([src, dst])
        ev_other = np.concatenate([dst, src])
        ev_t = np.concatenate([t, t])
        ev_e = np.concatenate([eidx, eidx])
        ev_batch = np.concatenate([batch_of, batch_of])
        ev_side = np.concatenate([np.zeros(n_edges, np.int64),
                                  np.ones(n_edges, np.int64)])
        ev_edge = np.concatenate([edge_i, edge_i])

        if history is not None:
            assert history.num_nodes == num_nodes and history.k >= 1
            live = history.nbr >= 0                       # (N, Kh)
            h_node, h_slot = np.nonzero(live)
            ev_node = np.concatenate([h_node, ev_node])
            ev_other = np.concatenate([history.nbr[live], ev_other])
            ev_t = np.concatenate([history.time[live], ev_t])
            ev_e = np.concatenate([history.eidx[live], ev_e])
            # history strictly precedes the stream: batch -1, slot order
            nh = len(h_node)
            ev_batch = np.concatenate([np.full(nh, -1, np.int64), ev_batch])
            ev_side = np.concatenate([np.zeros(nh, np.int64), ev_side])
            ev_edge = np.concatenate([h_slot.astype(np.int64), ev_edge])

        # sort by (node, batch, time, side, edge index): per-node contiguous
        # segments in exact ring-buffer application order.
        order = np.lexsort((ev_edge, ev_side, ev_t, ev_batch, ev_node))
        self._nbr = ev_other[order]
        self._t = ev_t[order]
        self._e = ev_e[order]
        node_s = ev_node[order]
        batch_s = ev_batch[order]
        counts = np.bincount(node_s, minlength=num_nodes)
        self._indptr = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(counts)])
        # combined (node, batch) key for vectorized "events before batch b"
        # prefix queries; +1 shifts history's batch -1 to 0.
        self._nb = self.num_batches + 1
        self._bkey = node_s * self._nb + (batch_s + 1)

    @classmethod
    def from_chunks(
        cls,
        chunks: Union[Sequence[Chunk], Callable[[], Iterable[Chunk]]],
        num_nodes: int,
        k: int,
        batch_size: int,
        history: NeighborSnapshot | None = None,
    ) -> "ChronoNeighborIndex":
        """Out-of-core T-CSR build over (src, dst, t, eidx) chunks.

        A two-pass counting sort that produces ARRAYS IDENTICAL to the
        one-shot constructor without ever concatenating the stream: pass 1
        accumulates per-node event counts (-> ``_indptr``), pass 2 lexsorts
        each chunk with the one-shot key and scatters it into per-node
        write cursors.  Chunks are internally re-aligned so no batch
        straddles a boundary; per node the sort key (batch, t, side, edge)
        is then strictly increasing ACROSS chunks (batches don't span
        chunks; the global edge index breaks all remaining ties), so
        chunk-local sorting + in-order placement equals the global sort.

        ``chunks`` is a sequence of (src, dst, t, eidx) tuples or — to
        avoid holding all id columns at once (e.g. ``ShardedStream``
        memory-maps) — a zero-arg callable returning a fresh iterator per
        pass.  A one-shot iterator/generator is materialized into a list
        (both passes must see every chunk).  ``eidx`` is the per-row
        feature index; the *stream position* (batch rank) is tracked
        internally.
        """
        if callable(chunks):
            get_iter = chunks
        else:
            if not isinstance(chunks, (list, tuple)):
                # a generator would be exhausted by pass 1 and leave pass 2
                # scattering nothing into the np.empty arrays
                chunks = list(chunks)
            get_iter = lambda: iter(chunks)  # noqa: E731

        obj = cls.__new__(cls)
        obj.num_nodes = num_nodes
        obj.k = k
        obj.batch_size = batch_size

        # pass 1: per-node event counts (each edge hits both endpoints)
        counts = np.zeros(num_nodes, dtype=np.int64)
        n_edges = 0
        for src, dst, _t, _e in get_iter():
            n_edges += len(src)
            counts += np.bincount(np.asarray(src, np.int64),
                                  minlength=num_nodes)
            counts += np.bincount(np.asarray(dst, np.int64),
                                  minlength=num_nodes)
        obj.num_batches = max(1, -(-n_edges // batch_size)) if n_edges else 0
        obj._nb = obj.num_batches + 1

        nh = 0
        if history is not None:
            assert history.num_nodes == num_nodes and history.k >= 1
            live = history.nbr >= 0
            h_node, h_slot = np.nonzero(live)
            counts += np.bincount(h_node, minlength=num_nodes)
            nh = len(h_node)

        total = 2 * n_edges + nh
        obj._indptr = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(counts)])
        obj._nbr = np.empty(total, np.int64)
        obj._t = np.empty(total, np.float64)
        obj._e = np.empty(total, np.int64)
        obj._bkey = np.empty(total, np.int64)
        cursor = obj._indptr[:-1].copy()

        def place(node_s, other_s, t_s, e_s, batch_s):
            """Scatter (node-sorted) events at each node's write cursor."""
            m = len(node_s)
            if m == 0:
                return
            idx = np.arange(m, dtype=np.int64)
            starts = np.concatenate(
                [[0], np.flatnonzero(np.diff(node_s)) + 1])
            runlen = np.diff(np.concatenate([starts, [m]]))
            off = idx - np.repeat(idx[starts], runlen)
            posn = cursor[node_s] + off
            obj._nbr[posn] = other_s
            obj._t[posn] = t_s
            obj._e[posn] = e_s
            obj._bkey[posn] = node_s * obj._nb + (batch_s + 1)
            np.add(cursor, np.bincount(node_s, minlength=num_nodes),
                   out=cursor)

        # pass 2a: history strictly precedes the stream (batch -1)
        if nh:
            h_t = history.time[live]
            order = np.lexsort((h_slot, h_t, h_node))
            place(h_node[order], history.nbr[live][order], h_t[order],
                  history.eidx[live][order], np.full(nh, -1, np.int64))

        # pass 2b: aligned chunks, each sorted with the one-shot key
        pos = 0
        for src, dst, t, eidx in _aligned_chunks(get_iter(), batch_size):
            m = len(src)
            src = np.asarray(src, np.int64)
            dst = np.asarray(dst, np.int64)
            t = np.asarray(t, np.float64)
            eidx = np.asarray(eidx, np.int64)
            edge_i = np.arange(pos, pos + m, dtype=np.int64)
            batch_of = edge_i // batch_size
            ev_node = np.concatenate([src, dst])
            ev_other = np.concatenate([dst, src])
            ev_t = np.concatenate([t, t])
            ev_e = np.concatenate([eidx, eidx])
            ev_batch = np.concatenate([batch_of, batch_of])
            ev_side = np.concatenate([np.zeros(m, np.int64),
                                      np.ones(m, np.int64)])
            ev_edge = np.concatenate([edge_i, edge_i])
            order = np.lexsort((ev_edge, ev_side, ev_t, ev_batch, ev_node))
            place(ev_node[order], ev_other[order], ev_t[order],
                  ev_e[order], ev_batch[order])
            pos += m
        if not np.array_equal(cursor, obj._indptr[1:]):
            raise ValueError(
                "chunk passes disagree: the chunk source must yield the "
                "same stream on every iteration")
        return obj

    def sample(
        self,
        nodes: np.ndarray,
        batch_of: np.ndarray | int,
        window: np.ndarray | int = 0,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """K most recent neighbors of ``nodes`` as of batch ``batch_of``.

        ``batch_of`` is scalar or per-row: events of stream batches
        >= batch_of are excluded (history always included).  Pass
        ``self.num_batches`` to see the whole stream.  ``window`` (scalar
        or per-row) shifts the K-wide gather back in time: window w
        returns events ``[end-(w+1)K, end-wK)`` — w = 0 is the K most
        recent (the default, and the only window the single-layer model
        uses); the multi-layer fold feeds layer l the window ``L-1-l`` so
        successive layers aggregate strictly older context.  Shapes:
        (len(nodes), K) ids / times / edge indices, oldest -> newest,
        -1 front-padded (times -1.0) — bit-identical to
        ``RecentNeighborBuffer.sample`` after the same updates (at
        window = 0).
        """
        nodes = np.asarray(nodes, dtype=np.int64)
        batch_of = np.broadcast_to(np.asarray(batch_of, np.int64),
                                   nodes.shape)
        window = np.broadcast_to(np.asarray(window, np.int64), nodes.shape)
        start = self._indptr[nodes]
        end = np.searchsorted(self._bkey, nodes * self._nb + (batch_of + 1),
                              side="left")
        idx = (end[:, None] - (window[:, None] + 1) * self.k
               + np.arange(self.k)[None, :])
        valid = idx >= start[:, None]
        idx = np.clip(idx, 0, max(len(self._nbr) - 1, 0))
        if len(self._nbr) == 0:
            shape = (len(nodes), self.k)
            return (np.full(shape, -1, np.int64),
                    np.full(shape, -1.0, np.float64),
                    np.full(shape, -1, np.int64))
        ids = np.where(valid, self._nbr[idx], -1)
        tms = np.where(valid, self._t[idx], -1.0)
        eix = np.where(valid, self._e[idx], -1)
        return ids, tms, eix

    def final_snapshot(self) -> NeighborSnapshot:
        """Index state after the full stream (for val/test continuation)."""
        all_nodes = np.arange(self.num_nodes, dtype=np.int64)
        ids, tms, eix = self.sample(all_nodes, self.num_batches)
        return NeighborSnapshot(nbr=ids, time=tms, eidx=eix)

    def device_export(self, depth: int = 1) -> dict[str, np.ndarray]:
        """T-CSR as device-stageable arrays for the device-side samplers
        (``kernels.ref.sample_ref`` / ``kernels.neighbor_sample``).

        The event arrays are FRONT-PADDED with ``k * depth`` zero entries
        and ``indptr`` is shifted to match, so the samplers' K-wide gather
        window ``[end - (w+1)k, end - wk)`` is always in-bounds with no
        clipping for every window w < depth — degree-0 nodes, K > degree,
        and the empty stream all fall out of the same code path (the
        binary search confines ``end``/``start`` to real segments, which
        never reach into the padding; out-of-segment window slots are
        masked by ``idx >= start``).  ``depth`` = the model's ``n_layers``
        (depth 1 = the single-window export of PR 6, byte-identical
        modulo the pad length).

        ``bat`` stores each event's search key ``batch + 1`` (history = 0)
        — per node it is non-decreasing in segment order, so bisecting for
        ``batch_of + 1`` reproduces ``sample``'s ``searchsorted`` over
        ``_bkey`` bit-for-bit.  Times are cast to float32 here, exactly
        where ``build_batch_program`` casts the host-sampled grid.

        Exports compose: several (e.g. per-PAC-device) exports can be
        concatenated into one flat event buffer by offsetting each
        ``indptr`` with the total length of the preceding exports.
        """
        assert depth >= 1, depth
        pad = self.k * depth
        total = len(self._nbr)

        def padded(arr, dtype):
            out = np.zeros(pad + total, dtype)
            out[pad:] = arr
            return out

        return {
            "indptr": (self._indptr + pad).astype(np.int32),
            "nbr": padded(self._nbr, np.int32),
            "t": padded(self._t, np.float32),
            "eidx": padded(self._e, np.int32),
            "bat": padded(self._bkey % self._nb, np.int32),
        }


class RecentNeighborBuffer:
    """Most-recent-K temporal neighbor index (mutable, host-side).

    The original streaming implementation — an O(E) interpreted per-edge
    loop in ``update``.  No longer on the training path (``build_batches``
    uses ``ChronoNeighborIndex``); retained as the reference oracle the
    vectorized index is property-tested against.

    All arrays use -1 for empty slots.  ``sample`` must be called *before*
    ``update`` for the same batch (neighbors strictly precede the batch).
    """

    def __init__(self, num_nodes: int, k: int):
        self.num_nodes = num_nodes
        self.k = k
        self.nbr = np.full((num_nodes, k), -1, dtype=np.int64)
        self.time = np.full((num_nodes, k), -1.0, dtype=np.float64)
        self.eidx = np.full((num_nodes, k), -1, dtype=np.int64)
        self.ptr = np.zeros(num_nodes, dtype=np.int64)

    def sample(self, nodes: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return the K most recent neighbors of ``nodes``.

        Shapes: (len(nodes), K) each of ids / times / edge indices,
        ordered oldest -> newest, -1-padded.
        """
        nodes = np.asarray(nodes, dtype=np.int64)
        ids = self.nbr[nodes]
        tms = self.time[nodes]
        eix = self.eidx[nodes]
        # roll each row so slots are oldest->newest (ring pointer varies)
        p = self.ptr[nodes] % self.k
        col = (np.arange(self.k)[None, :] + p[:, None]) % self.k
        rows = np.arange(len(nodes))[:, None]
        return ids[rows, col], tms[rows, col], eix[rows, col]

    def update(self, src: np.ndarray, dst: np.ndarray,
               t: np.ndarray, eidx: np.ndarray) -> None:
        """Push each interaction into both endpoints' ring buffers, in order
        (duplicates within the batch are applied sequentially, preserving
        exact chronology even when a node interacts repeatedly)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        eidx = np.asarray(eidx, np.int64)
        nodes = np.concatenate([src, dst])
        others = np.concatenate([dst, src])
        times = np.concatenate([t, t])
        eix = np.concatenate([eidx, eidx])
        order = np.argsort(times, kind="stable")
        for n, o, tt, ee in zip(nodes[order], others[order],
                                times[order], eix[order]):
            slot = self.ptr[n] % self.k
            self.nbr[n, slot] = o
            self.time[n, slot] = tt
            self.eidx[n, slot] = ee
            self.ptr[n] += 1

    def snapshot(self) -> NeighborSnapshot:
        """Current state in the oldest->newest front-padded layout."""
        ids, tms, eix = self.sample(np.arange(self.num_nodes))
        return NeighborSnapshot(nbr=ids, time=tms, eidx=eix)

    def copy(self) -> "RecentNeighborBuffer":
        out = RecentNeighborBuffer(self.num_nodes, self.k)
        out.nbr = self.nbr.copy()
        out.time = self.time.copy()
        out.eidx = self.eidx.copy()
        out.ptr = self.ptr.copy()
        return out
