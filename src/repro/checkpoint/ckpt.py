"""Pytree checkpointing (npz-based; no orbax offline).

Flattens any pytree of arrays into a single ``.npz`` with path-encoded keys,
plus a tiny JSON manifest (step, metadata).  Sharded arrays are gathered to
host before saving (fine at the scales this container trains); restore
re-places values onto the target shardings when given.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "|"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(directory: str, step: int, tree, *,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    np.savez_compressed(path, **flat)
    manifest = {"step": step, "num_arrays": len(flat),
                "metadata": metadata or {}}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1))
             for fn in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (shape/dtype checked).

    ``shardings``: optional matching pytree of jax.sharding.Sharding to
    device_put the restored leaves onto."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    for (path_elems, leaf), shard in zip(paths, shard_leaves):
        key = _SEP.join(_path_str(p) for p in path_elems)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {np.shape(leaf)}")
        if shard is not None:
            arr = jax.device_put(arr, shard)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
