"""Pytree checkpointing (npz-based; no orbax offline).

Flattens any pytree of arrays into a single ``.npz`` with path-encoded keys,
plus a tiny JSON manifest (step, metadata).  Sharded arrays are gathered to
host before saving (fine at the scales this container trains); restore
re-places values onto the target shardings when given.

Writes are crash-atomic: both files land via write-to-``*.tmp`` + fsync +
``os.replace``, and the manifest is written LAST so its presence marks a
complete step.  ``latest_step`` only reports steps whose npz+manifest pair
exists and loads — a process killed mid-save (the elastic PAC recovery
path) leaves at worst a ``*.tmp`` orphan and a skipped step, never a
restore that explodes later.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "|"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _names(directory: str, step: int) -> tuple[str, str]:
    return (os.path.join(directory, f"ckpt_{step:08d}.npz"),
            os.path.join(directory, f"ckpt_{step:08d}.json"))


def _atomic_write(path: str, write_fn: Callable[[Any], None]) -> None:
    """Write via a same-directory temp file, fsync, then rename into place
    — a reader (or a resume after SIGKILL) sees either the old complete
    file or the new complete file, never a torn write."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save_checkpoint(directory: str, step: int, tree, *,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path, manifest_path = _names(directory, step)
    flat = _flatten(tree)
    _atomic_write(path, lambda f: np.savez_compressed(f, **flat))
    manifest = {"step": step, "num_arrays": len(flat),
                "metadata": metadata or {}}
    # manifest last: its presence marks the step complete (latest_step
    # requires the pair, so a kill between the two renames hides the step)
    _atomic_write(manifest_path,
                  lambda f: f.write(json.dumps(manifest).encode()))
    return path


def _step_ok(directory: str, step: int) -> bool:
    """A step counts only when its npz + manifest pair is present and both
    parse — partial/corrupt leftovers of a killed writer are skipped."""
    path, manifest_path = _names(directory, step)
    if not (os.path.isfile(path) and os.path.isfile(manifest_path)):
        return False
    try:
        with open(manifest_path) as f:
            json.load(f)
        # np.load reads the zip central directory (at EOF), so a truncated
        # npz fails here instead of during restore
        with np.load(path) as data:
            data.files  # noqa: B018 — force the directory read
    except Exception:
        return False
    return True


def latest_step(directory: str) -> Optional[int]:
    """Newest COMPLETE step in ``directory`` (corrupt/partial steps — a
    lone npz, a torn zip, an unparsable manifest — are skipped, not
    raised)."""
    if not os.path.isdir(directory):
        return None
    steps = sorted({int(m.group(1))
                    for fn in os.listdir(directory)
                    if (m := re.match(r"ckpt_(\d+)\.(npz|json)$", fn))},
                   reverse=True)
    for step in steps:
        if _step_ok(directory, step):
            return step
    return None


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (shape/dtype checked).

    Raises ``FileNotFoundError`` when the step does not exist and
    ``ValueError`` — naming every offending key and what the checkpoint
    actually holds — when the checkpoint's tree structure does not cover
    the target (extra keys in the checkpoint are allowed: subset restore
    is how best-val ``{params, state}`` is pulled out of a periodic
    ``{params, opt_state, state}`` save).

    ``shardings``: optional matching pytree of jax.sharding.Sharding to
    device_put the restored leaves onto."""
    path, _ = _names(directory, step)
    if not os.path.isfile(path):
        raise FileNotFoundError(f"no checkpoint for step {step} in "
                                f"{directory!r}")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = [_SEP.join(_path_str(p) for p in path_elems)
            for path_elems, _leaf in paths]
    missing = [k for k in keys if k not in data]
    if missing:
        raise ValueError(
            f"checkpoint {path!r} does not match the target tree "
            f"structure: missing {len(missing)}/{len(keys)} keys "
            f"{missing[:5]}{'...' if len(missing) > 5 else ''}; "
            f"checkpoint holds {sorted(data.files)[:8]}"
            f"{'...' if len(data.files) > 8 else ''}")
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    for key, (_path_elems, leaf), shard in zip(keys, paths, shard_leaves):
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {np.shape(leaf)}")
        if shard is not None:
            arr = jax.device_put(arr, shard)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
