"""Minimal functional optimizer library (no optax in this environment)."""

from repro.optim.adamw import (
    Optimizer,
    adam,
    adamw,
    clip_by_global_norm,
    sgd,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_decay_schedule,
    linear_warmup_cosine,
)

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "sgd",
    "clip_by_global_norm",
    "constant_schedule",
    "cosine_decay_schedule",
    "linear_warmup_cosine",
]
