"""Learning-rate schedules (callables of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant_schedule", "cosine_decay_schedule",
           "linear_warmup_cosine"]


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay_schedule(lr: float, decay_steps: int, alpha: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * ((1 - alpha) * cos + alpha)
    return fn


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup_steps, 1)
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5
                    * (1.0 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
