"""Functional AdamW / Adam / SGD over arbitrary pytrees.

API mirrors optax: ``opt.init(params) -> opt_state``;
``opt.update(grads, opt_state, params) -> (updates, opt_state)``;
apply with ``jax.tree.map(lambda p, u: p + u, params, updates)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adam", "sgd", "clip_by_global_norm"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable

    def apply(self, grads, opt_state, params):
        """Convenience: one-call update returning (new_params, new_state)."""
        updates, new_state = self.update(grads, opt_state, params)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        return new_params, new_state


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw(
    lr: Schedule = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = None,
) -> Optimizer:
    """AdamW with optional global-norm clipping (decoupled weight decay)."""

    def init(params):
        zeros = lambda p: jnp.zeros_like(p)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state["nu"], grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p
            return u

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init=init, update=update)


def adam(lr: Schedule = 1e-3, **kw) -> Optimizer:
    return adamw(lr=lr, weight_decay=0.0, **kw)


def sgd(lr: Schedule = 1e-2, momentum: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mom": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g,
                               state["mom"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mom)
        else:
            mom = state["mom"]
            updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, {"step": step, "mom": mom}

    return Optimizer(init=init, update=update)
