"""Deterministic fault injection for the elastic PAC subsystem.

Training code calls ``FaultInjector.fire(site, **ctx)`` at named injection
points; which (if any) of those calls actually fail is decided by a spec
string — usually the ``REPRO_FAULTS`` environment variable, so the
2-process CPU-cluster test can kill process 1 mid-epoch without patching
any code path.  Everything is deterministic: a spec either pins an exact
epoch / call index, or draws from a seeded per-spec RNG keyed on the call
count, so two runs of the same spec fail at the same point.

Spec grammar (``;``-separated specs, ``,``-separated ``key=value`` args)::

    host_kill@epoch=1                 # SIGKILL self at the epoch-1 site
    staging_oom@at=2                  # MemoryError on the 2nd staging call
    prefetch_worker@epoch=0;sync_fail@epoch=1
    sync_fail@prob=0.5,seed=7         # seeded Bernoulli per call
    host_kill@epoch=1,rank=1          # only fire in process 1

Known sites (the trainers fire these; unknown sites are legal — a spec
simply never matches until some code fires it):

  * ``host_kill``       — top of each PAC epoch (action ``kill``: SIGKILL)
  * ``staging_oom``     — device staging / ``to_device`` (action ``oom``)
  * ``prefetch_worker`` — inside the prefetcher's build callback
  * ``sync_fail``       — before dispatching the Alg.2 sync program

This module also owns the *classification* side of fault tolerance:
``HostLossError`` is what ``pac_train`` raises when a failure looks like a
lost peer (gloo / coordination-service / socket errors), and
``is_host_loss`` is the textual classifier that maps raw collective
exceptions onto it.
"""

from __future__ import annotations

import dataclasses
import os
import signal
from typing import Optional

__all__ = [
    "InjectedFault",
    "HostLossError",
    "is_host_loss",
    "FaultSpec",
    "parse_faults",
    "FaultInjector",
    "FAULTS_ENV",
]

FAULTS_ENV = "REPRO_FAULTS"

# default action per site; any spec can override with action=...
_SITE_ACTIONS = {
    "host_kill": "kill",
    "staging_oom": "oom",
}
_ACTIONS = ("raise", "oom", "kill")


class InjectedFault(RuntimeError):
    """A deterministic injected failure (``action="raise"`` sites)."""

    def __init__(self, site: str, ctx: dict):
        super().__init__(f"injected fault at {site!r} ({ctx})")
        self.site = site
        self.ctx = ctx


class HostLossError(RuntimeError):
    """A peer process is gone (or unreachable): the multi-host run cannot
    continue with the current world and must be re-formed over the
    survivors (``launch.pac_cluster`` exits ``EXIT_PEER_LOST`` on this)."""


# substrings (lowercased) that mark a collective/distributed-plane failure
# rather than a local bug: gloo transport errors, the coordination
# service's liveness machinery, and socket-level breakage
_DIST_MARKERS = (
    "gloo",
    "connection reset",
    "connection closed",
    "connection refused",
    "broken pipe",
    "socket",
    "unavailable",
    "deadline exceeded",
    "heartbeat",
    "coordination service",
    "peer",
    "distributed runtime",
    "barrier",
    "timed out",
)


def is_host_loss(exc: BaseException) -> bool:
    """True when ``exc`` (or its cause chain) looks like a lost/unreachable
    peer rather than a local error.  Purely textual — the jax/gloo stack
    surfaces these as generic ``XlaRuntimeError``/``RuntimeError`` strings,
    so substring matching is the only portable classifier."""
    seen = set()
    while exc is not None and id(exc) not in seen:
        seen.add(id(exc))
        if isinstance(exc, HostLossError):
            return True
        text = f"{type(exc).__name__}: {exc}".lower()
        if any(m in text for m in _DIST_MARKERS):
            return True
        exc = exc.__cause__ or exc.__context__
    return False


@dataclasses.dataclass
class FaultSpec:
    """One armed failure: fires at most once, at a deterministic point."""

    site: str
    epoch: Optional[int] = None   # fire only when ctx["epoch"] == epoch
    at: Optional[int] = None      # fire only on the Nth call (1-based)
    rank: Optional[int] = None    # fire only in this (original) process
    prob: float = 1.0             # seeded Bernoulli per matching call
    seed: int = 0
    action: str = ""              # "" -> site default ("raise" otherwise)
    fired: bool = False

    def resolved_action(self) -> str:
        act = self.action or _SITE_ACTIONS.get(self.site, "raise")
        if act not in _ACTIONS:
            raise ValueError(f"unknown fault action {act!r} (expected one "
                             f"of {_ACTIONS})")
        return act


def parse_faults(text: str) -> list[FaultSpec]:
    """Parse the ``site@k=v,k=v;site2@...`` grammar into specs."""
    specs = []
    for chunk in (text or "").split(";"):
        chunk = chunk.strip()
        if not chunk:
            continue
        site, _, argstr = chunk.partition("@")
        kw: dict = {}
        for pair in filter(None, (p.strip() for p in argstr.split(","))):
            key, _, val = pair.partition("=")
            if key in ("epoch", "at", "rank", "seed"):
                kw[key] = int(val)
            elif key == "prob":
                kw[key] = float(val)
            elif key == "action":
                kw[key] = val
            else:
                raise ValueError(f"unknown fault spec arg {key!r} in "
                                 f"{chunk!r}")
        spec = FaultSpec(site=site.strip(), **kw)
        spec.resolved_action()      # validate eagerly
        specs.append(spec)
    return specs


class FaultInjector:
    """Holds armed ``FaultSpec``s and fires them at matching call sites.

    An injector with no specs is inert (``fire`` is a cheap no-op), so
    trainers can call ``FaultInjector.from_env()`` unconditionally.
    ``process_index`` scopes rank-filtered specs; when ``None`` it is
    resolved lazily from ``REPRO_PAC_ORIG_RANK`` (set by the elastic
    launcher, which re-ranks survivors) and finally ``jax.process_index``.
    """

    def __init__(self, specs=(), process_index: Optional[int] = None):
        self.specs = list(specs)
        self._rank = process_index
        self._counts: dict[str, int] = {}

    @classmethod
    def parse(cls, text: str, process_index: Optional[int] = None
              ) -> "FaultInjector":
        return cls(parse_faults(text), process_index=process_index)

    @classmethod
    def from_env(cls, env_var: str = FAULTS_ENV) -> "FaultInjector":
        return cls.parse(os.environ.get(env_var, ""))

    @property
    def armed(self) -> bool:
        return any(not s.fired for s in self.specs)

    def _process_index(self) -> int:
        if self._rank is None:
            env = os.environ.get("REPRO_PAC_ORIG_RANK")
            if env is not None:
                self._rank = int(env)
            else:
                try:
                    import jax
                    self._rank = jax.process_index()
                except Exception:
                    self._rank = 0
        return self._rank

    def _draw(self, spec: FaultSpec, count: int) -> bool:
        if spec.prob >= 1.0:
            return True
        import numpy as np
        rng = np.random.default_rng(
            np.random.SeedSequence([spec.seed, hash(spec.site) & 0x7FFFFFFF,
                                    count]))
        return bool(rng.random() < spec.prob)

    def fire(self, site: str, **ctx) -> None:
        """Raise/kill if an armed spec matches this call; no-op otherwise."""
        if not self.specs:
            return
        count = self._counts.get(site, 0) + 1
        self._counts[site] = count
        for spec in self.specs:
            if spec.fired or spec.site != site:
                continue
            if spec.epoch is not None and ctx.get("epoch") != spec.epoch:
                continue
            if spec.at is not None and count != spec.at:
                continue
            if spec.rank is not None and self._process_index() != spec.rank:
                continue
            if not self._draw(spec, count):
                continue
            spec.fired = True
            self._trip(spec, site, dict(ctx, call=count))

    def _trip(self, spec: FaultSpec, site: str, ctx: dict) -> None:
        action = spec.resolved_action()
        if action == "kill":
            # simulated host loss: die like a preempted/OOM-killed host —
            # no exception propagation, no cleanup, no exit handlers
            print(f"FAULT_INJECTED: {site} {ctx} -> SIGKILL", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
        if action == "oom":
            raise MemoryError(f"injected staging OOM at {site!r} ({ctx})")
        raise InjectedFault(site, ctx)
