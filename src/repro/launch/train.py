"""Unified training launcher.

Two pillars behind one CLI:
  * ``--arch speed-tig``  — the paper's pipeline: synthetic TIG -> SEP
    partitioning -> PAC multi-device training -> downstream eval.
  * ``--arch <llm-arch>`` — LM pretraining on the synthetic corpus with the
    pjit sharding rules (reduced configs on CPU; full configs are for the
    dry-run / real pods).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch speed-tig \
      --dataset small --devices 4 --parts 8 --topk 0.05 --epochs 3
  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --reduced \
      --steps 200 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

__all__ = ["main"]


def train_tig(args) -> None:
    import jax

    from repro.core import partition_stats, sep_partition
    from repro.configs.speed_tig import TIG
    from repro.tig.data import synthetic_tig
    from repro.tig.distributed import pac_train
    from repro.tig.graph import chronological_split
    from repro.tig.models import TIGConfig
    from repro.tig.train import evaluate_params

    g = synthetic_tig(args.dataset, seed=args.seed)
    print(f"dataset: {g.stats()}")
    train_g, _, _, _ = chronological_split(g)

    t0 = time.perf_counter()
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, args.parts, k=args.topk)
    print(f"SEP: {partition_stats(part)}")

    cfg = dataclasses.replace(
        TIG, dim=args.dim, dim_edge=g.dim_edge, dim_node=g.dim_node,
        dim_time=min(args.dim, 64), batch_size=args.batch,
        flavor=args.flavor)
    mesh = None
    if args.shard_map:
        from repro.launch.mesh import make_tig_mesh
        mesh = make_tig_mesh(args.devices)
    res = pac_train(train_g, part, cfg, num_devices=args.devices,
                    epochs=args.epochs, lr=args.lr, mesh=mesh,
                    grid_layout=args.grid_layout or None)
    print(f"PAC: derived speedup {res.derived_speedup:.2f}x, "
          f"edges/device {res.edges_per_device.tolist()}, "
          f"losses {res.mean_loss_per_epoch().round(4).tolist()}")
    ev = evaluate_params(g, cfg, res.params, eval_node_class=True)
    print(f"eval: {ev}")
    print(f"total {time.perf_counter() - t0:.1f}s")


def train_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data import LMDataConfig, packed_batches
    from repro.checkpoint import save_checkpoint
    from repro.models import init_params, make_train_step
    from repro.optim import adamw, linear_warmup_cosine

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.seq or args.batch:
        pass  # shapes live in the data config; model is shape-polymorphic
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq or 128,
                        global_batch=args.batch or 8, seed=args.seed)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{n_params/1e6:.2f}M params, seq={dcfg.seq_len}, "
          f"batch={dcfg.global_batch}")

    opt = adamw(lr=linear_warmup_cosine(args.lr, 20, args.steps),
                weight_decay=0.1, max_grad_norm=1.0)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    data = packed_batches(dcfg)
    t0 = time.perf_counter()
    tokens_seen = 0
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        tokens_seen += dcfg.global_batch * dcfg.seq_len
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"tok/s {tokens_seen/dt:,.0f}")
        if args.ckpt_dir and step and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step, params)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params)
        print(f"saved final checkpoint to {args.ckpt_dir}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=None)
    # TIG options
    ap.add_argument("--dataset", default="small")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--topk", type=float, default=0.05)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--flavor", default="tgn",
                    choices=["jodie", "dyrep", "tgn", "tige"])
    ap.add_argument("--shard-map", action="store_true",
                    help="use real devices (set XLA_FLAGS for >1 on CPU)")
    ap.add_argument("--grid-layout", default="",
                    choices=["", "replicated", "sharded"],
                    help="PAC batch-grid layout; empty picks the default "
                         "(sharded on a mesh, replicated on vmap). Multi-"
                         "host pods should launch repro.launch.pac_cluster")
    # LM options
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)
    if args.arch == "speed-tig":
        args.lr = args.lr or 1e-3
        args.batch = args.batch or 100
        train_tig(args)
    else:
        args.lr = args.lr or 3e-3
        train_lm(args)


if __name__ == "__main__":
    main()
