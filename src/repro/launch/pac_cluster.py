"""Multi-process PAC launcher — one process per host, devices pooled into
one process-spanning "part" axis.

This is both the reference for launching SPEED's PAC on a pod (one
invocation per host, a coordinator address they all agree on) and the
driver the 2-process CPU-cluster parity test spawns in CI.  Every process
runs the SAME program (standard SPMD): plans only its local devices' rows
(``pac_train`` detects the multi-process mesh), stages them with
``make_array_from_process_local_data``, and the Alg.2 shared-node memory
sync crosses hosts through the mesh collectives.

    # host 0                                       # host 1
    python -m repro.launch.pac_cluster \\
        --num-processes 2 --process-id 0 \\          ... --process-id 1 \\
        --coordinator 10.0.0.1:12321

On CPU the cluster uses the gloo collectives backend and
``--local-devices`` forces that many host devices per process, which is
how CI simulates two hosts on one machine.  ``--out`` dumps losses,
params, merged memories and protocol metrics to an ``.npz`` so runs can
be compared bit-for-bit across process counts.
"""

from __future__ import annotations

import argparse
import os
import sys


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="pac_cluster",
        description="multi-process PAC training driver (one per host)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--coordinator", default="127.0.0.1:12321",
                    help="host:port every process can reach (process 0 "
                         "binds it)")
    ap.add_argument("--local-devices", type=int, default=2,
                    help="force this many CPU devices per process "
                         "(0 = leave XLA_FLAGS alone, e.g. real TPUs)")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--parts", type=int, default=8,
                    help="SEP partitions; > total devices exercises the "
                         "shuffle-combine resync every epoch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grid-layout", default="sharded",
                    choices=["sharded", "replicated"])
    ap.add_argument("--sync-mode", default="latest",
                    choices=["latest", "mean"])
    ap.add_argument("--epoch-boundary", default="overlap",
                    choices=["overlap", "serial"],
                    help="'overlap' pipelines the Alg.2 memory sync and "
                         "loss reads behind the next epoch; 'serial' is "
                         "the fused bit-parity oracle")
    ap.add_argument("--out", default="",
                    help="write losses/params/memory/metrics to this .npz")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv)
    if args.local_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.local_devices}")

    import jax

    if args.num_processes > 1:
        try:
            # CPU collectives span processes through gloo; TPU pods skip
            # both lines (the default backend already crosses hosts)
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
            jax.distributed.initialize(
                coordinator_address=args.coordinator,
                num_processes=args.num_processes,
                process_id=args.process_id)
        except Exception as e:
            # the parity test reads this marker to skip gracefully on
            # platforms that cannot form the cluster (no gloo, sandboxed
            # sockets, ...) instead of failing the suite
            print(f"CLUSTER_UNAVAILABLE: {type(e).__name__}: {e}",
                  flush=True)
            return 17

    import numpy as np

    from repro.core import sep_partition
    from repro.launch.mesh import make_tig_mesh
    from repro.tig.data import synthetic_tig
    from repro.tig.distributed import pac_train
    from repro.tig.graph import chronological_split
    from repro.tig.models import TIGConfig

    g = synthetic_tig("tiny", seed=args.seed)
    train_g, _, _, _ = chronological_split(g)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         args.parts, k=0.05)
    mesh = make_tig_mesh()
    n_dev = int(mesh.devices.size)

    res = pac_train(
        train_g, part, cfg, num_devices=n_dev, epochs=args.epochs,
        seed=args.seed, shuffle_parts=True, sync_mode=args.sync_mode,
        mesh=mesh, plan="device", grid_layout=args.grid_layout,
        epoch_boundary=args.epoch_boundary, eval_graph=g)

    if args.out:
        payload = {}
        for e, losses in enumerate(res.losses):
            payload[f"loss_{e}"] = np.asarray(losses)
        # tree_leaves order is deterministic for a fixed param structure
        for i, leaf in enumerate(jax.tree_util.tree_leaves(res.params)):
            payload[f"param_{i}"] = np.asarray(leaf)
        for key in ("mem", "mem2", "last"):
            payload[f"state_{key}"] = np.asarray(res.memory_states[key])
        for key, val in sorted((res.metrics or {}).items()):
            payload[f"metric_{key}"] = np.asarray(val)
        np.savez(args.out, **payload)

    print(f"pac_cluster done: process {jax.process_index()}"
          f"/{jax.process_count()}, devices={n_dev}, "
          f"grid_layout={args.grid_layout}", flush=True)
    if args.num_processes > 1:
        # explicit teardown: the atexit shutdown can race the coordinator
        # when processes finish at different times (SIGABRT on slow hosts)
        jax.distributed.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
