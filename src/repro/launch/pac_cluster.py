"""Multi-process PAC launcher — one process per host, devices pooled into
one process-spanning "part" axis — with an elastic supervisor mode that
survives host loss.

This is both the reference for launching SPEED's PAC on a pod (one
invocation per host, a coordinator address they all agree on) and the
driver the 2-process CPU-cluster parity test spawns in CI.  Every process
runs the SAME program (standard SPMD): plans only its local devices' rows
(``pac_train`` detects the multi-process mesh), stages them with
``make_array_from_process_local_data``, and the Alg.2 shared-node memory
sync crosses hosts through the mesh collectives.

    # host 0                                       # host 1
    python -m repro.launch.pac_cluster \\
        --num-processes 2 --process-id 0 \\          ... --process-id 1 \\
        --coordinator 10.0.0.1:12321

On CPU the cluster uses the gloo collectives backend and
``--local-devices`` forces that many host devices per process, which is
how CI simulates two hosts on one machine.  ``--out`` dumps losses,
params, merged memories and protocol metrics to an ``.npz`` so runs can
be compared bit-for-bit across process counts.

Elastic mode (``--elastic --run-dir DIR``) splits each invocation into a
SUPERVISOR and a re-execed WORKER subprocess (gloo cannot re-join a
smaller world in-process, so recovery requires a fresh process):

  * the worker heartbeats ``DIR/hb_<rank>`` and a watchdog kills it with
    ``EXIT_PEER_LOST`` when a peer's heartbeat goes stale (a hung
    collective never times out on its own);
  * ``jax.distributed.initialize`` runs under bounded retries with
    exponential backoff + jitter (``--cluster-retries``/``--backoff``),
    logging every attempt — exhaustion exits ``EXIT_UNAVAILABLE``;
  * a worker killed by SIGKILL is treated as a PERMANENTLY lost host
    (simulated preemption): its supervisor marks ``DIR/lost_<rank>`` and
    exits 0;
  * surviving supervisors wait one heartbeat window (refreshing their own
    heartbeat), re-read the survivor set, and relaunch workers over a
    re-ranked world on a fresh coordinator port (``base_port + attempt``)
    with ``--resume``: params/opt state come back from the newest atomic
    checkpoint in ``DIR/ckpt`` and training continues from the next
    epoch — no replay of finished epochs.  ``--max-restarts`` bounds the
    cycles; exhaustion exits ``EXIT_RETRIES_EXHAUSTED``.

Deterministic faults for testing all of this are injected via the
``REPRO_FAULTS`` environment variable (see ``repro.faults``), e.g.
``REPRO_FAULTS=host_kill@epoch=1,rank=1`` SIGKILLs original rank 1 at the
top of epoch 1 — the surviving rank re-forms a 1-process world and
finishes the run.
"""

from __future__ import annotations

import argparse
import os
import random
import signal
import subprocess
import sys
import threading
import time

EXIT_UNAVAILABLE = 17        # the cluster cannot form at all (skip in CI)
EXIT_RETRIES_EXHAUSTED = 18  # elastic restart budget spent
EXIT_PEER_LOST = 23          # a peer died mid-run; supervisor may re-form

_WORKER_ENV = "REPRO_PAC_WORKER"
_RANK_ENV = "REPRO_PAC_ORIG_RANK"


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="pac_cluster",
        description="multi-process PAC training driver (one per host)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--coordinator", default="127.0.0.1:12321",
                    help="host:port every process can reach (process 0 "
                         "binds it)")
    ap.add_argument("--local-devices", type=int, default=2,
                    help="force this many CPU devices per process "
                         "(0 = leave XLA_FLAGS alone, e.g. real TPUs)")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--parts", type=int, default=8,
                    help="SEP partitions; > total devices exercises the "
                         "shuffle-combine resync every epoch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grid-layout", default="sharded",
                    choices=["sharded", "replicated"])
    ap.add_argument("--sync-mode", default="latest",
                    choices=["latest", "mean"])
    ap.add_argument("--epoch-boundary", default="overlap",
                    choices=["overlap", "serial"],
                    help="'overlap' pipelines the Alg.2 memory sync and "
                         "loss reads behind the next epoch; 'serial' is "
                         "the fused bit-parity oracle")
    ap.add_argument("--eval-warm", default="memory",
                    choices=["memory", "replay", "restart"],
                    help="where the eval protocol's warm memory comes "
                         "from: PAC's synced memories, a train-split "
                         "replay, or the TIGER-style restarter head")
    ap.add_argument("--out", default="",
                    help="write losses/params/memory/metrics to this .npz")
    # --- fault tolerance ---------------------------------------------
    ap.add_argument("--elastic", action="store_true",
                    help="supervise a re-execed worker: on host loss, "
                         "re-form the world over the survivors and resume "
                         "from the latest checkpoint")
    ap.add_argument("--run-dir", default="",
                    help="shared scratch dir for heartbeats, loss markers "
                         "and checkpoints (required with --elastic)")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint {params, opt_state, states} every "
                         "this many epochs (0 = off; needs --run-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restore from the newest checkpoint in "
                         "run-dir/ckpt before training")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="elastic re-formation cycles before giving up")
    ap.add_argument("--cluster-retries", type=int, default=3,
                    help="jax.distributed.initialize attempts per worker")
    ap.add_argument("--backoff", type=float, default=0.5,
                    help="base of the exponential retry backoff, seconds")
    ap.add_argument("--heartbeat-interval", type=float, default=0.25)
    ap.add_argument("--heartbeat-timeout", type=float, default=10.0,
                    help="a peer whose heartbeat is older than this is "
                         "declared lost")
    # internal (set by the supervisor on re-exec)
    ap.add_argument("--orig-rank", type=int, default=-1,
                    help=argparse.SUPPRESS)
    ap.add_argument("--peers", default="", help=argparse.SUPPRESS)
    return ap.parse_args(argv)


# --- run-dir markers ---------------------------------------------------

def _hb(run_dir, rank):
    return os.path.join(run_dir, f"hb_{rank}")


def _done(run_dir, rank):
    return os.path.join(run_dir, f"done_{rank}")


def _lost(run_dir, rank):
    return os.path.join(run_dir, f"lost_{rank}")


def _touch(path):
    with open(path, "w") as f:
        f.write(f"{time.time()}\n")


def _age(path) -> float:
    try:
        return time.time() - os.path.getmtime(path)
    except OSError:
        return float("inf")


# --- supervisor --------------------------------------------------------

def _supervise(args) -> int:
    """Run (and re-run) the worker subprocess for ONE original rank.

    Every host runs one supervisor; they coordinate purely through the
    shared ``--run-dir`` (heartbeat freshness + ``lost_<rank>`` markers)
    and the deterministic port schedule ``base_port + attempt`` — no
    control plane of its own, so the supervisor survives anything short
    of the host itself dying (which IS the case it exists to report)."""
    if not args.run_dir:
        print("ELASTIC: --elastic requires --run-dir", flush=True)
        return 2
    os.makedirs(args.run_dir, exist_ok=True)
    host, _, port_s = args.coordinator.rpartition(":")
    base_port = int(port_s)
    rank = args.process_id
    world = list(range(args.num_processes))
    # keep the TOTAL device count (and with it every epoch plan) fixed as
    # the world shrinks: survivors pick up the lost host's device slots,
    # so a recovered run is numerically the same schedule as an
    # undisturbed one (0 = real accelerators, nothing to scale)
    total_devices = args.num_processes * args.local_devices
    _touch(_hb(args.run_dir, rank))

    for attempt in range(args.max_restarts + 1):
        slot = world.index(rank)
        local = total_devices // len(world) if args.local_devices else 0
        cmd = [
            sys.executable, "-m", "repro.launch.pac_cluster",
            "--num-processes", str(len(world)),
            "--process-id", str(slot),
            "--coordinator", f"{host}:{base_port + attempt}",
            "--local-devices", str(local),
            "--epochs", str(args.epochs),
            "--parts", str(args.parts),
            "--seed", str(args.seed),
            "--grid-layout", args.grid_layout,
            "--sync-mode", args.sync_mode,
            "--epoch-boundary", args.epoch_boundary,
            "--eval-warm", args.eval_warm,
            "--run-dir", args.run_dir,
            "--ckpt-every", str(args.ckpt_every),
            "--cluster-retries", str(args.cluster_retries),
            "--backoff", str(args.backoff),
            "--heartbeat-interval", str(args.heartbeat_interval),
            "--heartbeat-timeout", str(args.heartbeat_timeout),
            "--orig-rank", str(rank),
            "--peers", ",".join(map(str, world)),
        ]
        if args.out:
            cmd += ["--out", args.out]
        if args.resume or attempt > 0:
            cmd.append("--resume")
        env = dict(os.environ)
        env[_WORKER_ENV] = "1"
        env[_RANK_ENV] = str(rank)
        print(f"ELASTIC: attempt {attempt}/{args.max_restarts}: rank "
              f"{rank} -> slot {slot} of world {world} on port "
              f"{base_port + attempt}", flush=True)
        rc = subprocess.Popen(cmd, env=env).wait()

        if rc == 0:
            return 0
        if rc == EXIT_UNAVAILABLE:
            print("ELASTIC: worker reported the cluster unavailable",
                  flush=True)
            return EXIT_UNAVAILABLE
        if rc == -signal.SIGKILL:
            # simulated preemption / OOM-kill: THIS host is the lost one.
            # Mark it permanently dead and bow out cleanly — the
            # survivors re-form without us.
            _touch(_lost(args.run_dir, rank))
            try:
                os.remove(_hb(args.run_dir, rank))
            except OSError:
                pass
            print(f"ELASTIC: rank {rank} HOST_LOST (worker SIGKILLed)",
                  flush=True)
            return 0
        if rc > 0 and rc != EXIT_PEER_LOST:
            return rc  # a real worker bug: don't mask it with retries

        # EXIT_PEER_LOST (or a startup-skew signal): wait one full
        # heartbeat window — refreshing OUR heartbeat so the other
        # survivors keep counting us — then re-read the survivor set.
        delay = max(args.heartbeat_timeout + 2 * args.heartbeat_interval,
                    args.backoff * (2 ** attempt))
        delay += random.uniform(0, args.heartbeat_interval)
        print(f"ELASTIC: rank {rank} worker exited rc={rc}; re-forming "
              f"in {delay:.1f}s", flush=True)
        deadline = time.time() + delay
        while time.time() < deadline:
            _touch(_hb(args.run_dir, rank))
            time.sleep(min(args.heartbeat_interval,
                           max(0.0, deadline - time.time())))
        world = [r for r in world
                 if r == rank or (
                     not os.path.exists(_lost(args.run_dir, r))
                     and _age(_hb(args.run_dir, r)) <
                     args.heartbeat_timeout)]
        print(f"ELASTIC: survivors = {world}", flush=True)

    print(f"ELASTIC: rank {rank} RETRIES_EXHAUSTED after "
          f"{args.max_restarts + 1} attempts", flush=True)
    return EXIT_RETRIES_EXHAUSTED


# --- worker ------------------------------------------------------------

def _start_heartbeat(run_dir: str, rank: int, interval: float) -> None:
    _touch(_hb(run_dir, rank))

    def beat():
        while True:
            time.sleep(interval)
            try:
                _touch(_hb(run_dir, rank))
            except OSError:
                return

    threading.Thread(target=beat, daemon=True).start()


def _start_watchdog(run_dir: str, rank: int, peers: list[int],
                    interval: float, timeout: float) -> None:
    """Kill THIS worker (``EXIT_PEER_LOST``) when a peer stops
    heartbeating without a ``done`` marker: a SIGKILLed peer leaves the
    survivors hung inside a gloo collective that may never error out, so
    liveness has to come from outside the collective stack."""
    started = time.time()

    def watch():
        while True:
            time.sleep(interval)
            for p in peers:
                if p == rank or os.path.exists(_done(run_dir, p)) \
                        or os.path.exists(_lost(run_dir, p)):
                    continue
                age = _age(_hb(run_dir, p))
                if age > timeout and time.time() - started > timeout:
                    print(f"PEER_LOST: rank {p} heartbeat stale "
                          f"({age:.1f}s) — aborting rank {rank}",
                          flush=True)
                    os._exit(EXIT_PEER_LOST)

    threading.Thread(target=watch, daemon=True).start()


def _init_with_retry(args) -> bool:
    """``jax.distributed.initialize`` under bounded retries with
    exponential backoff + jitter; every attempt is logged.  Returns False
    (after printing the ``CLUSTER_UNAVAILABLE`` marker CI keys off) when
    the retry budget is spent."""
    import jax

    # CPU collectives span processes through gloo; TPU pods skip this
    # (the default backend already crosses hosts)
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    last = None
    for i in range(max(1, args.cluster_retries)):
        try:
            jax.distributed.initialize(
                coordinator_address=args.coordinator,
                num_processes=args.num_processes,
                process_id=args.process_id,
                initialization_timeout=60)
            return True
        except Exception as e:  # noqa: BLE001 — every failure retries
            last = e
            print(f"CLUSTER_ATTEMPT {i + 1}/{args.cluster_retries} "
                  f"failed: {type(e).__name__}: {e}", flush=True)
            try:
                jax.distributed.shutdown()
            except Exception:  # noqa: BLE001 — best-effort teardown
                pass
            if i + 1 < max(1, args.cluster_retries):
                time.sleep(args.backoff * (2 ** i)
                           + random.uniform(0, args.backoff))
    print(f"CLUSTER_UNAVAILABLE: {type(last).__name__}: {last}",
          flush=True)
    return False


def _run(args) -> int:
    if args.local_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.local_devices}")

    orig_rank = args.orig_rank if args.orig_rank >= 0 else args.process_id
    peers = [int(p) for p in args.peers.split(",") if p != ""]
    if args.run_dir:
        os.makedirs(args.run_dir, exist_ok=True)
        _start_heartbeat(args.run_dir, orig_rank, args.heartbeat_interval)

    import jax

    if args.num_processes > 1 and not _init_with_retry(args):
        return EXIT_UNAVAILABLE
    if args.run_dir and len(peers) > 1:
        _start_watchdog(args.run_dir, orig_rank, peers,
                        args.heartbeat_interval, args.heartbeat_timeout)

    import numpy as np

    from repro.core import sep_partition
    from repro.faults import HostLossError, is_host_loss
    from repro.launch.mesh import make_tig_mesh
    from repro.tig.data import synthetic_tig
    from repro.tig.distributed import pac_train
    from repro.tig.graph import chronological_split
    from repro.tig.models import TIGConfig

    g = synthetic_tig("tiny", seed=args.seed)
    train_g, _, _, _ = chronological_split(g)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         args.parts, k=0.05)
    mesh = make_tig_mesh()
    n_dev = int(mesh.devices.size)
    ckpt_dir = os.path.join(args.run_dir, "ckpt") if args.run_dir else None

    try:
        res = pac_train(
            train_g, part, cfg, num_devices=n_dev, epochs=args.epochs,
            seed=args.seed, shuffle_parts=True, sync_mode=args.sync_mode,
            mesh=mesh, plan="device", grid_layout=args.grid_layout,
            epoch_boundary=args.epoch_boundary, eval_graph=g,
            eval_warm=args.eval_warm, ckpt_dir=ckpt_dir,
            ckpt_every=args.ckpt_every if ckpt_dir else 0,
            resume=args.resume and ckpt_dir is not None)
    except HostLossError as e:
        print(f"PEER_LOST: {e}", flush=True)
        return EXIT_PEER_LOST
    except Exception as e:  # noqa: BLE001 — classified below
        if args.num_processes > 1 and is_host_loss(e):
            print(f"PEER_LOST: {type(e).__name__}: {e}", flush=True)
            return EXIT_PEER_LOST
        raise

    if args.out:
        payload = {}
        for e, losses in enumerate(res.losses):
            payload[f"loss_{e}"] = np.asarray(losses)
        # tree_leaves order is deterministic for a fixed param structure
        for i, leaf in enumerate(jax.tree_util.tree_leaves(res.params)):
            payload[f"param_{i}"] = np.asarray(leaf)
        for key in ("mem", "mem2", "last"):
            payload[f"state_{key}"] = np.asarray(res.memory_states[key])
        for key, val in sorted((res.metrics or {}).items()):
            payload[f"metric_{key}"] = np.asarray(val)
        np.savez(args.out, **payload)

    print(f"pac_cluster done: process {jax.process_index()}"
          f"/{jax.process_count()}, devices={n_dev}, "
          f"grid_layout={args.grid_layout}", flush=True)
    if args.run_dir:
        _touch(_done(args.run_dir, orig_rank))
    if args.num_processes > 1:
        # explicit teardown: the atexit shutdown can race the coordinator
        # when processes finish at different times (SIGABRT on slow hosts)
        try:
            jax.distributed.shutdown()
        except Exception as e:  # noqa: BLE001 — peers may already be gone
            print(f"shutdown raced: {type(e).__name__}: {e}", flush=True)
    return 0


def main(argv=None) -> int:
    args = _parse(argv)
    if args.elastic and os.environ.get(_WORKER_ENV) != "1":
        return _supervise(args)
    return _run(args)


if __name__ == "__main__":
    sys.exit(main())
