import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): prove the distribution config is
coherent without real hardware.

For every (architecture x input shape x mesh) combination this script
``.lower().compile()``s the real training / prefill / decode program against
ShapeDtypeStruct stand-ins (no allocation), prints memory_analysis() (fits
HBM?) and cost_analysis() (FLOPs/bytes for §Roofline), parses the collective
schedule from the optimized HLO, and writes one JSON per combination under
``experiments/dryrun/``.

Meshes: single-pod (16, 16) ("data", "model") = 256 chips, and multi-pod
(2, 16, 16) ("pod", "data", "model") = 512 chips (the "pod" axis shards the
batch — proving cross-pod data parallelism lowers).

The paper's own workload (speed-tig) is dry-run as the PAC shard_map program
on a 256-way "part" mesh (one sub-graph partition per chip).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|...]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh, make_tig_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.roofline.analysis import MODEL_FLOPS, analyze_compiled

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# long_500k runs only for sub-quadratic archs (DESIGN.md §4)
LONG_OK = {"rwkv6-1.6b", "hymba-1.5b", "starcoder2-3b"}

ENC_LEN_DECODE = 4096       # fixed encoder memory for seamless decode shapes


def microbatch_for(cfg, shape, n_batch_shards: int = 16) -> int:
    """Grad-accumulation splits: keep per-microbatch per-device ~1 sequence
    at 4k so remat-saved carries fit HBM.  Capped so each microbatch still
    divides the batch-sharding axes."""
    if shape.kind != "train":
        return 1
    cap = max(shape.global_batch // n_batch_shards, 1)
    per_dev = max(shape.global_batch // 16, 1)
    if cfg.d_model >= 3584 or cfg.is_moe:
        m = per_dev
    elif cfg.d_model >= 2048:
        m = max(per_dev // 2, 1)
    else:
        m = max(per_dev // 4, 1)
    return min(m, cap)


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.enc_dec:
            batch["frames"] = sds((b, s, cfg.d_model), bf16)
        if cfg.frontend == "vision":
            f = cfg.frontend_tokens
            batch["patches"] = sds((b, f, cfg.d_model), bf16)
            batch["positions3"] = sds((b, 3, s), i32)
            batch["tokens"] = sds((b, s - f), i32)
            batch["targets"] = sds((b, s - f), i32)
        else:
            batch["tokens"] = sds((b, s), i32)
            batch["targets"] = sds((b, s), i32)
        return batch
    # decode: cross-attn K/V live in the cache (filled at prefill)
    return {"token": sds((b,), i32), "pos": sds((b,), i32)}


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_axis(global_batch: int, mesh, multi_pod: bool):
    """Batch sharding axes; B=1 (long_500k) cannot shard -> replicate."""
    data = mesh.shape["data"]
    pod = mesh.shape.get("pod", 1)
    if multi_pod and global_batch % (data * pod) == 0:
        return ("pod", "data")
    if global_batch % data == 0:
        return ("data",)
    return None


def _respec_batch(specs: dict, axes) -> dict:
    """Rewrite the leading batch axis of every batch spec to ``axes``."""
    def fix(p):
        rest = tuple(p)[1:]
        return P(axes, *rest)
    return {k: fix(v) for k, v in specs.items()}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               save: bool = True, verbose: bool = True) -> dict:
    if arch == "speed-tig":
        return dryrun_speed_tig(multi_pod=multi_pod, save=save,
                                verbose=verbose)
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and arch not in LONG_OK:
        return {"arch": arch, "shape": shape_name,
                "status": "skipped (full attention; DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    tp = mesh.shape["model"]
    batch = input_specs(arch, shape_name)
    b_axes = _batch_axis(shape.global_batch, mesh, multi_pod)
    n_shards = 1
    if b_axes:
        n_shards = int(np.prod([mesh.shape[a] for a in
                                (b_axes if isinstance(b_axes, tuple)
                                 else (b_axes,))]))
    cfg = dataclasses.replace(
        cfg, microbatch=microbatch_for(cfg, shape, n_shards))
    bspecs = _respec_batch(
        M.batch_specs(cfg, shape.kind, multi_pod), b_axes)
    bspecs = {k: v for k, v in bspecs.items() if k in batch}
    # train: FSDP (params+opt state over data x model).  prefill: weights
    # also sharded over data (§Perf A2 — throughput path, per-layer weight
    # all-gathers overlap; required for 235B-class params to fit v5e).
    # decode: model-only (latency path; per-layer gathers would serialize —
    # the 235B config needs a larger serving mesh, noted in EXPERIMENTS.md).
    pspecs = M.param_specs(cfg, fsdp=(shape.kind in ("train", "prefill")))

    t0 = time.time()
    sharded_moe = cfg.is_moe and shape.kind in ("train", "prefill") \
        and not os.environ.get("REPRO_MOE_PJIT")
    with compat.set_mesh(mesh), \
            M.activation_batch_axes(b_axes, sharded_moe=sharded_moe):
        if shape.kind == "train":
            params_shape = jax.eval_shape(
                lambda k: M.init_params(k, cfg, tp),
                jax.random.PRNGKey(0))
            opt = adamw(lr=1e-4)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            ospecs = {
                "step": P(),
                "mu": pspecs,
                "nu": pspecs,
            }
            step = M.make_train_step(cfg, opt, tp, batch_axes=b_axes)
            jitted = jax.jit(
                step,
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, ospecs),
                              _shardings(mesh, bspecs)),
                out_shardings=(_shardings(mesh, pspecs),
                               _shardings(mesh, ospecs),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, batch)
            tokens = shape.global_batch * shape.seq_len
            mflops = MODEL_FLOPS(cfg.active_param_count(), tokens, "train")
        elif shape.kind == "prefill":
            params_shape = jax.eval_shape(
                lambda k: M.init_params(k, cfg, tp),
                jax.random.PRNGKey(0))
            params_shape = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
                params_shape)
            fwd = lambda p, b: M.forward(p, b, cfg, tp)[0]
            logits_axes = P(b_axes, None, "model")
            jitted = jax.jit(
                fwd,
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, bspecs)),
                out_shardings=NamedSharding(mesh, logits_axes),
            )
            lowered = jitted.lower(params_shape, batch)
            tokens = shape.global_batch * shape.seq_len
            mflops = MODEL_FLOPS(cfg.active_param_count(), tokens, "infer")
        else:  # decode
            params_shape = jax.eval_shape(
                lambda k: M.init_params(k, cfg, tp),
                jax.random.PRNGKey(0))
            params_shape = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
                params_shape)
            cache_shape = jax.eval_shape(
                lambda: M.init_cache(cfg, tp, shape.global_batch,
                                     shape.seq_len, ENC_LEN_DECODE))
            cspecs = _respec_batch_cache(
                M.cache_specs(cfg, multi_pod), b_axes)
            sstep = lambda p, c, b: M.serve_step(p, c, b, cfg, tp)
            jitted = jax.jit(
                sstep,
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, cspecs),
                              _shardings(mesh, bspecs)),
                out_shardings=(NamedSharding(mesh, P(b_axes, "model")),
                               _shardings(mesh, cspecs)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, cache_shape, batch)
            mflops = MODEL_FLOPS(cfg.active_param_count(),
                                 shape.global_batch, "infer")

        compiled = lowered.compile()

    elapsed = time.time() - t0
    report = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=mflops,
        note=f"tp={tp} microbatch={cfg.microbatch} "
             f"batch_axes={b_axes} kind={shape.kind}")
    out = report.to_json()
    out["status"] = "ok"
    out["compile_seconds"] = elapsed
    mem = compiled.memory_analysis()
    out["memory_analysis"] = str(mem)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled in "
              f"{elapsed:.1f}s")
        print("  memory:", mem)
        print(f"  flops(global)={report.hlo_flops:.3e} "
              f"bytes={report.hlo_bytes:.3e} "
              f"coll={report.collective_bytes:.3e}")
        print(f"  terms: compute={report.compute_s*1e3:.2f}ms "
              f"memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"-> {report.dominant}-bound; useful={report.useful_ratio:.2f}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR, f"{arch}_{shape_name}_{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(out, f, indent=1)
    return out


def _respec_batch_cache(specs: dict, axes) -> dict:
    """Cache specs: batch is the SECOND axis (after layers)."""
    def fix(p):
        t = tuple(p)
        return P(t[0], axes, *t[2:])
    return {k: fix(v) for k, v in specs.items()}


def dryrun_speed_tig(*, multi_pod: bool, save: bool = True,
                     verbose: bool = True) -> dict:
    """Dry-run the PAC shard_map epoch program on a pod-scale 'part' mesh:
    256 (or 512) sub-graph partitions, one per chip — DGraphFin-scale node
    memory sharded per device (the paper's space-overhead story at pod
    scale).

    The lowered layout is the row-range-SHARDED data plane (PR 8): the
    (n_parts, steps, batch) raw-record grid AND the per-device T-CSR
    events are partitioned over "part" — after compilation the per-device
    input shards are asserted to be exactly ``1/n_parts`` of the global
    grid/event rows (each chip receives only its own rows; the replicated
    flat layout would ship every chip the full buffer)."""
    from repro.configs.speed_tig import TIG
    from repro.optim import adamw as _adamw
    from repro.tig.distributed import make_pac_epoch
    from repro.tig.models import init_params as tig_init

    n_parts = 512 if multi_pod else 256
    mesh = make_tig_mesh(n_parts)
    mesh_name = f"part{n_parts}"
    cfg = TIG
    # DGraphFin-scale: 4.9M nodes / n_parts per device; a few batches/epoch
    capacity = 4_889_537 // n_parts + 1
    steps = 8
    b, k = cfg.batch_size, cfg.num_neighbors
    sds = jax.ShapeDtypeStruct
    i32, f32, b_ = jnp.int32, jnp.float32, jnp.bool_
    n_edges = 4_300_999
    e_cap = n_edges // n_parts + n_parts  # balanced partitions (SEP)
    # per-device T-CSR export: 2 endpoint events per edge + K*depth pad
    ev_cap = 2 * e_cap + k * cfg.n_layers

    def batch_tree():
        # device plan + sharded layout: per-chip (steps, ...) RAW edge
        # records, row-range sharded over "part" (each chip's rows live on
        # that chip only); neighbor grids are sampled on device from the
        # per-device T-CSR below.
        return {
            "src": sds((n_parts, steps, b), i32),
            "dst": sds((n_parts, steps, b), i32),
            "neg": sds((n_parts, steps, b), i32),
            "t": sds((n_parts, steps, b), f32),
            "eidx": sds((n_parts, steps, b), i32),
            "valid": sds((n_parts, steps, b), b_),
        }

    def tcsr_events():
        return {
            "nbr": sds((n_parts, ev_cap), i32),
            "t": sds((n_parts, ev_cap), f32),
            "eidx": sds((n_parts, ev_cap), i32),
            "bat": sds((n_parts, ev_cap), i32),
        }

    opt = _adamw(lr=1e-4, max_grad_norm=1.0)
    params_shape = jax.eval_shape(
        lambda key: tig_init(key, cfg), jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt.init, params_shape)
    n_shared = int(0.01 * 4_889_537)   # top_k=1% hubs shared

    epoch_fn = make_pac_epoch(cfg, opt, steps, capacity, mesh=mesh,
                              device_plan=True, grid_layout="sharded")
    t0 = time.time()
    lowered = epoch_fn.lower(
        params_shape, opt_shape, batch_tree(),
        sds((n_parts,), i32),            # per-device grid offsets (all 0)
        sds((n_parts,), i32),            # per-device real batch counts
        sds((n_parts, capacity + 1, cfg.dim_node), f32),
        sds((n_parts, e_cap + 1, cfg.dim_edge), f32),
        sds((n_parts, n_shared), i32),
        sds((n_parts, capacity + 1), i32),   # T-CSR indptr (unoffset)
        tcsr_events(),
    )
    compiled = lowered.compile()
    elapsed = time.time() - t0

    # the sharded-grid contract: each chip's input shard holds ONE row of
    # the grid and of the event buffer — 1/n_parts of the global rows
    args_sh = compiled.input_shardings[0]
    grid_shard = args_sh[2]["src"].shard_shape((n_parts, steps, b))
    ev_shard = args_sh[9]["nbr"].shard_shape((n_parts, ev_cap))
    assert grid_shard == (1, steps, b), grid_shard
    assert ev_shard == (1, ev_cap), ev_shard
    shrink = n_parts * steps * b // (grid_shard[0] * steps * b)
    assert shrink == n_parts, (shrink, n_parts)

    report = analyze_compiled(
        compiled, arch="speed-tig", shape="pac_epoch",
        mesh_name=mesh_name, chips=n_parts,
        model_flops=0.0,
        note=f"PAC epoch (sharded grid + T-CSR): {steps} lockstep steps, "
             f"batch {b}, capacity {capacity} nodes/device, "
             f"{n_shared} shared nodes")
    out = report.to_json()
    out["status"] = "ok"
    out["compile_seconds"] = elapsed
    out["memory_analysis"] = str(compiled.memory_analysis())
    out["grid_layout"] = "sharded"
    out["per_device_grid_rows"] = int(grid_shard[0] * steps)
    out["per_device_event_rows"] = int(ev_shard[0] * ev_cap)
    out["input_shrink_factor"] = int(shrink)
    if verbose:
        print(f"[speed-tig PAC x {mesh_name}] compiled in {elapsed:.1f}s")
        print("  memory:", compiled.memory_analysis())
        print(f"  sharded inputs: grid shard {grid_shard}, events "
              f"{ev_shard} -> {shrink}x smaller than replicated")
        print(f"  terms: compute={report.compute_s*1e3:.3f}ms "
              f"memory={report.memory_s*1e3:.3f}ms "
              f"collective={report.collective_s*1e3:.3f}ms")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR, f"speed-tig_pac_{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    combos = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    for a in archs:
        if a == "speed-tig":
            combos.append((a, "pac_epoch"))
            continue
        for s in shapes:
            combos.append((a, s))

    failures = []
    for a, s in combos:
        for mp in meshes:
            try:
                r = dryrun_one(a, s, multi_pod=mp, save=not args.no_save)
                if r.get("status", "").startswith("skip"):
                    print(f"[{a} x {s}] {r['status']}")
            except Exception as e:
                failures.append((a, s, mp, repr(e)))
                print(f"[{a} x {s} mp={mp}] FAILED: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures")
        sys.exit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
