"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

Hardware constants for the roofline (v5e): see ``repro.roofline.analysis``.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_tig_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """single pod: (16, 16) ("data", "model") = 256 chips;
    multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tig_mesh(num_parts: int):
    """PAC mesh: one axis, one sub-graph partition per device (paper §II-C).

    On the production pod a TIG deployment uses all chips of one pod as
    partitions (the memory module shards |V|/256 per chip)."""
    return jax.make_mesh((num_parts,), ("part",))
