"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

Hardware constants for the roofline (v5e): see ``repro.roofline.analysis``.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_tig_mesh", "local_part_ranks"]


def make_production_mesh(*, multi_pod: bool = False):
    """single pod: (16, 16) ("data", "model") = 256 chips;
    multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tig_mesh(num_parts: Optional[int] = None):
    """PAC mesh: one process-spanning "part" axis, one sub-graph partition
    per device (paper §II-C); defaults to every device of the cluster
    (``jax.process_count() * local_device_count``).

    Devices are ordered by ``(process_index, id)`` so each host's local
    devices form a CONTIGUOUS row range of the axis — the contract the
    row-range-sharded PAC plan relies on: ``plan_epoch(local_ranks=...)``
    materializes only those rows per host and
    ``stream.stage_partitioned`` places them with
    ``make_array_from_process_local_data``, which maps local shards to
    local devices in exactly this order.

    On the production pod a TIG deployment uses all chips of one pod as
    partitions (the memory module shards |V|/256 per chip)."""
    devices = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    if num_parts is None:
        num_parts = len(devices)
    return jax.sharding.Mesh(np.asarray(devices[:num_parts]), ("part",))


def local_part_ranks(mesh) -> np.ndarray:
    """Ranks on the mesh's "part" axis owned by THIS process.

    The row-range-sharded PAC data plane requires them to be contiguous
    (one slice of the flat grid per host) — build the mesh with
    ``make_tig_mesh`` to guarantee that ordering."""
    flat = list(np.asarray(mesh.devices).flat)
    pi = jax.process_index()
    ranks = np.array([i for i, d in enumerate(flat)
                      if d.process_index == pi], dtype=np.int64)
    if ranks.size == 0:
        raise ValueError(
            f"process {pi} owns no device on the 'part' axis of {mesh}")
    if not np.array_equal(ranks,
                          np.arange(ranks[0], ranks[0] + ranks.size)):
        raise ValueError(
            "each process's devices must be contiguous on the 'part' axis "
            "(build the mesh with launch.mesh.make_tig_mesh)")
    return ranks
