"""Architecture configs: the 10 assigned architectures + the paper's own
TIG workload.  See base.py for the registry."""

from repro.configs.base import (
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    get_config,
    list_archs,
)

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get_config",
           "list_archs"]
