"""Qwen2-VL-7B [arXiv:2409.12191] — M-RoPE, dynamic resolution.

Assigned spec: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
We implement the LANGUAGE BACKBONE; the ViT vision encoder + projector is
the assignment's allowed stub — ``input_specs`` provides precomputed patch
embeddings that are prepended to the token embeddings, plus the 3D
(temporal, height, width) position ids that drive M-RoPE.  Full attention
-> long_500k skipped.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    citation="arXiv:2409.12191",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18_944,
    vocab=152_064,
    head_dim=128,
    act="swiglu",
    rope="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # t/h/w rotary sections (sum = dh/2)
    frontend="vision",
    frontend_tokens=1024,          # stubbed image patches in train shapes
)

REDUCED = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    citation="arXiv:2409.12191",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    head_dim=64,
    act="swiglu",
    rope="mrope",
    mrope_sections=(8, 12, 12),
    frontend="vision",
    frontend_tokens=16,
)

register(FULL, REDUCED)
