"""StarCoder2-3B [arXiv:2402.19173].

Assigned spec: 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 —
GQA, RoPE, native sliding-window attention (window 4096) -> long_500k RUNS
with the ring-buffer SWA cache.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    citation="arXiv:2402.19173",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12_288,
    vocab=49_152,
    head_dim=128,
    act="gelu",
    rope="rope",
    rope_theta=100_000.0,
    window=4096,
)

REDUCED = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    citation="arXiv:2402.19173",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=1024,
    vocab=512,
    head_dim=32,
    act="gelu",
    rope="rope",
    window=64,
)

register(FULL, REDUCED)
