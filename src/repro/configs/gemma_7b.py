"""Gemma-7B [arXiv:2403.08295].

Assigned spec: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000 —
GeGLU activation, head_dim=256 (the 2B variant uses MQA; 7B is effectively
MHA with kv=16).  Full attention only -> long_500k skipped (DESIGN.md §4).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="gemma-7b",
    family="dense",
    citation="arXiv:2403.08295",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24_576,
    vocab=256_000,
    head_dim=256,
    act="geglu",
    rope="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="gemma-7b",
    family="dense",
    citation="arXiv:2403.08295",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=1024,
    vocab=512,
    head_dim=64,
    act="geglu",
    rope="rope",
    tie_embeddings=True,
)

register(FULL, REDUCED)
