"""Hymba-1.5B [arXiv:2411.13676] — hybrid-head: parallel attention + mamba.

Assigned spec: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Every layer runs attention heads and Mamba (SSM) heads IN
PARALLEL on the same input and fuses their (normalized) outputs — the
paper's hybrid-head module.  Attention is sliding-window (local) in most
layers -> long_500k RUNS (SSM state + SWA ring cache).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    citation="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    head_dim=64,
    act="swiglu",
    rope="rope",
    window=1024,          # hymba's local attention window
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)

REDUCED = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    citation="arXiv:2411.13676",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    head_dim=32,
    act="swiglu",
    rope="rope",
    window=32,
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
)

register(FULL, REDUCED)
