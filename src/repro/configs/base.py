"""Architecture config system: one frozen dataclass + a registry.

Every assigned architecture ships a ``src/repro/configs/<id>.py`` declaring
its exact published hyper-parameters (cited), plus a ``reduced()`` variant
(<=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.  The full
configs are exercised only by the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence

__all__ = ["ArchConfig", "register", "get_config", "list_archs",
           "INPUT_SHAPES", "InputShape"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Transformer-family architecture description.

    Families: dense | moe | ssm | hybrid | audio | vlm.
    """

    name: str
    family: str
    citation: str

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default d_model // n_heads

    # attention / norm details
    act: str = "swiglu"                  # swiglu | geglu | gelu
    qk_norm: bool = False
    rope: str = "rope"                   # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Sequence[int] = ()   # per-axis rotary sections (M-RoPE)
    window: Optional[int] = None         # sliding-window size (SWA)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq: int = 524_288

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # decode-time capacity multiple (vs perfectly-uniform routing).  The
    # dropless alternative pads every expert to the full token count —
    # E/top_k-fold wasted GEMM work (16x for 128e top-8); 4x capacity keeps
    # the drop probability negligible for near-uniform routers while
    # cutting decode FLOPs ~E/(4*top_k)-fold (§Perf A3).
    decode_capacity_factor: float = 4.0
    router_aux_weight: float = 1e-2

    # SSM (mamba-style; hymba hybrid)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # RWKV6
    rwkv: bool = False
    rwkv_head_dim: int = 64

    # encoder-decoder (seamless)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: #frontend tokens prepended as embeddings
    frontend: str = "none"               # none | audio | vision
    frontend_tokens: int = 0             # default #stub tokens in train

    # training / numerics
    dtype: str = "bfloat16"
    remat: bool = True
    microbatch: int = 1                  # grad-accumulation splits
    attn_chunk: int = 512                # q-block for chunked attention

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.rwkv

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / linear attention / SWA)."""
        return self.rwkv or self.ssm_state > 0 or self.window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers), for 6*N*D.

        Tracks init_params to <2% (tested per arch in tests/test_archs.py).
        """
        d, v = self.d_model, self.vocab
        dh = self.resolved_head_dim
        ff_mats = 3 if self.act in ("swiglu", "geglu") else 2
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.rwkv:
            # time-mix (r,k,v,g,o = 5 d^2 + decay LoRA) + channel-mix
            lora = max(32, d // 32)
            per_layer = 5 * d * d + 2 * d * lora \
                + 2 * d * self.d_ff + d * d
        else:
            qkvo = d * (self.n_heads * dh) * 2 \
                + d * (self.n_kv_heads * dh) * 2
            per_layer += qkvo
            if self.is_moe:
                per_layer += self.n_experts * ff_mats * d \
                    * self.d_ff_expert + d * self.n_experts
            else:
                per_layer += ff_mats * d * self.d_ff
            if self.ssm_state:  # hymba parallel SSM heads
                di = self.ssm_expand * d
                dt_rank = max(16, d // 16)
                per_layer += d * 2 * di + di * self.ssm_conv \
                    + di * (dt_rank + 2 * self.ssm_state) \
                    + dt_rank * di + di * self.ssm_state \
                    + di * d + di
        n = emb + self.n_layers * per_layer
        if self.enc_dec:
            # encoder layers + decoder cross-attention
            enc = self.n_enc_layers * (4 * d * d + ff_mats * d * self.d_ff)
            cross = self.n_layers * 4 * d * d
            n += enc + cross
        return n

    def active_param_count(self) -> int:
        """MoE: params actually used per token (for 6*N_active*D)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense_like = dataclasses.replace(
            self, n_experts=0, top_k=0,
            d_ff=self.top_k * self.d_ff_expert)
        return dense_like.param_count() + self.n_layers * d * self.n_experts


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, "ArchConfig"] = {}
_REDUCED: dict[str, "ArchConfig"] = {}

_ARCH_MODULES = [
    "minitron_4b", "rwkv6_1g6b", "gemma_7b", "qwen3_32b",
    "seamless_m4t_medium", "qwen3_moe_235b_a22b", "starcoder2_3b",
    "hymba_1g5b", "qwen2_vl_7b", "olmoe_1b_7b", "speed_tig",
]


def register(cfg: ArchConfig, reduced: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)
