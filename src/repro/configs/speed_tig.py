"""The paper's own workload as a selectable config: a TGN-family TIG model
trained with SEP partitions + PAC (see repro.tig / repro.core).

This is not a transformer ArchConfig — it is registered for launcher
completeness (``--arch speed-tig`` routes to the TIG trainer) and is the
"most representative of the paper's technique" §Perf hillclimb target.
The ArchConfig fields describe the TIG model's dense modules so the dry-run
machinery can size it.
"""

from repro.configs.base import ArchConfig, register
from repro.tig.models import TIGConfig

TIG = TIGConfig(
    flavor="tgn",
    dim=172,             # paper's feature dim on the small datasets
    dim_time=100,
    dim_edge=172,
    dim_node=172,
    num_neighbors=10,
    batch_size=200,      # paper §III-A small-dataset batch size
)

# MXU-aligned 2-layer preset: every lane dim the kernels see is already a
# multiple of 128 — dim = 128 and raw_msg_dim = 2*128 + 64 + 64 = 384 =
# 3 x 128 — so the ops-boundary padding tier (kernels/ops.py) is a no-op
# and the Pallas launches fill whole MXU tiles.  n_heads = 1 keeps the
# PER-HEAD attention dim at 128 (the lane axis the kernel tiles; 2 heads
# would halve it to 64 and reintroduce padding); num_neighbors = 16 fills
# the 8-sublane tile of the attention K axis.  n_layers = 2 compiles the
# stacked temporal-attention fold (ONE scanned layer block).  Not
# paper-faithful (use TIG for Tab.III-V parity); this is the perf target.
TIG_MXU = TIGConfig(
    flavor="tgn",
    dim=128,
    dim_time=64,
    dim_edge=64,
    dim_node=64,
    num_neighbors=16,
    batch_size=200,
    n_heads=1,
    n_layers=2,
    use_pallas=True,
)

FULL = ArchConfig(
    name="speed-tig",
    family="tig",
    citation="this paper (SPEED)",
    n_layers=1,
    d_model=172,
    n_heads=2,
    n_kv_heads=2,
    d_ff=344,
    vocab=0,
    rope="none",
    act="gelu",
)

REDUCED = ArchConfig(
    name="speed-tig",
    family="tig",
    citation="this paper (SPEED)",
    n_layers=1,
    d_model=32,
    n_heads=2,
    n_kv_heads=2,
    d_ff=64,
    vocab=0,
    rope="none",
    act="gelu",
)

register(FULL, REDUCED)
