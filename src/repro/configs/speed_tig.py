"""The paper's own workload as a selectable config: a TGN-family TIG model
trained with SEP partitions + PAC (see repro.tig / repro.core).

This is not a transformer ArchConfig — it is registered for launcher
completeness (``--arch speed-tig`` routes to the TIG trainer) and is the
"most representative of the paper's technique" §Perf hillclimb target.
The ArchConfig fields describe the TIG model's dense modules so the dry-run
machinery can size it.
"""

from repro.configs.base import ArchConfig, register
from repro.tig.models import TIGConfig

TIG = TIGConfig(
    flavor="tgn",
    dim=172,             # paper's feature dim on the small datasets
    dim_time=100,
    dim_edge=172,
    dim_node=172,
    num_neighbors=10,
    batch_size=200,      # paper §III-A small-dataset batch size
)

FULL = ArchConfig(
    name="speed-tig",
    family="tig",
    citation="this paper (SPEED)",
    n_layers=1,
    d_model=172,
    n_heads=2,
    n_kv_heads=2,
    d_ff=344,
    vocab=0,
    rope="none",
    act="gelu",
)

REDUCED = ArchConfig(
    name="speed-tig",
    family="tig",
    citation="this paper (SPEED)",
    n_layers=1,
    d_model=32,
    n_heads=2,
    n_kv_heads=2,
    d_ff=64,
    vocab=0,
    rope="none",
    act="gelu",
)

register(FULL, REDUCED)
