"""OLMoE-1B-7B [arXiv:2409.02060] — fully open MoE, 64 experts top-8.

Assigned spec: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 (d_ff = per-expert hidden).  Full attention ->
long_500k skipped.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    citation="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50_304,
    act="swiglu",
    qk_norm=True,
    rope="rope",
    rope_theta=10_000.0,
    n_experts=64,
    top_k=8,
    d_ff_expert=1024,
    capacity_factor=1.25,
)

REDUCED = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    citation="arXiv:2409.02060",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=512,
    act="swiglu",
    qk_norm=True,
    rope="rope",
    n_experts=4,
    top_k=2,
    d_ff_expert=64,
    capacity_factor=1.5,
)

register(FULL, REDUCED)
