"""Minitron-4B — width/depth-pruned Nemotron-4 [arXiv:2407.14679].

Assigned spec: 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="minitron-4b",
    family="dense",
    citation="arXiv:2407.14679",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256_000,
    head_dim=128,        # pruned from Nemotron-4 15B (kept head_dim)
    act="swiglu",
    rope="rope",
    rope_theta=10_000.0,
)

REDUCED = ArchConfig(
    name="minitron-4b",
    family="dense",
    citation="arXiv:2407.14679",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    head_dim=32,
    act="swiglu",
    rope="rope",
)

register(FULL, REDUCED)
