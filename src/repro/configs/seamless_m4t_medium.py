"""SeamlessM4T-medium [arXiv:2308.11596] — speech/text enc-dec.

Assigned spec: 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206,
encoder-decoder, multimodal.  We implement the TRANSFORMER BACKBONE: a
12-layer encoder consuming STUBBED audio frame embeddings (the
mel-spectrogram + conformer feature extractor is the assignment's allowed
stub) and a 12-layer causal decoder with cross-attention over the encoder
memory.  Full attention -> long_500k skipped; decode shapes use the decoder
KV cache with a fixed encoder memory.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    citation="arXiv:2308.11596",
    n_layers=12,             # decoder layers
    n_enc_layers=12,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256_206,
    act="gelu",
    rope="none",             # learned/sinusoidal positions in the original
    frontend="audio",
    frontend_tokens=1024,    # stubbed audio frames fed to the encoder
)

REDUCED = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    citation="arXiv:2308.11596",
    n_layers=2,
    n_enc_layers=2,
    enc_dec=True,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    act="gelu",
    rope="none",
    frontend="audio",
    frontend_tokens=32,
)

register(FULL, REDUCED)
