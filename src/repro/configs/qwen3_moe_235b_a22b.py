"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family].

Assigned spec: 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936,
MoE 128 experts top-8 (d_ff is the per-expert hidden dim).  Full attention
-> long_500k skipped.  Experts are sharded over the "model" mesh axis
(expert parallelism).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    citation="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,              # kept for config parity; experts use d_ff_expert
    vocab=151_936,
    head_dim=128,
    act="swiglu",
    qk_norm=True,
    rope="rope",
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_ff_expert=1536,
    capacity_factor=1.25,
)

REDUCED = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    citation="hf:Qwen/Qwen3-30B-A3B",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=512,
    head_dim=32,
    act="swiglu",
    qk_norm=True,
    rope="rope",
    n_experts=4,
    top_k=2,
    d_ff_expert=96,
    capacity_factor=1.5,
)

register(FULL, REDUCED)
