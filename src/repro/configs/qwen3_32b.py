"""Qwen3-32B [hf:Qwen/Qwen3-8B family scaling].

Assigned spec: 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936 —
qk_norm (RMSNorm on q and k heads), GQA.  Full attention -> long_500k
skipped.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-32b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25_600,
    vocab=151_936,
    head_dim=128,
    act="swiglu",
    qk_norm=True,
    rope="rope",
    rope_theta=1_000_000.0,
)

REDUCED = ArchConfig(
    name="qwen3-32b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=768,
    vocab=512,
    head_dim=32,
    act="swiglu",
    qk_norm=True,
    rope="rope",
)

register(FULL, REDUCED)
