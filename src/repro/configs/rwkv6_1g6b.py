"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay
[arXiv:2404.05892].

Assigned spec: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
Head structure: d_model / 64 = 32 WKV heads of dim 64 (the published layout).
Supports long_500k (recurrent state is O(1) in sequence length).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    citation="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # WKV heads = d_model / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65_536,
    rwkv=True,
    rwkv_head_dim=64,
    rope="none",
    act="relu_sq",       # RWKV channel-mix uses squared ReLU
)

REDUCED = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    citation="arXiv:2404.05892",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    d_ff=448,
    vocab=512,
    rwkv=True,
    rwkv_head_dim=64,
    rope="none",
    act="relu_sq",
)

register(FULL, REDUCED)
