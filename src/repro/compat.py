"""jax version-compatibility shims (single home for API drift).

The codebase targets the modern public SPMD APIs — ``jax.shard_map`` with
``check_vma`` and the ambient-mesh ``jax.set_mesh`` — but deployments pin a
range of jax versions; on 0.4.x those live at
``jax.experimental.shard_map.shard_map`` (kwarg ``check_rep``) and the
ambient mesh is the ``Mesh`` context manager + ``thread_resources``.

Use ``compat.shard_map`` / ``compat.set_mesh`` everywhere instead of
touching ``jax.*`` directly, so the version split stays in this file.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "set_mesh"]


if hasattr(jax, "shard_map"):                               # jax >= 0.6

    def shard_map(f, *, mesh=None, in_specs, out_specs, check=False):
        kw = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=check, **kw)

else:                                                       # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    from jax.interpreters import pxla

    def _ambient_mesh():
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError(
                "shard_map without an explicit mesh needs an ambient mesh "
                "(enter one with repro.compat.set_mesh)")
        return mesh

    def shard_map(f, *, mesh=None, in_specs, out_specs, check=False):
        def wrapped(*args):
            m = mesh if mesh is not None else _ambient_mesh()
            return _shard_map(f, m, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check)(*args)
        return wrapped


if hasattr(jax, "set_mesh"):                                # jax >= 0.6

    def set_mesh(mesh):
        return jax.set_mesh(mesh)

else:                                                       # jax 0.4.x:
    # Mesh is itself the ambient-mesh context manager
    def set_mesh(mesh):
        return mesh
