"""Benchmark driver: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings
    PYTHONPATH=src python -m benchmarks.run --only table6_partition_stats
"""

from __future__ import annotations

import argparse
import time
import traceback

MODULES = [
    "engine_speedup",
    "kernel_backward",
    "ingest_prefetch",
    "pac_plan",
    "pac_multihost",
    "epoch_pipeline",
    "elastic_recovery",
    "device_sampling",
    "protocol_sharded",
    "table3_efficiency",
    "table4_linkpred",
    "table5_nodeclass",
    "table6_partition_stats",
    "table7_kl_compare",
    "table8_partition_time",
    "fig7_shuffle",
    "fig8_num_parts",
    "roofline_report",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            mod.run(fast=not args.full)
            print(f"[{name}] done in {time.perf_counter() - t0:.1f}s\n")
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
