"""Sharded quality path end-to-end: ingest -> 70/15/15 split views ->
val-selected out-of-core training -> paper-table metrics, all from a
``tig-shards-v1`` directory — plus the in-memory parity check.

``train_sharded(protocol=True)`` must report val/test transductive +
inductive AP/AUROC without materializing the full edge-feature table on
host, and its numbers must equal ``evaluate_params`` on the equivalent
in-memory graph (identical batch plan => identical metrics).  The CI
sharded-protocol smoke step runs this module in fast mode.

Rows go to ``experiments/bench/protocol_sharded.csv``.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.tig.data import synthetic_tig
from repro.tig.models import TIGConfig
from repro.tig.stream import write_graph_shards
from repro.tig.train import evaluate_params, train_sharded

PARITY_KEYS = ("val_ap", "val_auc", "val_ap_inductive", "test_ap",
               "test_auc", "test_ap_inductive", "test_auc_inductive")


def run(fast: bool = True):
    name, epochs = ("tiny", 2) if fast else ("small", 4)
    g = synthetic_tig(name, seed=1)
    cfg = TIGConfig(dim=16, dim_time=8, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=4, batch_size=128)

    with tempfile.TemporaryDirectory() as tmp:
        sh = write_graph_shards(g, os.path.join(tmp, "sh"), shard_edges=499)
        t0 = time.perf_counter()
        res = train_sharded(sh, cfg, epochs=epochs, protocol=True,
                            patience=2, seed=0,
                            eval_node_class=not fast)
        t_total = time.perf_counter() - t0
        ev = evaluate_params(sh.as_graph(), cfg, res.params, seed=0,
                             eval_node_class=not fast)

    nan_mismatch = [k for k in PARITY_KEYS
                    if np.isnan(res.metrics[k]) != np.isnan(ev[k])]
    diffs = [abs(res.metrics[k] - ev[k]) for k in PARITY_KEYS
             if np.isfinite(res.metrics[k]) and np.isfinite(ev[k])]
    parity = float(np.max(diffs)) if diffs else 0.0
    assert not nan_mismatch and parity == 0.0, \
        f"sharded/in-memory protocol parity broken: max diff {parity}, " \
        f"NaN mismatches {nan_mismatch}"

    m = res.metrics
    rows = [{
        "dataset": name,
        "edges": g.num_edges,
        "epochs_run": len(res.losses),
        "best_epoch": res.best_epoch,
        "val_ap": m["val_ap"],
        "val_auc": m["val_auc"],
        "val_ap_inductive": m["val_ap_inductive"],
        "test_ap": m["test_ap"],
        "test_auc": m["test_auc"],
        "test_ap_inductive": m["test_ap_inductive"],
        "test_auc_inductive": m["test_auc_inductive"],
        "node_auroc": m["node_auroc"],
        "parity_max_abs_diff": parity,
        "total_s": t_total,
    }]
    emit("protocol_sharded", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
