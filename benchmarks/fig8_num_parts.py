"""Paper Fig.8 — effect of the number of devices/partitions N on
downstream quality (more partitions => more deleted edges)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import edge_cut_fraction, sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import evaluate_params


def run(fast: bool = True, dataset: str = "small"):
    g = synthetic_tig(dataset, seed=0)
    train_g, _, _, _ = chronological_split(g)
    epochs = 2 if fast else 4
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    rows = []
    for n in (2, 4) if fast else (2, 4, 8):
        part = sep_partition(train_g.src, train_g.dst, train_g.t,
                             g.num_nodes, n, k=0.05)
        res = pac_train(train_g, part, cfg, num_devices=n, epochs=epochs,
                        shuffle_parts=False)
        ev = evaluate_params(g, cfg, res.params)
        rows.append({
            "num_devices": n,
            "edge_cut%": 100 * edge_cut_fraction(part),
            "ap_transductive": ev["test_ap"],
            "derived_speedup": res.derived_speedup,
        })
    emit("fig8_num_parts", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
