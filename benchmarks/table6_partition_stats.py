"""Paper Tab.VI — edge-cut %, edge/node balance, per algorithm.

SEP across top_k + HDRF + Random + LDG + KL on the largest synthetic
dataset the container comfortably holds."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import (
    hdrf_partition,
    kl_partition,
    ldg_partition,
    partition_stats,
    random_partition,
    sep_partition,
)
from repro.tig.data import synthetic_tig


def run(fast: bool = True, dataset: str | None = None):
    dataset = dataset or ("small" if fast else "taobao-s")
    scale = 1.0 if fast else 0.1      # taobao-s at 10% = 200k edges
    g = synthetic_tig(dataset, seed=0, scale=scale)
    rows = []

    def add(res):
        s = partition_stats(res)
        rows.append({
            "algorithm": s.algorithm,
            "total_cut%": 100 * s.edge_cut,
            "edge_std": s.edge_std,
            "avg_node_portion%": 100 * s.avg_node_portion,
            "node_std": s.node_std,
            "replication_factor": s.replication_factor,
            "shared_nodes": s.num_shared,
            "partition_time_s": s.elapsed_s,
        })

    for k in (0.0, 0.01, 0.05, 0.10):
        add(sep_partition(g.src, g.dst, g.t, g.num_nodes, 4, k=k))
    add(hdrf_partition(g.src, g.dst, g.num_nodes, 4))
    add(random_partition(g.src, g.dst, g.num_nodes, 4))
    add(ldg_partition(g.src, g.dst, g.num_nodes, 4))
    if g.num_edges <= 300_000:    # KL is O(V^2)-ish; cap its input
        add(kl_partition(g.src, g.dst, g.num_nodes, 4))
    emit("table6_partition_stats", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
