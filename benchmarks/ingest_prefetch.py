"""Chunked data plane end-to-end: ingest -> vectorized SEP -> chunked index
-> double-buffered prefetch -> scanned device epoch.

Measures, on a taobao-shaped synthetic stream (fast: ~200k edges, full:
the 2M-edge ``taobao-s`` preset):

  * JODIE CSV parse throughput, per-line loop vs the vectorized
    well-formed-block fast path (rows/s before/after),
  * shard ingestion time and peak host RSS (the feature table never
    materializes in host RAM — shards are memory-mapped and staged to a
    donated device buffer shard by shard),
  * chunk-vectorized SEP partition time over the sharded id columns,
  * chunked T-CSR neighbor-index build time,
  * steady-state epoch wall-clock with prefetch ON vs OFF — the overlap of
    epoch e+1's host planning with epoch e's scan.

Rows go to ``experiments/bench/ingest_prefetch.csv``.
"""

from __future__ import annotations

import os
import resource
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.models import TIGConfig
from repro.tig.sampler import ChronoNeighborIndex
from repro.tig.stream import (
    ShardedStream,
    iter_jodie_blocks,
    write_graph_shards,
)
from repro.tig.train import train_sharded


def _rss_mb() -> float:
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # ru_maxrss is kilobytes on Linux but bytes on macOS
    return rss / (1024.0 ** 2) if sys.platform == "darwin" else rss / 1024.0


def _jodie_parse_rows_s(g, tmp: str, rows: int) -> tuple[float, float]:
    """Rows/s of the JODIE block reader: per-line loop vs vectorized
    fast path, on a well-formed CSV written from the synthetic stream."""
    path = os.path.join(tmp, "ml_bench.csv")
    n = min(rows, g.num_edges)
    feat = g.edge_feat[:n, :4]
    with open(path, "w") as f:
        f.write("user_id,item_id,timestamp,state_label,"
                + ",".join(f"f{i}" for i in range(feat.shape[1])) + "\n")
        lab = g.labels if g.labels is not None else np.zeros(n, np.int64)
        for i in range(n):
            f.write(f"{g.src[i]},{g.dst[i]},{g.t[i]},{lab[i]},"
                    + ",".join(repr(float(x)) for x in feat[i]) + "\n")
    out = []
    for fast_path in (False, True):
        t0 = time.perf_counter()
        got = sum(len(b[0]) for b in iter_jodie_blocks(path, fast=fast_path))
        assert got == n
        out.append(n / (time.perf_counter() - t0))
    os.remove(path)
    return out[0], out[1]


def run(fast: bool = True):
    name, scale, epochs = ("ml25m-s", 0.4, 3) if fast \
        else ("taobao-s", 1.0, 3)

    g = synthetic_tig(name, seed=0, scale=scale)
    cfg = TIGConfig(dim=16, dim_time=8, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=4, batch_size=500)
    with tempfile.TemporaryDirectory() as tmp:
        rows_s_loop, rows_s_fast = _jodie_parse_rows_s(
            g, tmp, 200_000 if fast else 1_000_000)

        t0 = time.perf_counter()
        write_graph_shards(g, os.path.join(tmp, "sh"))
        t_ingest = time.perf_counter() - t0
        edges, nodes = g.num_edges, g.num_nodes
        del g  # from here on the stream lives on disk
        sh = ShardedStream.open(os.path.join(tmp, "sh"))

        t0 = time.perf_counter()
        src = sh.column("src")
        dst = sh.column("dst")
        t = sh.column("t")
        part = sep_partition(src, dst, t, sh.num_nodes, 4, k=0.05)
        t_sep = time.perf_counter() - t0

        t0 = time.perf_counter()
        ChronoNeighborIndex.from_chunks(
            lambda: sh.edge_chunks(), sh.num_nodes,
            cfg.num_neighbors, cfg.batch_size)
        t_index = time.perf_counter() - t0
        del src, dst, t

        res_pf = train_sharded(sh, cfg, epochs=epochs, prefetch=True)
        res_serial = train_sharded(sh, cfg, epochs=epochs, prefetch=False)

    # steady state: skip epoch 0 (jit compile + cold prefetch pipeline)
    steady_pf = float(np.mean(res_pf.epoch_seconds[1:]))
    steady_serial = float(np.mean(res_serial.epoch_seconds[1:]))
    assert res_pf.losses == res_serial.losses, \
        "prefetch changed training results"
    rows = [{
        "dataset": name,
        "edges": edges,
        "nodes": nodes,
        "jodie_rows_s_loop": rows_s_loop,
        "jodie_rows_s_fast": rows_s_fast,
        "jodie_parse_speedup": rows_s_fast / rows_s_loop,
        "ingest_s": t_ingest,
        "sep_partition_s": t_sep,
        "sep_edge_cut": float((part.edge_part < 0).mean()),
        "index_build_s": t_index,
        "epoch_s_prefetch": steady_pf,
        "epoch_s_serial": steady_serial,
        "prefetch_speedup": steady_serial / steady_pf,
        "peak_rss_mb": _rss_mb(),
    }]
    emit("ingest_prefetch", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
