"""Paper Tab.V — dynamic node classification AUROC (labeled datasets).

All rows report through the shared protocol driver: PAC rows via
``pac_train(eval_graph=..., eval_node_class=True)``, the single-device row
via ``train_single``, and an out-of-core row via
``train_sharded(protocol=True, eval_node_class=True)`` straight from a
``tig-shards-v1`` directory (dynamic labels ride the shard label column)."""

from __future__ import annotations

import os
import tempfile

from benchmarks.common import emit
from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.stream import write_graph_shards
from repro.tig.train import train_sharded, train_single


def run(fast: bool = True, dataset: str = "small"):
    g = synthetic_tig(dataset, seed=0)   # labeled preset
    train_g, _, _, _ = chronological_split(g)
    flavors = ("tgn",) if fast else ("jodie", "dyrep", "tgn", "tige")
    epochs = 2 if fast else 4
    rows = []
    for flavor in flavors:
        cfg = TIGConfig(flavor=flavor, dim=32, dim_time=16,
                        dim_edge=g.dim_edge, dim_node=g.dim_node,
                        num_neighbors=5, batch_size=100)
        for label, k in (("topk=0%", 0.0), ("topk=5%", 0.05)):
            part = sep_partition(train_g.src, train_g.dst, train_g.t,
                                 g.num_nodes, 4, k=k)
            res = pac_train(train_g, part, cfg, num_devices=4,
                            epochs=epochs, eval_graph=g,
                            eval_node_class=True)
            rows.append({"backbone": flavor, "setting": label,
                         "auroc": res.metrics["node_auroc"]})
        single = train_single(g, cfg, epochs=epochs, eval_node_class=True)
        rows.append({"backbone": flavor, "setting": "w/o partitioning",
                     "auroc": single.node_auroc})
        with tempfile.TemporaryDirectory() as tmp:
            sh = write_graph_shards(g, os.path.join(tmp, "sh"))
            shd = train_sharded(sh, cfg, epochs=epochs, protocol=True,
                                patience=max(1, epochs - 1),
                                eval_node_class=True)
        rows.append({"backbone": flavor, "setting": "sharded (out-of-core)",
                     "auroc": shd.metrics["node_auroc"]})
    emit("table5_nodeclass", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
