"""§Roofline report (deliverable g): aggregate the dry-run JSONs into the
per-(arch x shape x mesh) roofline table."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(fast: bool = True):
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            d = json.load(f)
        if d.get("status") != "ok":
            continue
        rows.append({
            "arch": d["arch"],
            "shape": d["shape"],
            "mesh": d["mesh"],
            "compute_ms": 1e3 * d["compute_s"],
            "memory_ms": 1e3 * d["memory_s"],
            "collective_ms": 1e3 * d["collective_s"],
            "dominant": d["dominant"],
            "useful_ratio": d["useful_ratio"],
            "hlo_flops": d["hlo_flops"],
            "collective_bytes": d["collective_bytes"],
        })
    if not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all --both-meshes` first")
    emit("roofline_report", rows)
    return rows


if __name__ == "__main__":
    run()
