"""Streaming-engine throughput: epoch seconds on a synthetic 100k-edge
stream (single device), split into host planning vs device epoch.

This is the measurement behind the engine refactor: host planning is the
vectorized chronological neighbor index + pre-staged (steps, ...) batch
pytree, and the device epoch is ONE jitted ``lax.scan`` instead of one
jitted dispatch per batch.

    PYTHONPATH=src python benchmarks/engine_speedup.py [--epochs N]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.optim import adamw
from repro.tig.batching import build_batch_program
from repro.tig.data import synthetic_tig
from repro.tig.engine import make_train_epoch
from repro.tig.models import TIGConfig, init_params, init_state
from repro.tig.train import graph_as_stream, train_epoch


def run(fast: bool = True, epochs: int = 3):
    # ml25m-s at 1/5 scale -> exactly 100k edges
    g = synthetic_tig("ml25m-s", seed=0, scale=0.2)
    cfg = TIGConfig(flavor="tgn", dim=64, dim_time=32, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=10, batch_size=200)
    stream, tables = graph_as_stream(g)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    rng = np.random.default_rng(0)

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    opt_state = opt.init(params)
    epoch_fn = make_train_epoch(cfg, opt)

    rows = []
    for ep in range(epochs):
        t0 = time.perf_counter()
        batches, _ = build_batch_program(stream, cfg, rng)
        t_host = time.perf_counter() - t0
        state = init_state(cfg, g.num_nodes)
        t1 = time.perf_counter()
        params, opt_state, state, loss = train_epoch(
            params, opt_state, state, batches, tables_j, epoch_fn)
        t_dev = time.perf_counter() - t1
        rows.append({
            "epoch": ep,
            "edges": g.num_edges,
            "steps": len(batches["src"]),
            "host_planning_s": round(t_host, 3),
            "device_epoch_s": round(t_dev, 3),
            "total_s": round(t_host + t_dev, 3),
            "edges_per_s": round(g.num_edges / (t_host + t_dev)),
            "loss": round(loss, 4),
            "note": "epoch 0 includes jit compile" if ep == 0 else "",
        })
        print(rows[-1])
    emit("engine_speedup", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    args = ap.parse_args()
    run(epochs=args.epochs)
