"""Paper Tab.VII — KL vs SEP(top_k=0): downstream AP + schedule speed-up.

KL balances nodes but not edges, so its PAC schedule wraps around badly —
the derived speed-up column shows exactly the paper's effect."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import kl_partition, sep_partition
from repro.core.pac import derived_speedup
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import evaluate_params


def run(fast: bool = True, dataset: str = "small"):
    g = synthetic_tig(dataset, seed=0)
    train_g, _, _, _ = chronological_split(g)
    flavors = ("tgn",) if fast else ("jodie", "dyrep", "tgn", "tige")
    epochs = 2 if fast else 4
    rows = []
    parts = {
        "kl": kl_partition(train_g.src, train_g.dst, g.num_nodes, 4),
        "sep_topk=0": sep_partition(train_g.src, train_g.dst, train_g.t,
                                    g.num_nodes, 4, k=0.0),
    }
    for flavor in flavors:
        cfg = TIGConfig(flavor=flavor, dim=32, dim_time=16,
                        dim_edge=g.dim_edge, dim_node=g.dim_node,
                        num_neighbors=5, batch_size=100)
        for label, part in parts.items():
            res = pac_train(train_g, part, cfg, num_devices=4,
                            epochs=epochs, shuffle_parts=False)
            ev = evaluate_params(g, cfg, res.params)
            rows.append({
                "backbone": flavor,
                "partitioner": label,
                "ap_transductive": ev["test_ap"],
                "ap_inductive": ev["test_ap_inductive"],
                "derived_speedup": res.derived_speedup,
                "partition_time_s": part.elapsed_s,
            })
    emit("table7_kl_compare", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
