"""Paper Fig.7 — effect of partition shuffling (8 parts -> 4 devices,
shuffled vs statically combined)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import evaluate_params


def run(fast: bool = True, dataset: str = "small"):
    g = synthetic_tig(dataset, seed=0)
    train_g, _, _, _ = chronological_split(g)
    epochs = 2 if fast else 4
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    part8 = sep_partition(train_g.src, train_g.dst, train_g.t,
                          g.num_nodes, 8, k=0.05)
    rows = []
    for shuffle in (True, False):
        res = pac_train(train_g, part8, cfg, num_devices=4, epochs=epochs,
                        shuffle_parts=shuffle)
        ev = evaluate_params(g, cfg, res.params)
        rows.append({
            "setting": "shuffled" if shuffle else "static-combine",
            "ap_transductive": ev["test_ap"],
            "ap_inductive": ev["test_ap_inductive"],
            "final_loss": float(res.mean_loss_per_epoch()[-1]),
        })
    emit("fig7_shuffle", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
