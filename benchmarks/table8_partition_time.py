"""Paper Tab.VIII — partitioning wall time, plus old-vs-new SEP throughput.

Two comparisons per dataset:
  * SEP (chunk-vectorized engine, the default) vs the per-edge scalar
    reference pass — edges/s and speedup, with a bit-parity check of the
    assignments (the chunked engine must be an exact drop-in);
  * SEP vs KL (the paper's Tab.VIII comparison; KL only on sizes where the
    O(V^2)-ish KL is feasible).

The paper reports 41x..94.6x SEP-vs-KL speed-up growing with graph size;
same trend here (CPU, synthetic shape-mirrors).  The chunked-vs-scalar
column is the PR-2 acceptance number: >= 10x on a million-edge stream.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import kl_partition
from repro.core.centrality import temporal_centrality, top_k_hubs
from repro.core.sep import streaming_vertex_cut, streaming_vertex_cut_reference
from repro.tig.data import synthetic_tig


def run(fast: bool = True):
    datasets = [("tiny", 1.0), ("small", 1.0), ("wikipedia-s", 1.0)] \
        if fast else [("small", 1.0), ("wikipedia-s", 1.0),
                      ("mooc-s", 1.0), ("dgraphfin-s", 0.25),
                      ("taobao-s", 0.5)]        # 1M-edge acceptance stream
    rows = []
    for name, scale in datasets:
        g = synthetic_tig(name, seed=0, scale=scale)
        cent = temporal_centrality(g.src, g.dst, g.t, g.num_nodes)
        hubs = top_k_hubs(cent, 0.05)
        chunked = streaming_vertex_cut(
            g.src, g.dst, g.num_nodes, 4, centrality=cent, hubs=hubs)
        scalar = streaming_vertex_cut_reference(
            g.src, g.dst, g.num_nodes, 4, centrality=cent, hubs=hubs)
        assert np.array_equal(chunked.edge_part, scalar.edge_part) \
            and np.array_equal(chunked.node_masks, scalar.node_masks), \
            f"{name}: chunked SEP diverged from the scalar oracle"
        t_kl = float("nan")
        if g.num_edges <= 120_000:
            t_kl = kl_partition(g.src, g.dst, g.num_nodes, 4).elapsed_s
        rows.append({
            "dataset": name,
            "edges": g.num_edges,
            "nodes": g.num_nodes,
            "sep_chunked_s": chunked.elapsed_s,
            "sep_scalar_s": scalar.elapsed_s,
            "chunked_edges_per_s": g.num_edges / chunked.elapsed_s,
            "scalar_edges_per_s": g.num_edges / scalar.elapsed_s,
            "chunked_speedup": scalar.elapsed_s / chunked.elapsed_s,
            "kl_seconds": t_kl,
            "kl_vs_sep_speedup": t_kl / chunked.elapsed_s,
        })
    emit("table8_partition_time", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
