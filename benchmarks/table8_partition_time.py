"""Paper Tab.VIII — partitioning wall time: SEP vs KL across dataset sizes.

The paper reports 41x..94.6x SEP speed-up growing with graph size; same
trend here (CPU, synthetic shape-mirrors)."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import kl_partition, sep_partition
from repro.tig.data import synthetic_tig


def run(fast: bool = True):
    datasets = [("tiny", 1.0), ("small", 1.0), ("wikipedia-s", 1.0)] \
        if fast else [("small", 1.0), ("wikipedia-s", 1.0),
                      ("mooc-s", 1.0), ("dgraphfin-s", 0.25)]
    rows = []
    for name, scale in datasets:
        g = synthetic_tig(name, seed=0, scale=scale)
        sep = sep_partition(g.src, g.dst, g.t, g.num_nodes, 4, k=0.05)
        t_kl = None
        if g.num_edges <= 120_000:
            kl = kl_partition(g.src, g.dst, g.num_nodes, 4)
            t_kl = kl.elapsed_s
        rows.append({
            "dataset": name,
            "edges": g.num_edges,
            "nodes": g.num_nodes,
            "sep_seconds": sep.elapsed_s,
            "kl_seconds": t_kl if t_kl is not None else float("nan"),
            "speedup": (t_kl / sep.elapsed_s) if t_kl else float("nan"),
        })
    emit("table8_partition_time", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
