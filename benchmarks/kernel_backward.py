"""Kernel fwd/bwd microbenchmark: wall-time + modeled HBM bytes per backend.

For each differentiable kernelized op (gru, temporal_attn, fused flush)
and each backend:

  * ``xla``                  — pure-jnp oracle forward, XLA autodiff bwd,
  * ``interpret-oracle-vjp`` — Pallas kernel body (interpret mode on CPU)
    forward, oracle-recompute VJP backward,
  * ``interpret-fused-bwd``  — Pallas forward AND Pallas backward kernel
    (flash-style in-kernel recompute; gru/attention only — the flush
    backward is oracle-VJP by design),

record forward and forward+backward wall time plus the modeled HBM bytes
from ``repro.roofline.kernel_bytes`` for the matching pipeline.  Interpret
mode executes kernels in Python, so its *wall time* is not meaningful as
device time — the modeled bytes column is the roofline-relevant output,
and the CSV is what CI uploads to track the fused-vs-oracle byte gap.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.roofline.kernel_bytes import attn_bytes, flush_bytes, gru_bytes

REPS = 3


def _time(fn, *args):
    jax.tree.map(lambda x: x.block_until_ready(), fn(*args))   # compile
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _gru_cases(b, d_in, d_h):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    args = (jax.random.normal(ks[0], (b, d_in)),
            jax.random.normal(ks[1], (b, d_h)),
            jax.random.normal(ks[2], (d_in, 3 * d_h)) * 0.3,
            jax.random.normal(ks[3], (d_h, 3 * d_h)) * 0.3,
            jax.random.normal(ks[4], (3 * d_h,)) * 0.1,
            jax.random.normal(ks[5], (3 * d_h,)) * 0.1)

    def fns(backend, bwd):
        if backend == "xla":
            f = ref.gru_ref
        else:
            f = lambda *a: ops.gru(*a, backend="interpret", bwd=bwd)
        loss = lambda *a: jnp.sum(f(*a))
        return jax.jit(f), jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))

    model = lambda fused_f, fused_b: (
        gru_bytes(b, d_in, d_h, direction="fwd", fused=fused_f).total,
        gru_bytes(b, d_in, d_h, direction="bwd", fused=fused_b).total)
    return args, fns, model, f"b={b},d_in={d_in},d_h={d_h}"


def _attn_cases(b, k, h, d):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    args = (jax.random.normal(ks[0], (b, h, d)),
            jax.random.normal(ks[1], (b, k, h, d)),
            jax.random.normal(ks[2], (b, k, h, d)),
            jax.random.uniform(ks[3], (b, k)) > 0.3)

    def fns(backend, bwd):
        if backend == "xla":
            f = ref.temporal_attention_ref
        else:
            f = lambda *a: ops.temporal_attention(
                *a, backend="interpret", bwd=bwd)
        loss = lambda q, kk, v, m: jnp.sum(f(q, kk, v, m))
        return jax.jit(f), jax.jit(jax.grad(loss, argnums=(0, 1, 2)))

    model = lambda fused_f, fused_b: (
        attn_bytes(b, k, h, d, direction="fwd", fused=fused_f).total,
        attn_bytes(b, k, h, d, direction="bwd", fused=fused_b).total)
    return args, fns, model, f"b={b},k={k},h={h},d={d}"


def _flush_cases(n, rows, dm, d):
    ks = jax.random.split(jax.random.PRNGKey(2), 8)
    args = (jax.random.randint(ks[0], (rows,), 0, n + 1).astype(jnp.int32),
            jax.random.normal(ks[1], (rows, dm)),
            jax.random.uniform(ks[2], (rows,)) * 10,
            jax.random.normal(ks[3], (n + 1, d)),
            jax.random.uniform(ks[4], (n + 1,)),
            jax.random.normal(ks[5], (dm, 3 * d)) * 0.3,
            jax.random.normal(ks[6], (d, 3 * d)) * 0.3,
            jax.random.normal(ks[7], (3 * d,)) * 0.1,
            jnp.zeros((3 * d,)))

    def fns(backend, bwd):
        be = "xla" if backend == "xla" else "interpret"
        f = lambda *a: ops.fused_flush(*a, backend=be)
        loss = lambda *a: jnp.sum(f(*a)[0]) + jnp.sum(f(*a)[2])
        return jax.jit(f), jax.jit(jax.grad(loss, argnums=(1, 5, 6, 7)))

    model = lambda fused_f, fused_b: (
        flush_bytes(n, rows, dm, d, direction="fwd", fused=fused_f).total,
        flush_bytes(n, rows, dm, d, direction="bwd", fused=fused_b).total)
    return args, fns, model, f"n={n},rows={rows},d_msg={dm},d_mem={d}"


# backend -> (fwd pipeline fused?, bwd pipeline fused?, bwd mode string)
BACKENDS = [
    ("xla", False, False, "oracle"),
    ("interpret-oracle-vjp", True, False, "oracle"),
    ("interpret-fused-bwd", True, True, "fused"),
]


def run(fast: bool = True):
    if fast:
        cases = [("gru", _gru_cases(64, 48, 32)),
                 ("temporal_attn", _attn_cases(64, 8, 2, 16)),
                 ("flush", _flush_cases(512, 64, 48, 32))]
    else:
        cases = [("gru", _gru_cases(512, 176, 128)),
                 ("temporal_attn", _attn_cases(600, 10, 2, 32)),
                 ("flush", _flush_cases(100_000, 400, 176, 128))]

    rows = []
    for op, (args, fns, model, shape) in cases:
        for backend, fused_f, fused_b, bwd in BACKENDS:
            if op == "flush" and bwd == "fused":
                continue       # flush backward is oracle-VJP by design
            fwd_fn, bwd_fn = fns(backend, bwd)
            mb_f, mb_b = model(fused_f, fused_b)
            rows.append({
                "op": op,
                "backend": backend,
                "shape": shape,
                "t_fwd_ms": _time(fwd_fn, *args),
                "t_fwd_bwd_ms": _time(bwd_fn, *args),
                "model_fwd_mb": mb_f / 1e6,
                "model_bwd_mb": mb_b / 1e6,
            })
    emit("kernel_backward", rows)
    return rows


if __name__ == "__main__":
    run()
