"""Multi-layer temporal attention scaling: wall-time + modeled HBM bytes
vs ``n_layers``, padded (MXU-aligned) vs raw lanes.

The stacked attention fold (``modules.stacked_temporal_attention``) runs
ONE compiled layer block under ``lax.scan`` — per layer it adds exactly
one attention fwd+bwd launch pair over the same 3B rows (the flush/memory
pipeline runs once regardless of depth).  This module measures:

  * epoch wall-time for n_layers in {1, 2, 3} on a small synthetic stream
    (compile epoch and steady-state epoch reported separately — on the CPU
    container these are informational, not asserted);
  * modeled per-step HBM bytes from ``roofline.kernel_bytes
    .step_pipeline_bytes`` at raw dims and at the lane-padded dims the
    Pallas launches actually move (``lanes=True``);
  * the per-layer byte increment, cross-checked against the standalone
    ``attn_bytes`` fwd+bwd model (asserted within 10% — they are the same
    model, so this guards the n_layers wiring, and it is deterministic on
    any host);
  * that an MXU-aligned config (the ``TIG_MXU`` preset dims) pays ZERO
    padding tax — lane padding is a no-op when every dim is already a
    multiple of 128.

    PYTHONPATH=src python -m benchmarks.run --only layer_scaling
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

LAYER_SWEEP = (1, 2, 3)


def _epoch_times(cfg, g, stream, tables_j, epochs=2):
    """Per-epoch device wall-times (epoch 0 includes jit compile)."""
    from repro.optim import adamw
    from repro.tig.batching import build_batch_program
    from repro.tig.engine import make_train_epoch
    from repro.tig.models import init_params, init_state
    from repro.tig.train import train_epoch

    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    opt_state = opt.init(params)
    epoch_fn = make_train_epoch(cfg, opt)

    times, steps = [], 0
    for _ in range(epochs):
        batches, _ = build_batch_program(stream, cfg, rng)
        steps = len(batches["src"])
        state = init_state(cfg, g.num_nodes)
        t0 = time.perf_counter()
        params, opt_state, state, _ = train_epoch(
            params, opt_state, state, batches, tables_j, epoch_fn)
        times.append(time.perf_counter() - t0)
    return times, steps


def run(fast: bool = True):
    from repro.roofline.kernel_bytes import attn_bytes, step_pipeline_bytes
    from repro.tig.data import synthetic_tig
    from repro.tig.models import TIGConfig
    from repro.tig.train import graph_as_stream

    g = synthetic_tig("wikipedia-s", seed=0, scale=0.25 if fast else 1.0)
    base = TIGConfig(flavor="tgn", dim=64, dim_time=32, dim_edge=g.dim_edge,
                     dim_node=g.dim_node, num_neighbors=10, batch_size=200)
    stream, tables = graph_as_stream(g)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}

    # per-layer attention increment, from the standalone op model
    head_d = base.dim // base.n_heads
    deltas = {}
    for lanes in (False, True):
        pair = (attn_bytes(3 * base.batch_size, base.num_neighbors,
                           base.n_heads, head_d, direction="fwd",
                           lanes=lanes).total
                + attn_bytes(3 * base.batch_size, base.num_neighbors,
                             base.n_heads, head_d, direction="bwd",
                             lanes=lanes).total)
        deltas[lanes] = pair

    rows = []
    prev = {}
    for n_layers in LAYER_SWEEP:
        cfg = dataclasses.replace(base, n_layers=n_layers)
        times, steps = _epoch_times(cfg, g, stream, tables_j)
        model = {lanes: step_pipeline_bytes(
            n_nodes=g.num_nodes, batch=cfg.batch_size, d_msg=cfg.msg_dim,
            d_mem=cfg.dim, k_neighbors=cfg.num_neighbors,
            n_heads=cfg.n_heads, n_layers=n_layers, lanes=lanes)
            for lanes in (False, True)}
        # the modeled per-layer increment must match the standalone
        # attention fwd+bwd model within 10% (same model — guards the
        # n_layers wiring in step_pipeline_bytes)
        for lanes in (False, True):
            if n_layers > 1:
                inc = model[lanes]["fused"] - prev[lanes]
                assert abs(inc - deltas[lanes]) <= 0.1 * deltas[lanes], (
                    n_layers, lanes, inc, deltas[lanes])
            prev[lanes] = model[lanes]["fused"]
        assert model[True]["fused"] >= model[False]["fused"]
        rows.append({
            "n_layers": n_layers,
            "edges": g.num_edges,
            "steps": steps,
            "compile_epoch_s": round(times[0], 3),
            "epoch_s": round(times[-1], 3),
            "edges_per_s": round(g.num_edges / times[-1]),
            "model_step_mb_raw": model[False]["fused"] / 1e6,
            "model_step_mb_padded": model[True]["fused"] / 1e6,
            "pad_overhead_x": model[True]["fused"] / model[False]["fused"],
            "model_layer_mb_raw": deltas[False] / 1e6,
            "model_layer_mb_padded": deltas[True] / 1e6,
        })
        print(rows[-1])

    # the TIG_MXU preset dims pay zero padding tax: msg_dim=384, per-head
    # attention dim 128 (one head), K=16 — all already tile-aligned
    mxu_raw = step_pipeline_bytes(n_nodes=g.num_nodes, batch=200, d_msg=384,
                                  d_mem=128, k_neighbors=16, n_heads=1,
                                  n_layers=2, lanes=False)
    mxu_pad = step_pipeline_bytes(n_nodes=g.num_nodes, batch=200, d_msg=384,
                                  d_mem=128, k_neighbors=16, n_heads=1,
                                  n_layers=2, lanes=True)
    assert mxu_pad["fused"] == mxu_raw["fused"], (
        "MXU-aligned dims must make lane padding a no-op, got "
        f"{mxu_pad['fused']} vs {mxu_raw['fused']}")

    emit("layer_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
