"""Epoch-boundary bubble benchmark: serial vs overlapped (async) boundary.

PR 9 splits PAC's fused epoch program into a scan body plus a separable
Alg.2 memory-sync epilogue and defers the per-epoch loss read to an async
drain, so the boundary's cross-host collectives and D2H copies hide
behind the next epoch instead of serializing the loop.  This module
measures that bubble on the simulated 2-host pod and cross-checks the
``roofline.pipeline_bubble`` model.

Both disciplines run the REAL programs (``make_pac_epoch`` /
``make_pac_sync`` on the vmap-simulated 4-device pod, bit-parity
asserted between them); what is *simulated* is the data-center-network
drain of the sync collectives.  On this one-CPU test rig the tiny
scenario's real sync moves ~0.5 MB — far below dispatch overhead — so
each epoch's drain is modeled as a sleep sized from
``kernel_bytes.pac_sync_bytes`` at production pod scale (the busiest
host of the 3-vs-1 split, DCN at ``DCN_GBPS``), exactly the constant the
roofline model uses.  The serial loop pays that drain (and the loss
fetch) inline every epoch; the overlapped loop dispatches the sync
program plus an async loss copy and drains on background threads,
paying one drain once, after the loop.

Per-epoch boundary bubble (epoch 0 excluded — compile warmup):

  * serial     = plan + stage + drain + fetch, all inline;
  * overlapped = prefetcher wait (the plan+stage spill) + dispatch
                 + (one final drain) / epochs.

Asserted (CI runs this module): overlapped bubble >= 1.3x below serial,
and the ``pipeline_bubble`` model's serial AND overlapped predictions
each agree with the measurement within 25%.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import emit, timer

PART_GROUPS = ([0, 1, 2], [3, 4], [5, 6], [7])  # 8 SEP parts -> 4 devices
HOSTS = ([0, 1, 2], [3])                        # 2 hosts, 3-vs-1 devices

# production-scale pod constants for the simulated DCN drain: shared-node
# memory of a wikipedia-scale run sharded 4 ways across 2 hosts
POD_SHARED = 30_000     # shared (cut) nodes
POD_D_MEM = 100         # memory width (TGN default)
DCN_GBPS = 1.25         # 10 GbE data-center link
EPOCHS = 4              # epoch 0 (pipeline fill) is excluded from stats


def _build_case():
    from repro.core import sep_partition
    from repro.tig.data import synthetic_tig
    from repro.tig.graph import chronological_split
    from repro.tig.models import TIGConfig

    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         len(PART_GROUPS) * 2, k=0.05)
    return train_g, part, cfg


def run(fast: bool = True):
    import jax
    import jax.numpy as jnp

    from repro.core.pac import shuffle_combine
    from repro.optim import adamw
    from repro.roofline.kernel_bytes import pac_sync_bytes
    from repro.roofline.pipeline_bubble import pipeline_bubble
    from repro.tig.distributed import (make_pac_epoch, make_pac_sync,
                                       plan_epoch)
    from repro.tig.models import init_params
    from repro.tig.stream import EpochPrefetcher
    from repro.tig.train import epoch_rng, time_scale_of

    train_g, part, cfg = _build_case()
    n_dev = len(PART_GROUPS)
    small = part.node_lists()
    scale = time_scale_of(train_g.t)
    seed = 0

    # the simulated cross-host drain: the busiest host of the 3-vs-1 pod
    # moves its local devices' DCN share of the sync collectives
    sync_b = pac_sync_bytes(POD_SHARED, POD_D_MEM, n_dev,
                            n_hosts=len(HOSTS), mode="latest")
    n_busy = max(len(h) for h in HOSTS)
    drain_s = sync_b["cross_host"] * n_busy / (DCN_GBPS * 1e9)
    print(f"simulated pod drain: {sync_b['cross_host'] * n_busy / 1e6:.1f}"
          f" MB cross-host on the {n_busy}-device host -> "
          f"{drain_s * 1e3:.1f} ms/epoch at {DCN_GBPS} GB/s")

    def build(ep):
        rng_ep = epoch_rng(seed, ep, 11)
        node_lists = shuffle_combine(small, n_dev, rng_ep)
        return plan_epoch(train_g, node_lists, part.shared_nodes, cfg,
                          rng_ep, time_scale=scale, plan="device")

    def to_device(ep_plan):
        dev = [
            {k: jnp.asarray(v) for k, v in ep_plan.batches.items()},
            jnp.asarray(ep_plan.offsets),
            jnp.asarray(ep_plan.n_batches),
            jnp.asarray(ep_plan.nfeat_local),
            jnp.asarray(ep_plan.efeat_local),
            jnp.asarray(ep_plan.shared_local),
            jnp.asarray(ep_plan.tcsr["indptr"]),
            {k: jnp.asarray(v) for k, v in ep_plan.tcsr.items()
             if k != "indptr"},
        ]
        jax.block_until_ready(dev)
        return ep_plan, tuple(dev)

    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    progs: dict = {}

    def programs(ep_plan, sync_epilogue):
        key = (ep_plan.steps, ep_plan.capacity, sync_epilogue)
        if key not in progs:
            progs[key] = make_pac_epoch(
                cfg, opt, ep_plan.steps, ep_plan.capacity,
                sync_mode="latest", device_plan=True,
                sync_epilogue=sync_epilogue)
        return progs[key]

    sync_p = make_pac_sync(sync_mode="latest")

    # warm every program the timed loops will hit (shuffle-combine draws
    # a few distinct (steps, capacity) shapes across epochs): compilation
    # must not pollute mid-loop boundary timings
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = opt.init(params)
    for ep in range(EPOCHS):
        ep_plan, dev = to_device(build(ep))
        out = programs(ep_plan, True)(params, opt_state, *dev)
        p2, o2, raw, l2 = programs(ep_plan, False)(
            params, opt_state, *dev)
        st = sync_p(raw, dev[5])
        jax.block_until_ready((out, p2, o2, st, l2))

    # ---------------------------------------------------------- serial
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = opt.init(params)
    plan_s, stage_s, scan_s, fetch_s, ser_bubble = [], [], [], [], []
    ser_losses = []
    for ep in range(EPOCHS):
        with timer() as t_plan:
            ep_plan = build(ep)
        with timer() as t_stage:
            ep_plan, dev = to_device(ep_plan)
        fused = programs(ep_plan, sync_epilogue=True)
        with timer() as t_scan:
            params, opt_state, states, losses = fused(
                params, opt_state, *dev)
            jax.block_until_ready((params, opt_state, states, losses))
        with timer() as t_fetch:
            time.sleep(drain_s)             # the inline cross-host drain
            ser_losses.append(np.asarray(losses))
        if ep == 0:                          # steady state only
            continue
        plan_s.append(t_plan.s)
        stage_s.append(t_stage.s)
        scan_s.append(t_scan.s)
        fetch_s.append(t_fetch.s - drain_s)
        ser_bubble.append(t_plan.s + t_stage.s + t_fetch.s)
    ser_params = params

    # ------------------------------------------------------- overlapped
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = opt.init(params)
    get_s, disp_s = [], []
    threads, ovl_losses = [], [None] * EPOCHS

    def drain(ep, states, losses):
        jax.block_until_ready(states)        # the sync program's output
        time.sleep(drain_s)                  # its simulated DCN share
        for leaf in jax.tree_util.tree_leaves(losses):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        ovl_losses[ep] = np.asarray(losses)

    with EpochPrefetcher(build, EPOCHS, to_device=to_device,
                         depth=1) as pf:
        for ep in range(EPOCHS):
            with timer() as t_get:
                ep_plan, dev = pf.get(ep)
            scan_only = programs(ep_plan, sync_epilogue=False)
            with timer() as t_disp:
                params, opt_state, raw, losses = scan_only(
                    params, opt_state, *dev)
                states = sync_p(raw, dev[5])     # dispatched, not awaited
                th = threading.Thread(target=drain,
                                      args=(ep, states, losses))
                th.start()
                threads.append(th)
            # the scan itself is identical across disciplines: excluded
            # from the bubble in both loops
            jax.block_until_ready((params, opt_state))
            if ep == 0:
                continue
            get_s.append(t_get.s)
            disp_s.append(t_disp.s)
    with timer() as t_join:                  # the one end-of-loop drain
        for th in threads:
            th.join()
    jax.block_until_ready(states)
    ovl_params = params

    # parity: split scan+sync and async drain must be bit-identical
    for a, b in zip(ser_losses, ovl_losses):
        np.testing.assert_array_equal(a, b)
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), ser_params, ovl_params)

    n_meas = EPOCHS - 1
    serial_b = float(np.mean(ser_bubble))
    ovl_b = float(np.mean(get_s) + np.mean(disp_s) + t_join.s / n_meas)
    ratio = serial_b / ovl_b

    model = pipeline_bubble(
        plan_s=float(np.mean(plan_s)), stage_s=float(np.mean(stage_s)),
        sync_s=drain_s, fetch_s=float(np.mean(fetch_s)),
        scan_s=float(np.mean(scan_s)), epochs=n_meas,
        dispatch_s=float(np.mean(disp_s)))
    err_serial = abs(model["serial_s"] - serial_b) / serial_b
    err_ovl = abs(model["overlapped_s"] - ovl_b) / ovl_b

    rows = [{
        "epochs_measured": n_meas,
        "drain_ms": drain_s * 1e3,
        "plan_ms": float(np.mean(plan_s)) * 1e3,
        "stage_ms": float(np.mean(stage_s)) * 1e3,
        "scan_ms": float(np.mean(scan_s)) * 1e3,
        "fetch_ms": float(np.mean(fetch_s)) * 1e3,
        "dispatch_ms": float(np.mean(disp_s)) * 1e3,
        "spill_ms": float(np.mean(get_s)) * 1e3,
        "serial_bubble_ms": serial_b * 1e3,
        "overlapped_bubble_ms": ovl_b * 1e3,
        "bubble_speedup": ratio,
        "model_serial_ms": model["serial_s"] * 1e3,
        "model_overlapped_ms": model["overlapped_s"] * 1e3,
        "model_err_serial": err_serial,
        "model_err_overlapped": err_ovl,
    }]
    print(f"boundary bubble: serial {serial_b * 1e3:.1f} ms -> overlapped "
          f"{ovl_b * 1e3:.1f} ms ({ratio:.2f}x); model "
          f"{model['serial_s'] * 1e3:.1f} / "
          f"{model['overlapped_s'] * 1e3:.1f} ms "
          f"(err {err_serial:.1%} / {err_ovl:.1%})")

    assert ratio >= 1.3, (
        f"overlapped boundary bubble must be >= 1.3x below serial, got "
        f"{ratio:.2f}x ({serial_b * 1e3:.1f} -> {ovl_b * 1e3:.1f} ms)")
    assert err_serial <= 0.25 and err_ovl <= 0.25, (
        f"pipeline_bubble model must agree within 25%: serial err "
        f"{err_serial:.1%}, overlapped err {err_ovl:.1%}")

    emit("epoch_pipeline", rows)
    return rows


if __name__ == "__main__":
    run()
