"""Device-side epoch planning benchmark: staged-grid H2D bytes + plan time.

PR 6 moves temporal-neighbor sampling onto the device: instead of the host
pre-sampling nine (steps, B, K) neighbor grids per stream and re-shipping
them EVERY epoch (``plan="host"``), the planner exports each stream's
T-CSR once (``ChronoNeighborIndex.device_export``) and ships raw edge
records only — the scanned step binary-searches the batch boundary and
gathers its own neighbor windows (``kernels.neighbor_sample``).

This module measures, on the deliberately imbalanced 4-device PAC split of
a synthetic stream (the same Tab.VII regime as ``benchmarks.pac_plan``):

  * plan wall-time (host pre-sampling is the dominant planning cost),
  * staged-grid H2D bytes (``EpochPlan.grid_bytes``),
  * total per-epoch H2D bytes including the staged T-CSR
    (``EpochPlan.plan_bytes`` — the T-CSR is epoch-invariant but charged
    here anyway, making the comparison conservative),
  * the analytic model (``roofline.kernel_bytes.epoch_plan_bytes``) next
    to the measured numbers.

The >= 2x H2D reduction on the imbalanced scenario is asserted here (CI
runs this module), as is raw-record bit-equality between the two plans.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timer
from benchmarks.pac_plan import _imbalanced_node_lists


def _measure(g, node_lists, cfg, *, plan, time_scale):
    from repro.tig.distributed import plan_epoch

    shared = np.zeros(0, dtype=np.int64)
    rng = np.random.default_rng(0)
    with timer() as t:
        ep = plan_epoch(g, node_lists, shared, cfg, rng,
                        time_scale=time_scale, host_replay=False, plan=plan)
    return ep, {
        "plan_s": t.s,
        "grid_mb": ep.grid_bytes() / 1e6,
        "tcsr_mb": ep.tcsr_bytes() / 1e6,
        "h2d_mb": ep.plan_bytes() / 1e6,
        "steps": ep.steps,
        "real_batches": int(ep.n_batches.sum()),
    }


def run(fast: bool = True):
    from repro.roofline.kernel_bytes import epoch_plan_bytes
    from repro.tig.data import synthetic_tig
    from repro.tig.models import TIGConfig

    name = "wikipedia-s" if fast else "ml25m-s"
    g = synthetic_tig(name, seed=0)
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    node_lists = _imbalanced_node_lists(g)
    from repro.tig.train import time_scale_of
    scale = time_scale_of(g.t)

    plan_host, m_host = _measure(g, node_lists, cfg,
                                 plan="host", time_scale=scale)
    plan_dev, m_dev = _measure(g, node_lists, cfg,
                               plan="device", time_scale=scale)

    # the device plan's raw records must be the host plan's, bit for bit —
    # only the nine pre-sampled neighbor grids may differ (absent)
    for key in plan_dev.batches:
        np.testing.assert_array_equal(plan_dev.batches[key],
                                      plan_host.batches[key])
    assert not any(k.startswith("nbr") for k in plan_dev.batches)

    # analytic model on the equivalent single-stream plan, for reference
    model = epoch_plan_bytes(
        steps=int(plan_host.n_batches.sum()), batch=cfg.batch_size,
        k=cfg.num_neighbors, num_nodes=g.num_nodes, total_events=2 * g.num_edges)

    rows = [
        {"plan": "host (pre-sampled grids)", "dataset": name, **m_host,
         "model_h2d_mb": model["host"] / 1e6},
        {"plan": "device (T-CSR + kernel)", "dataset": name, **m_dev,
         "model_h2d_mb": model["device"] / 1e6},
    ]
    ratio = m_host["h2d_mb"] / m_dev["h2d_mb"]
    grid_ratio = m_host["grid_mb"] / m_dev["grid_mb"]
    for r in rows:
        r["h2d_reduction_vs_host"] = m_host["h2d_mb"] / r["h2d_mb"]
    print(f"staged-plan H2D reduction: {ratio:.2f}x "
          f"(grid-only: {grid_ratio:.2f}x)")
    assert m_dev["h2d_mb"] < m_host["h2d_mb"], (
        "device planning must move strictly fewer H2D bytes than host "
        f"planning, got {m_dev['h2d_mb']:.3f} vs {m_host['h2d_mb']:.3f} MB")
    assert ratio >= 2.0, (
        f"imbalanced scenario must cut staged-plan H2D bytes >= 2x, "
        f"got {ratio:.2f}x")

    emit("device_sampling", rows)
    return rows


if __name__ == "__main__":
    run()
