"""Multi-host PAC staging benchmark: per-host grid + T-CSR bytes,
replicated flat layout vs row-range sharded (PR 8).

The replicated layout (the single-host oracle) ships EVERY device the
full flat batch grid and the concatenated T-CSR event buffer, so a host
with ``n_local`` devices stages the full plan once and transfers it
``n_local`` times over H2D.  The row-range-sharded layout cuts the same
plan by per-device rows: ``plan_epoch(layout="sharded", local_ranks=...)``
materializes ONLY the host's own devices' rows (host bytes) and each
device receives only its own (padded) row range (H2D bytes).

The simulated pod is deliberately imbalanced twice over: 8 SEP
partitions are combined unevenly onto 4 devices (3/2/2/1 parts each, so
per-device row counts differ), and the devices are split 3-vs-1 across 2
simulated hosts — the shape where the replicated layout hurts most,
because the 3-device host pays the full flat plan three times.  Per host
the module measures staged bytes (what planning must hold in RAM) and
H2D bytes (what the epoch transfers to that host's devices), asserting:

  * each local-ranks plan is bit-identical to its rows of the full
    sharded plan (every host derives the same global layout),
  * the sharded layout stages strictly fewer host bytes,
  * per-host H2D drops >= 2x (CI runs this module),
  * the measured reduction matches the analytic
    ``roofline.kernel_bytes.pac_staging_bytes`` model.

The layouts' training parity (exact equality of losses/params/memory/
metrics across >= 2 epochs with shuffle-combine resyncs, plus the
2-process CPU cluster) is covered by ``tests/test_pac_multihost.py``.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit

PART_GROUPS = ([0, 1, 2], [3, 4], [5, 6], [7])  # 8 SEP parts -> 4 devices
HOSTS = ([0, 1, 2], [3])                        # 2 hosts, 3-vs-1 devices


def run(fast: bool = True):
    from repro.core import sep_partition
    from repro.roofline.kernel_bytes import pac_staging_bytes
    from repro.tig.data import synthetic_tig
    from repro.tig.distributed import plan_epoch
    from repro.tig.models import TIGConfig
    from repro.tig.train import time_scale_of

    name = "wikipedia-s" if fast else "ml25m-s"
    g = synthetic_tig(name, seed=0)
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    part = sep_partition(g.src, g.dst, g.t, g.num_nodes,
                         len(PART_GROUPS) * 2, k=0.05)
    small = part.node_lists()
    node_lists = [np.unique(np.concatenate([small[i] for i in grp]))
                  for grp in PART_GROUPS]
    scale = time_scale_of(g.t)

    def plan(**kw):
        return plan_epoch(g, node_lists, part.shared_nodes, cfg,
                          np.random.default_rng(0), time_scale=scale,
                          plan="device", **kw)

    full_rep = plan(layout="replicated")
    full_sh = plan(layout="sharded")
    n_dev = len(node_lists)
    print(f"{name}: per-device batches {full_sh.n_batches.tolist()} "
          f"(rows_cap pads to {int(full_sh.n_batches.max())})")

    rows = []
    for h, ranks in enumerate(HOSTS):
        local = plan(layout="sharded", local_ranks=ranks)
        # the local-ranks plan must be bit-identical to its rows of the
        # full sharded plan (every host derives the same global layout)
        for key in full_sh.batches:
            np.testing.assert_array_equal(
                local.batches[key], full_sh.batches[key][ranks])
        for key in full_sh.tcsr:
            np.testing.assert_array_equal(
                local.tcsr[key], full_sh.tcsr[key][ranks])

        n_local = len(ranks)
        rep_staged = full_rep.plan_bytes()              # full flat plan
        rep_h2d = n_local * full_rep.device_input_bytes()
        sh_staged = local.plan_bytes()                  # own rows only
        sh_h2d = sh_staged      # each device receives exactly its rows
        staged_ratio = rep_staged / sh_staged
        h2d_ratio = rep_h2d / sh_h2d
        rows.append({
            "host": h,
            "n_local": n_local,
            "dataset": name,
            "replicated_staged_mb": rep_staged / 1e6,
            "sharded_staged_mb": sh_staged / 1e6,
            "replicated_h2d_mb": rep_h2d / 1e6,
            "sharded_h2d_mb": sh_h2d / 1e6,
            "staged_reduction": staged_ratio,
            "h2d_reduction": h2d_ratio,
        })
        print(f"host {h} ({n_local} dev): staged {rep_staged/1e6:.2f} -> "
              f"{sh_staged/1e6:.2f} MB ({staged_ratio:.2f}x), "
              f"H2D {rep_h2d/1e6:.2f} -> {sh_h2d/1e6:.2f} MB "
              f"({h2d_ratio:.2f}x)")
        assert sh_staged < rep_staged, (
            f"host {h}: sharded staging must be strictly below replicated")
        assert h2d_ratio >= 2.0, (
            f"host {h}: sharded layout must cut per-host H2D >= 2x, "
            f"got {h2d_ratio:.2f}x")

    # analytic cross-check: the roofline staging model, fed the plan's
    # actual row/event counts and per-row bytes, must reproduce the
    # measured per-device reduction (indptr bytes are the only unmodeled
    # term)
    row_bytes = full_rep.grid_bytes() / int(full_rep.n_batches.sum())
    events = (2 * full_sh.edges_per_device
              + cfg.num_neighbors * cfg.n_layers)
    model = pac_staging_bytes(full_sh.n_batches, events,
                              row_bytes=row_bytes, n_hosts=len(HOSTS))
    got = full_rep.plan_bytes() / (full_sh.plan_bytes() / n_dev)
    want = (model["per_device_replicated"] / model["per_device_sharded"])
    assert abs(got - want) / want < 0.15, (got, want)
    for row in rows:
        row["model_h2d_reduction"] = want

    emit("pac_multihost", rows)
    return rows


if __name__ == "__main__":
    run()
