"""Paper Tab.III — training time & per-device memory vs top_k.

For each top_k in {0, 1, 5, 10}% (+ HDRF + single-device):
  * partition the training stream (SEP / HDRF),
  * run one PAC epoch (4 simulated devices) measuring wall time,
  * report per-edge step time, schedule-derived speed-up vs single device,
    and the per-device memory-module bytes (the paper's GPU-memory column:
    node-memory rows x dim x 4B — the quantity that OOMs single devices).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import hdrf_partition, sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import train_single


def run(fast: bool = True, dataset: str = "small", flavors=("tgn",)):
    g = synthetic_tig(dataset, seed=0)
    train_g, _, _, _ = chronological_split(g)
    n_dev = 4
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    epochs = 1 if fast else 3
    rows = []
    mem_bytes_per_node = (2 * cfg.dim + 1) * 4  # mem + mem2 + last, f32

    def pac_row(label, part):
        t0 = time.perf_counter()
        res = pac_train(train_g, part, cfg, num_devices=n_dev,
                        epochs=epochs, shuffle_parts=False)
        wall = (time.perf_counter() - t0) / epochs
        cap = res.plan.capacity
        rows.append({
            "setting": label,
            "epoch_seconds(simulated_1core)": wall,
            "derived_speedup": res.derived_speedup,
            "edges_per_device_max": int(res.edges_per_device.max()),
            "mem_module_bytes_per_device": cap * mem_bytes_per_node,
            "loss": float(res.mean_loss_per_epoch()[-1]),
        })

    for k_pct in (0, 1, 5, 10):
        part = sep_partition(train_g.src, train_g.dst, train_g.t,
                             g.num_nodes, n_dev, k=k_pct / 100.0)
        pac_row(f"sep_topk={k_pct}%", part)

    hd = hdrf_partition(train_g.src, train_g.dst, g.num_nodes, n_dev)
    pac_row("hdrf", hd)

    # single-device baseline (the paper's Single-GPU / CPU row)
    t0 = time.perf_counter()
    res1 = train_single(g, cfg, epochs=epochs)
    wall1 = (time.perf_counter() - t0) / epochs
    rows.append({
        "setting": "single-device",
        "epoch_seconds(simulated_1core)": wall1,
        "derived_speedup": 1.0,
        "edges_per_device_max": train_g.num_edges,
        "mem_module_bytes_per_device": g.num_nodes * mem_bytes_per_node,
        "loss": res1.losses[-1],
    })
    emit("table3_efficiency", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
