"""PAC epoch-plan benchmark: host bytes + H2D traffic, replay vs wrap.

The transfer-minimal plan (PR 5) ships each device's REAL batches only —
a flat grid gathered on device as ``offset + s % n_batches`` — where the
legacy plan replayed every grid to the global lockstep length on the host
(``v[replay]``).  On an imbalanced partition the lockstep length is set by
the largest device, so the replayed plan pays ``N_dev * steps`` batch rows
of host memory and host->device transfer while the flat plan pays
``sum_k real_k``.  This module measures, on a deliberately imbalanced
4-device split of a synthetic stream:

  * plan wall-time,
  * peak host bytes during planning (tracemalloc),
  * batch-grid bytes (the H2D payload that differs between the layouts),
  * total H2D bytes (grids + per-device feature tables + metadata),

for the host-replay oracle, the device-wrap plan, and the device-wrap plan
built straight from ``tig-shards-v1`` row ranges (whose grids are asserted
bit-identical to the in-memory plan).  The >= 2x grid-byte reduction on
the imbalanced scenario is asserted here (CI runs this module).
"""

from __future__ import annotations

import tempfile
import tracemalloc

import numpy as np

from benchmarks.common import emit, timer


def _imbalanced_node_lists(g, weights=(0.70, 0.10, 0.10, 0.10), seed=0):
    """Split users and items across devices with skewed shares — every part
    keeps both sides of the bipartite stream so it owns internal edges,
    but one device dwarfs the rest (the Tab.VII imbalance regime)."""
    rng = np.random.default_rng(seed)
    nu = int(g.src.max()) + 1                   # users are [0, nu)
    parts: list[list[np.ndarray]] = [[] for _ in weights]
    for lo, hi in ((0, nu), (nu, g.num_nodes)):
        ids = rng.permutation(np.arange(lo, hi))
        cuts = np.cumsum(np.array(weights) * len(ids)).astype(int)[:-1]
        for k, piece in enumerate(np.split(ids, cuts)):
            parts[k].append(piece)
    return [np.sort(np.concatenate(p)) for p in parts]


def _measure_plan(source, node_lists, cfg, *, host_replay, time_scale):
    """Build one epoch plan and return (plan, row dict of measurements)."""
    import jax.numpy as jnp

    from repro.tig.distributed import plan_epoch

    shared = np.zeros(0, dtype=np.int64)
    rng = np.random.default_rng(0)
    tracemalloc.start()
    with timer() as t:
        plan = plan_epoch(source, node_lists, shared, cfg, rng,
                          time_scale=time_scale, host_replay=host_replay)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    # what pac_train's to_device actually ships
    offsets = plan.offsets if plan.offsets is not None else \
        np.zeros(len(node_lists), np.int32)
    h2d = [jnp.asarray(v) for v in plan.batches.values()]
    h2d += [jnp.asarray(offsets), jnp.asarray(plan.n_batches),
            jnp.asarray(plan.nfeat_local), jnp.asarray(plan.efeat_local),
            jnp.asarray(plan.shared_local)]
    h2d_bytes = int(sum(int(x.nbytes) for x in h2d))
    return plan, {
        "plan_s": t.s,
        "peak_host_mb": peak / 1e6,
        "grid_mb": plan.grid_bytes() / 1e6,
        "h2d_mb": h2d_bytes / 1e6,
        "steps": plan.steps,
        "real_batches": int(plan.n_batches.sum()),
    }


def run(fast: bool = True):
    from repro.tig.data import synthetic_tig
    from repro.tig.models import TIGConfig
    from repro.tig.stream import write_graph_shards
    from repro.tig.train import time_scale_of

    name = "wikipedia-s" if fast else "ml25m-s"
    g = synthetic_tig(name, seed=0)
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    node_lists = _imbalanced_node_lists(g)
    scale = time_scale_of(g.t)

    rows = []
    plan_old, m_old = _measure_plan(g, node_lists, cfg,
                                    host_replay=True, time_scale=scale)
    rows.append({"plan": "host_replay (oracle)", "dataset": name, **m_old})
    plan_new, m_new = _measure_plan(g, node_lists, cfg,
                                    host_replay=False, time_scale=scale)
    rows.append({"plan": "device_wrap (flat)", "dataset": name, **m_new})

    with tempfile.TemporaryDirectory(prefix="pac_plan_") as td:
        sh = write_graph_shards(g, td, shard_edges=4096)
        plan_shd, m_shd = _measure_plan(sh, node_lists, cfg,
                                        host_replay=False, time_scale=scale)
        rows.append({"plan": "device_wrap (sharded)", "dataset": name,
                     **m_shd})
        # the out-of-core localization must emit the exact same plan
        for key in plan_new.batches:
            np.testing.assert_array_equal(plan_shd.batches[key],
                                          plan_new.batches[key])
        np.testing.assert_array_equal(plan_shd.offsets, plan_new.offsets)

    grid_ratio = m_old["grid_mb"] / m_new["grid_mb"]
    h2d_ratio = m_old["h2d_mb"] / m_new["h2d_mb"]
    for r in rows:
        r["grid_reduction_vs_replay"] = m_old["grid_mb"] / r["grid_mb"]
    print(f"batch-grid H2D reduction: {grid_ratio:.2f}x "
          f"(total H2D incl. feature tables: {h2d_ratio:.2f}x)")
    assert grid_ratio >= 2.0, (
        f"imbalanced scenario must cut batch-grid H2D bytes >= 2x, "
        f"got {grid_ratio:.2f}x")

    emit("pac_plan", rows)
    return rows


if __name__ == "__main__":
    run()
