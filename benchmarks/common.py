"""Shared helpers for the paper-table benchmarks.

Conventions: every ``table*.py``/``fig*.py`` module exposes ``run(fast=True)``
returning a list of row dicts and prints a CSV; ``benchmarks.run`` drives
them all and writes ``experiments/bench/<name>.csv``.

Scale note (DESIGN.md §3): the paper's absolute wall-clock speed-ups come
from 4x V100s; this container has one CPU core.  Time-like columns therefore
report (a) measured per-edge step time and (b) the schedule-derived speed-up
``total_edges / max_device_edges``, the perfect-overlap bound realized by
PAC's lockstep loop.  Partition-quality and downstream-quality columns are
measured exactly as in the paper.
"""

from __future__ import annotations

import csv
import io
import os
import time

import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def emit(name: str, rows: list[dict]) -> str:
    """Print rows as CSV and persist to experiments/bench/<name>.csv."""
    if not rows:
        print(f"[{name}] no rows")
        return ""
    cols = list(rows[0].keys())
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow({k: _fmt(v) for k, v in r.items()})
    text = buf.getvalue()
    print(f"==== {name} ====")
    print(text)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.csv"), "w") as f:
        f.write(text)
    return text


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4f}"
    return v


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
