"""Recovery warm-up benchmark: replay vs TIGER restart vs checkpoint.

After a host loss, the recovered process has its parameters back (atomic
checkpoint) but needs a warm node memory before it can serve val/test —
the SPEED protocol's default is an O(E) replay of the train split.  This
module measures the three warm-up disciplines the elastic subsystem
offers on the same trained model:

  * ``replay``   — re-run the forward-only train epoch (the oracle);
  * ``restart``  — TIGER-style (arXiv 2302.06057): one O(N) forward of
                   the fitted restarter head over the embedding bank
                   (``tig.restart``), no stream access at all;
  * ``ckpt``     — ``repro.checkpoint`` restore of the saved memory (the
                   lower bound, but only valid at the exact saved step —
                   replay/restart warm ANY params to the stream's end).

The restarter's collect+fit cost is amortized once at train time and
reported separately (``fit_s``).  Quality parity: every discipline's
warm state is scored through the SAME protocol path (``warm="state"``),
and the restart state must stay within 0.05 val AP of the replay-warm
oracle.  Asserted (CI runs this module): ``restart`` wall time strictly
below ``replay``.
"""

from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import emit, timer

EPOCHS = 1          # setup training (params only need to be plausible)
FIT_STEPS = 200     # restarter head fit (fast mode)


def _setup():
    from repro.tig.data import synthetic_tig
    from repro.tig.models import TIGConfig
    from repro.tig.train import train_single

    g = synthetic_tig("tiny", seed=0)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    res = train_single(g, cfg, epochs=EPOCHS, seed=0)
    return g, cfg, res.params


def _replay_state(params, cfg, splits, tables_j):
    """Forward-only train replay to a warm memory — the pure O(E) oracle
    (no embedding collection overhead)."""
    from repro.tig.batching import build_batch_program, stack_batches
    from repro.tig.engine import make_eval_epoch
    from repro.tig.models import init_state
    from repro.tig.protocol import device_batches

    batches, _ = build_batch_program(splits.train, cfg,
                                     np.random.default_rng(0),
                                     neg_pool=splits.neg_pool)
    if isinstance(batches, (list, tuple)):
        batches = stack_batches(list(batches))
    state, _aux = make_eval_epoch(cfg)(
        params, init_state(cfg, splits.num_nodes),
        device_batches(batches), tables_j)
    import jax
    return jax.block_until_ready(state)


def run(fast: bool = True):
    import jax

    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.tig.batching import make_tables
    from repro.tig.protocol import run_protocol, split_views
    from repro.tig.restart import build_restarter, restart_memory

    g, cfg, params = _setup()
    splits = split_views(g)
    tables_j = {k: np.asarray(v) for k, v in
                make_tables(g.edge_feat, g.node_feat).items()}
    import jax.numpy as jnp
    tables_j = {k: jnp.asarray(v) for k, v in tables_j.items()}

    steps = FIT_STEPS if fast else 400
    with timer() as t_fit:
        rst, oracle_state = build_restarter(params, cfg, splits, tables_j,
                                            seed=0, steps=steps)

    with tempfile.TemporaryDirectory(prefix="tig_elastic_") as d:
        ckpt_dir = os.path.join(d, "ckpt")
        save_checkpoint(ckpt_dir, 0, {"state": oracle_state})
        template = {"state": jax.tree.map(np.asarray, oracle_state)}

        # pre-warm every compiled program so the timed passes measure the
        # recovery step, not compilation
        _replay_state(params, cfg, splits, tables_j)
        restart_memory(rst, splits.num_nodes, tables_j)
        restore_checkpoint(ckpt_dir, 0, template)

        with timer() as t_replay:
            replay_warm = _replay_state(params, cfg, splits, tables_j)
        with timer() as t_restart:
            restart_warm = restart_memory(rst, splits.num_nodes, tables_j)
        with timer() as t_ckpt:
            ckpt_warm = restore_checkpoint(ckpt_dir, 0, template)["state"]

    def score(state):
        m = run_protocol(params, cfg, splits, tables_j, seed=0,
                         warm="state", state=state)
        return float(m["val_ap"]), float(m["test_ap"])

    rows = []
    aps = {}
    for name, secs, state in (("replay", t_replay.s, replay_warm),
                              ("restart", t_restart.s, restart_warm),
                              ("ckpt", t_ckpt.s, ckpt_warm)):
        val_ap, test_ap = score(state)
        aps[name] = val_ap
        rows.append({"discipline": name, "warm_s": secs,
                     "speedup_vs_replay": t_replay.s / max(secs, 1e-9),
                     "val_ap": val_ap, "test_ap": test_ap,
                     "fit_s": t_fit.s if name == "restart" else 0.0,
                     "fit_mse": rst.fit_mse if name == "restart" else 0.0})

    assert t_restart.s < t_replay.s, \
        f"restart warm-up {t_restart.s:.3f}s not below replay " \
        f"{t_replay.s:.3f}s"
    assert abs(aps["restart"] - aps["replay"]) <= 0.05, \
        f"restart val AP {aps['restart']:.4f} drifted from replay oracle " \
        f"{aps['replay']:.4f}"
    assert abs(aps["ckpt"] - aps["replay"]) <= 1e-9, \
        "checkpoint restore must reproduce the replay-warm metrics exactly"

    emit("elastic_recovery", rows)
    return rows


if __name__ == "__main__":
    run()
