"""Paper Tab.IV — link-prediction AP (transductive + inductive) across
top_k settings, HDRF, the no-partitioning baseline, and the out-of-core
sharded quality path, per backbone.

Every row reports through the same protocol driver
(``repro.tig.protocol.run_protocol``): PAC-trained rows route via
``pac_train(eval_graph=...)``, the single-device row via ``train_single``,
and the sharded row via ``train_sharded(protocol=True)`` — trained and
evaluated directly from a ``tig-shards-v1`` directory."""

from __future__ import annotations

import os
import tempfile

from benchmarks.common import emit
from repro.core import hdrf_partition, sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.stream import write_graph_shards
from repro.tig.train import train_sharded, train_single


def run(fast: bool = True, dataset: str = "small"):
    g = synthetic_tig(dataset, seed=0)
    train_g, _, _, _ = chronological_split(g)
    n_dev = 4
    flavors = ("tgn",) if fast else ("jodie", "dyrep", "tgn", "tige")
    epochs = 2 if fast else 4
    rows = []
    for flavor in flavors:
        cfg = TIGConfig(flavor=flavor, dim=32, dim_time=16,
                        dim_edge=g.dim_edge, dim_node=g.dim_node,
                        num_neighbors=5, batch_size=100)
        settings = [(f"topk={k}%", k / 100.0) for k in (0, 5)] \
            if fast else [(f"topk={k}%", k / 100.0) for k in (0, 1, 5, 10)]
        for label, k in settings:
            part = sep_partition(train_g.src, train_g.dst, train_g.t,
                                 g.num_nodes, n_dev, k=k)
            res = pac_train(train_g, part, cfg, num_devices=n_dev,
                            epochs=epochs, eval_graph=g)
            rows.append({"backbone": flavor, "setting": label,
                         "ap_transductive": res.metrics["test_ap"],
                         "ap_inductive": res.metrics["test_ap_inductive"]})
        hd = hdrf_partition(train_g.src, train_g.dst, g.num_nodes, n_dev)
        res = pac_train(train_g, hd, cfg, num_devices=n_dev, epochs=epochs,
                        eval_graph=g)
        rows.append({"backbone": flavor, "setting": "hdrf",
                     "ap_transductive": res.metrics["test_ap"],
                     "ap_inductive": res.metrics["test_ap_inductive"]})
        single = train_single(g, cfg, epochs=epochs)
        rows.append({"backbone": flavor, "setting": "w/o partitioning",
                     "ap_transductive": single.test_ap,
                     "ap_inductive": single.test_ap_inductive})
        # quality path from shards: same protocol, no in-memory graph
        with tempfile.TemporaryDirectory() as tmp:
            sh = write_graph_shards(g, os.path.join(tmp, "sh"))
            shd = train_sharded(sh, cfg, epochs=epochs, protocol=True,
                                patience=max(1, epochs - 1))
        rows.append({"backbone": flavor, "setting": "sharded (out-of-core)",
                     "ap_transductive": shd.metrics["test_ap"],
                     "ap_inductive": shd.metrics["test_ap_inductive"]})
    emit("table4_linkpred", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
