"""Engine parity: the scanned epoch programs must reproduce the per-batch
step loop (single-device) and the hand-rolled PAC device-epoch semantics
(cycle reset/backup, DDP pmean, shared-node sync) they replaced."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sep_partition
from repro.optim import adamw
from repro.tig.batching import build_batch_program, unstack_batches
from repro.tig.data import synthetic_tig
from repro.tig.distributed import make_pac_epoch, pac_train, plan_epoch
from repro.tig.engine import (
    make_eval_epoch,
    make_train_epoch,
    scan_eval_stream,
    scan_train_epoch,
)
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig, init_params, init_state, step_loss
from repro.tig.train import (
    graph_as_stream,
    make_eval_step,
    make_train_step,
    train_epoch,
)

CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=32)


def setup_single(cfg=CFG, seed=3):
    g = synthetic_tig("tiny", seed=seed)
    stream, tables = graph_as_stream(g)
    stacked, _ = build_batch_program(stream, cfg, np.random.default_rng(0))
    stacked = {k: v for k, v in stacked.items() if k != "labels"}
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, g.num_nodes)
    return g, stacked, tables_j, params, state


def test_scan_train_epoch_matches_per_batch_loop():
    g, stacked, tables_j, params, state = setup_single()
    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    opt_state = opt.init(params)

    # reference: the pre-engine per-batch dispatch loop
    step_fn = make_train_step(CFG, opt)
    p_ref, o_ref, s_ref = params, opt_state, state
    losses_ref = []
    for batch in unstack_batches(stacked):
        bj = {k: jnp.asarray(v) for k, v in batch.items()}
        p_ref, o_ref, s_ref, loss = step_fn(p_ref, o_ref, s_ref, bj,
                                            tables_j)
        losses_ref.append(float(loss))

    epoch_fn = make_train_epoch(CFG, opt)
    bj = {k: jnp.asarray(v) for k, v in stacked.items()}
    p, o, s, losses = epoch_fn(params, opt_state, state, bj, tables_j)

    np.testing.assert_allclose(np.asarray(losses), losses_ref, atol=1e-5)
    for key in ("mem", "mem2", "last"):
        np.testing.assert_allclose(np.asarray(s[key]),
                                   np.asarray(s_ref[key]), atol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5), p, p_ref)


def test_scan_eval_stream_matches_per_batch_loop():
    g, stacked, tables_j, params, state = setup_single(seed=5)
    eval_step = make_eval_step(CFG)
    s_ref = state
    pos_ref, neg_ref, emb_ref = [], [], []
    for batch in unstack_batches(stacked):
        bj = {k: jnp.asarray(v) for k, v in batch.items()}
        s_ref, aux = eval_step(params, s_ref, bj, tables_j)
        pos_ref.append(np.asarray(aux["pos_logit"]))
        neg_ref.append(np.asarray(aux["neg_logit"]))
        emb_ref.append(np.asarray(aux["src_embed"]))

    eval_fn = make_eval_epoch(CFG, collect_embeddings=True)
    bj = {k: jnp.asarray(v) for k, v in stacked.items()}
    s, aux = eval_fn(params, state, bj, tables_j)

    np.testing.assert_allclose(np.asarray(aux["pos_logit"]),
                               np.stack(pos_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(aux["neg_logit"]),
                               np.stack(neg_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(aux["src_embed"]),
                               np.stack(emb_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s["mem"]),
                               np.asarray(s_ref["mem"]), atol=1e-5)


def test_train_epoch_accepts_stacked_and_list_batches():
    g, stacked, tables_j, params, state = setup_single()
    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    epoch_fn = make_train_epoch(CFG, opt)

    def run(batches):
        # fresh carries per run: the epoch donates its input buffers
        p = jax.tree.map(jnp.copy, params)
        s = jax.tree.map(jnp.copy, state)
        return train_epoch(p, opt.init(p), s, batches, tables_j, epoch_fn)

    out_stacked = run(stacked)
    out_list = run(unstack_batches(stacked))
    assert out_stacked[-1] == pytest.approx(out_list[-1], abs=1e-6)


def test_pac_epoch_matches_reference_loop():
    """make_pac_epoch (vmap over the shared scan program, device-side
    Alg.2 wrap-around over the flat real-batch grid) vs a hand-rolled
    python loop implementing Alg.2: per-device cycle reset, wrap-around
    batch lookup, mean-of-grads DDP update, cycle-end backup,
    latest-timestamp shared sync."""
    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    n_dev = 2
    cfg = TIGConfig(flavor="tgn", dim=8, dim_time=4, dim_edge=16,
                    dim_node=16, num_neighbors=3, batch_size=100)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, n_dev, k=0.05)
    rng = np.random.default_rng(0)
    plan = plan_epoch(train_g, part.node_lists(), part.shared_nodes,
                      cfg, rng)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    opt_state = opt.init(params)

    # --- engine path (vmap simulation) --------------------------------
    epoch_fn = make_pac_epoch(cfg, opt, plan.steps, plan.capacity,
                              sync_mode="latest")
    p_e, o_e, states_e, losses_e = epoch_fn(
        params, opt_state,
        {k: jnp.asarray(v) for k, v in plan.batches.items()},
        jnp.asarray(plan.offsets),
        jnp.asarray(plan.n_batches), jnp.asarray(plan.nfeat_local),
        jnp.asarray(plan.efeat_local), jnp.asarray(plan.shared_local))

    # --- reference loop ----------------------------------------------
    vg = jax.jit(jax.value_and_grad(step_loss, has_aux=True),
                 static_argnames="cfg")
    tables = [{"efeat": jnp.asarray(plan.efeat_local[k]),
               "nfeat": jnp.asarray(plan.nfeat_local[k])}
              for k in range(n_dev)]
    p_ref, o_ref = params, opt_state
    states = [init_state(cfg, plan.capacity) for _ in range(n_dev)]
    backups = [init_state(cfg, plan.capacity) for _ in range(n_dev)]
    losses_ref = np.zeros((n_dev, plan.steps), np.float32)
    for s in range(plan.steps):
        grads_all = []
        for k in range(n_dev):
            if s % int(plan.n_batches[k]) == 0:
                states[k] = init_state(cfg, plan.capacity)
            # Alg.2 wrap-around: this device's row of the flat real grid
            row = int(plan.offsets[k]) + s % int(plan.n_batches[k])
            batch = {key: jnp.asarray(v[row])
                     for key, v in plan.batches.items()}
            (loss, (states[k], _)), grads = vg(p_ref, states[k], batch,
                                               tables[k], cfg=cfg)
            losses_ref[k, s] = float(loss)
            grads_all.append(grads)
        gmean = jax.tree.map(lambda *gs: sum(gs) / n_dev, *grads_all)
        p_ref, o_ref = opt.apply(gmean, o_ref, p_ref)
        for k in range(n_dev):
            if (s + 1) % int(plan.n_batches[k]) == 0:
                backups[k] = states[k]
    # latest-timestamp shared sync on the backups
    S = plan.shared_local.shape[1]
    if S:
        last = np.stack([np.asarray(backups[k]["last"])[plan.shared_local[k]]
                         for k in range(n_dev)])           # (n_dev, S)
        win = last.argmax(0)
        for k in range(n_dev):
            mem = np.asarray(backups[k]["mem"]).copy()
            rows = np.stack([np.asarray(backups[w]["mem"])
                             [plan.shared_local[w, si]]
                             for si, w in enumerate(win)])
            mem[plan.shared_local[k]] = rows
            backups[k]["mem"] = mem

    np.testing.assert_allclose(np.asarray(losses_e), losses_ref, atol=1e-4)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4), p_e, p_ref)
    for k in range(n_dev):
        np.testing.assert_allclose(np.asarray(states_e["mem"][k]),
                                   np.asarray(backups[k]["mem"]), atol=1e-4)


def test_pac_train_unchanged_semantics():
    """pac_train end-to-end on the engine: losses drop, memories stay
    finite, and shared rows agree across devices after sync."""
    g = synthetic_tig("tiny", seed=1)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, 4, k=0.1)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    res = pac_train(train_g, part, cfg, num_devices=4, epochs=2, lr=2e-3,
                    shuffle_parts=False)
    per_epoch = res.mean_loss_per_epoch()
    assert np.isfinite(per_epoch).all()
    assert per_epoch[-1] < per_epoch[0] + 0.05
    plan = res.plan
    mem = res.memory_states["mem"]
    for si in range(plan.shared_local.shape[1]):
        rows = [mem[k, plan.shared_local[k, si]] for k in range(4)]
        for r in rows[1:]:
            np.testing.assert_allclose(r, rows[0], atol=1e-6)


def test_scan_epoch_pallas_interpret_matches_xla():
    """cfg.use_pallas routing inside the scanned step: the Pallas kernel
    bodies (interpret mode on CPU) must match the XLA fallback path."""
    cfg_x = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                      dim_node=16, num_neighbors=4, batch_size=32)
    cfg_p = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                      dim_node=16, num_neighbors=4, batch_size=32,
                      use_pallas=True, kernel_backend="interpret")
    g, stacked, tables_j, params, state = setup_single(cfg=cfg_x)
    # a short stream is enough to cover flush + attention inside the scan
    short = {k: jnp.asarray(v[:4]) for k, v in stacked.items()}
    opt = adamw(lr=1e-3, max_grad_norm=1.0)
    o0 = opt.init(params)
    outs = {}
    for name, cfg in (("xla", cfg_x), ("pallas", cfg_p)):
        p, o, s, losses = scan_train_epoch(
            params, o0, state, short, tables_j, cfg=cfg, opt=opt)
        outs[name] = (np.asarray(losses), np.asarray(s["mem"]))
    np.testing.assert_allclose(outs["pallas"][0], outs["xla"][0], atol=1e-4)
    np.testing.assert_allclose(outs["pallas"][1], outs["xla"][1], atol=1e-4)


def test_eval_program_cache_is_lru():
    """A hit must move the program to the back of the eviction order, so an
    alternating workload cycling through > max configs keeps its hot
    programs compiled (move-to-end-on-hit, evict-front)."""
    from repro.tig import engine

    saved, saved_max = engine._EVAL_PROGRAMS, engine._EVAL_PROGRAMS_MAX
    engine._EVAL_PROGRAMS = {}
    engine._EVAL_PROGRAMS_MAX = 3
    try:
        def cfg_for(d):
            return TIGConfig(flavor="tgn", dim=d, dim_time=8, dim_edge=16,
                             dim_node=16, num_neighbors=4, batch_size=8)

        f8 = make_eval_epoch(cfg_for(8))
        make_eval_epoch(cfg_for(16))
        make_eval_epoch(cfg_for(24))
        # hit cfg(8): it becomes most-recent, cfg(16) is now the LRU entry
        assert make_eval_epoch(cfg_for(8)) is f8
        make_eval_epoch(cfg_for(32))            # evicts cfg(16), not cfg(8)
        assert make_eval_epoch(cfg_for(8)) is f8
        keys_dims = [k[0][1] for k in engine._EVAL_PROGRAMS]
        assert 16 not in keys_dims and 8 in keys_dims
    finally:
        engine._EVAL_PROGRAMS = saved
        engine._EVAL_PROGRAMS_MAX = saved_max
