"""PAC distributed training tests (vmap simulation path on one device, plus
a subprocess shard_map equivalence check on 4 forced host devices)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train, plan_epoch
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import evaluate_params, time_scale_of


CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=50)


def setup_case(seed=0, num_parts=4, k=0.05, name="tiny"):
    g = synthetic_tig(name, seed=seed)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, num_parts, k=k)
    return g, train_g, part


def test_plan_epoch_shapes_and_schedule():
    g, train_g, part = setup_case()
    rng = np.random.default_rng(0)
    plan = plan_epoch(train_g, part.node_lists(), part.shared_nodes,
                      CFG, rng, time_scale=time_scale_of(train_g.t))
    n_dev = 4
    # transfer-minimal layout: flat grid of ONLY the real batches
    total_real = int(plan.n_batches.sum())
    assert plan.batches["src"].shape[0] == total_real
    assert plan.batches["src"].shape[1] == CFG.batch_size
    assert plan.offsets.shape == (n_dev,)
    np.testing.assert_array_equal(
        plan.offsets, np.concatenate([[0], np.cumsum(plan.n_batches)[:-1]]))
    assert plan.n_batches.max() == plan.steps
    assert (plan.edges_per_device > 0).all()
    # shared nodes present on all devices
    assert plan.shared_local.shape[0] == n_dev
    assert (plan.shared_local >= 0).all()
    # localized ids stay within capacity
    assert plan.batches["src"].max() < plan.capacity


def test_plan_epoch_host_replay_oracle_layout():
    """host_replay=True keeps the legacy replayed (N_dev, steps, ...) grid,
    row-for-row the wrap-around expansion of the flat plan."""
    g, train_g, part = setup_case()
    rng = np.random.default_rng(0)
    plan = plan_epoch(train_g, part.node_lists(), part.shared_nodes,
                      CFG, rng, time_scale=time_scale_of(train_g.t))
    rng = np.random.default_rng(0)
    old = plan_epoch(train_g, part.node_lists(), part.shared_nodes,
                     CFG, rng, time_scale=time_scale_of(train_g.t),
                     host_replay=True)
    assert old.host_replay and old.offsets is None
    assert old.batches["src"].shape[:2] == (4, old.steps)
    np.testing.assert_array_equal(old.n_batches, plan.n_batches)
    for key, v in old.batches.items():
        for k in range(4):
            rows = plan.offsets[k] + \
                np.arange(old.steps) % plan.n_batches[k]
            np.testing.assert_array_equal(v[k], plan.batches[key][rows])
    # the flat plan ships no more bytes than the replayed one
    assert plan.grid_bytes() <= old.grid_bytes()


def test_pac_train_loss_decreases_and_balanced():
    g, train_g, part = setup_case(name="small", num_parts=8)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=32,
                    dim_node=32, num_neighbors=4, batch_size=100)
    res = pac_train(train_g, part, cfg, num_devices=4, epochs=3, lr=2e-3)
    per_epoch = res.mean_loss_per_epoch()
    assert per_epoch[-1] < per_epoch[0]
    assert res.derived_speedup > 2.5  # balanced partitions -> near 4x
    assert np.isfinite(res.memory_states["mem"]).all()


def test_pac_trained_params_evaluate_reasonably():
    g, train_g, part = setup_case(name="small", num_parts=4)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=32,
                    dim_node=32, num_neighbors=4, batch_size=100)
    res = pac_train(train_g, part, cfg, num_devices=4, epochs=3, lr=2e-3)
    ev = evaluate_params(g, cfg, res.params)
    assert ev["test_ap"] > 0.6  # competitive, paper Tab.IV story


def test_pac_shared_node_memory_agrees_across_devices():
    g, train_g, part = setup_case(num_parts=4, k=0.1)
    res = pac_train(train_g, part, CFG, num_devices=4, epochs=1,
                    shuffle_parts=False)
    plan = res.plan
    if plan.shared_local.shape[1] == 0:
        pytest.skip("no shared nodes in this draw")
    mem = res.memory_states["mem"]
    for s in range(plan.shared_local.shape[1]):
        rows = [mem[k, plan.shared_local[k, s]] for k in range(4)]
        for r in rows[1:]:
            np.testing.assert_allclose(r, rows[0], atol=1e-6)


def test_pac_sync_modes_differ():
    g, train_g, part = setup_case(num_parts=4, k=0.1)
    r1 = pac_train(train_g, part, CFG, num_devices=4, epochs=1,
                   shuffle_parts=False, sync_mode="latest")
    r2 = pac_train(train_g, part, CFG, num_devices=4, epochs=1,
                   shuffle_parts=False, sync_mode="mean")
    if r1.plan.shared_local.shape[1] == 0:
        pytest.skip("no shared nodes")
    # params identical (sync happens after all grad updates)...
    for la, lb in zip(r1.losses, r2.losses):
        np.testing.assert_allclose(la, lb, atol=1e-6)
    # ...but synced memories differ between modes
    assert not np.allclose(r1.memory_states["mem"], r2.memory_states["mem"])


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from repro.core import sep_partition
    from repro.tig.data import synthetic_tig
    from repro.tig.graph import chronological_split
    from repro.tig.models import TIGConfig
    from repro.tig.distributed import pac_train

    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, 4, k=0.05)
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    mesh = jax.make_mesh((4,), ("part",))
    sm = pac_train(train_g, part, cfg, num_devices=4, epochs=1,
                   mesh=mesh, shuffle_parts=False)
    vm = pac_train(train_g, part, cfg, num_devices=4, epochs=1,
                   mesh=None, shuffle_parts=False)
    assert all(np.allclose(a, b, atol=1e-4)\n               for a, b in zip(sm.losses, vm.losses)), "losses diverge"
    d = jax.tree.map(lambda a, b: float(np.max(np.abs(a - b))),
                     sm.params, vm.params)
    m = max(jax.tree.leaves(d))
    assert m < 1e-3, f"params diverge: {m}"
    print("OK")
""")


def test_shard_map_equals_vmap_simulation():
    """The real SPMD path (4 forced host devices in a subprocess) must match
    the single-device vmap simulation bit-for-bit (up to reduction order)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout
