"""Dry-run machinery tests: input_specs coverage + one real 512-device
lower+compile in a subprocess (the full sweep is
``python -m repro.launch.dryrun --all --both-meshes``)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_long_500k_policy():
    """long_500k runs iff the arch is sub-quadratic (DESIGN.md §4)."""
    expected = {"rwkv6-1.6b", "hymba-1.5b", "starcoder2-3b"}
    for arch in list_archs():
        if arch == "speed-tig":
            continue
        cfg = get_config(arch)
        assert cfg.sub_quadratic == (arch in expected), arch


def test_input_specs_all_combos():
    """input_specs must produce a complete batch for every runnable
    (arch x shape) combination without touching devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    script = textwrap.dedent("""
        from repro.launch.dryrun import input_specs, LONG_OK
        from repro.configs import INPUT_SHAPES, get_config, list_archs
        n = 0
        for arch in list_archs():
            if arch == "speed-tig":
                continue
            cfg = get_config(arch)
            for shape in INPUT_SHAPES:
                if shape == "long_500k" and arch not in LONG_OK:
                    continue
                batch = input_specs(arch, shape)
                kind = INPUT_SHAPES[shape].kind
                if kind in ("train", "prefill"):
                    assert "tokens" in batch and (
                        kind == "prefill" or "targets" in batch)
                    if cfg.frontend == "vision":
                        assert "patches" in batch and "positions3" in batch
                    if cfg.enc_dec:
                        assert "frames" in batch
                else:
                    assert set(batch) == {"token", "pos"}
                n += 1
        assert n == 33, n
        print("SPECS_OK", n)
    """)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=300,
                          cwd=REPO, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SPECS_OK 33" in proc.stdout


@pytest.mark.slow
def test_dryrun_one_combo_512_devices():
    """End-to-end: lower + compile one real combination on the 512-chip
    multi-pod mesh (subprocess so the forced device count stays local)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    script = textwrap.dedent("""
        from repro.launch.dryrun import dryrun_one
        r = dryrun_one("seamless-m4t-medium", "decode_32k",
                       multi_pod=True, save=False, verbose=False)
        assert r["status"] == "ok", r
        assert r["chips"] == 512
        assert r["hlo_flops"] > 0 and r["collective_bytes"] >= 0
        print("DRYRUN_OK", r["dominant"])
    """)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          cwd=REPO, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "DRYRUN_OK" in proc.stdout
