"""Property tests: the chunk-vectorized SEP engine is bit-identical to the
per-edge reference pass (the parity oracle) — assignments, discards,
node masks, shared nodes, replication factor, and balance all match, for
every chunk size (including degenerate chunk_size=1) and both the
hub-restricted (SEP) and unrestricted (HDRF/Greedy) modes."""

import numpy as np
import pytest

from repro.core import (
    replication_factor,
    sep_partition,
    streaming_vertex_cut,
    streaming_vertex_cut_reference,
    temporal_centrality,
    top_k_hubs,
)

CHUNK_SIZES = [1, 7, 65536]


def random_stream(rng, n_lo=5, n_hi=200, e_hi=2000):
    n = int(rng.integers(n_lo, n_hi))
    e = int(rng.integers(1, e_hi))
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    t = np.sort(rng.uniform(0, 1e5, e))
    return src, dst, t, n


def assert_same_partition(a, b):
    np.testing.assert_array_equal(a.edge_part, b.edge_part)
    np.testing.assert_array_equal(a.node_masks, b.node_masks)
    np.testing.assert_array_equal(a.shared_nodes, b.shared_nodes)
    if a.hubs is None:
        assert b.hubs is None
    else:
        np.testing.assert_array_equal(a.hubs, b.hubs)
    # derived quantities (replication factor, discards, balance) follow
    # from the arrays above but are asserted explicitly per the spec
    assert replication_factor(a) == replication_factor(b)
    assert (a.edge_part < 0).sum() == (b.edge_part < 0).sum()
    np.testing.assert_array_equal(a.edge_counts(), b.edge_counts())


@pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
def test_chunked_equals_oracle_sep_modes(chunk_size):
    rng = np.random.default_rng(chunk_size)
    for trial in range(8):
        src, dst, t, n = random_stream(rng)
        num_parts = int(rng.choice([1, 2, 4, 8, 17]))
        k = float(rng.choice([0.0, 0.05, 0.3, 1.0]))
        cent = temporal_centrality(src, dst, t, n)
        hubs = top_k_hubs(cent, k)
        for h in (hubs, None):
            a = streaming_vertex_cut_reference(
                src, dst, n, num_parts, centrality=cent, hubs=h)
            b = streaming_vertex_cut(
                src, dst, n, num_parts, centrality=cent, hubs=h,
                chunk_size=chunk_size)
            assert_same_partition(a, b)


@pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
def test_chunked_equals_oracle_hyperparams(chunk_size):
    """lam outside (0, 1] and negative centrality disable the tiered fast
    path — the fallback must still match the oracle exactly."""
    rng = np.random.default_rng(100 + chunk_size)
    for lam in (0.0, 0.25, 1.0, 2.5):
        src, dst, t, n = random_stream(rng)
        cent = rng.normal(size=n)  # negative centralities
        hubs = top_k_hubs(np.abs(cent), 0.1)
        a = streaming_vertex_cut_reference(
            src, dst, n, 4, centrality=cent, hubs=hubs, lam=lam)
        b = streaming_vertex_cut(
            src, dst, n, 4, centrality=cent, hubs=hubs, lam=lam,
            chunk_size=chunk_size)
        assert_same_partition(a, b)


def test_sep_partition_default_engine_matches_reference():
    rng = np.random.default_rng(7)
    src, dst, t, n = random_stream(rng, e_hi=4000)
    for k in (0.0, 0.05, 1.0):
        a = sep_partition(src, dst, t, n, 4, k=k, chunk_size=0)
        b = sep_partition(src, dst, t, n, 4, k=k)          # chunked default
        c = sep_partition(src, dst, t, n, 4, k=k, chunk_size=64)
        assert_same_partition(a, b)
        assert_same_partition(a, c)


def test_shared_to_all_false_matches():
    rng = np.random.default_rng(11)
    src, dst, t, n = random_stream(rng)
    a = sep_partition(src, dst, t, n, 8, k=0.2, shared_to_all=False,
                      chunk_size=0)
    b = sep_partition(src, dst, t, n, 8, k=0.2, shared_to_all=False,
                      chunk_size=37)
    assert_same_partition(a, b)


def test_empty_and_tiny_streams():
    for e in (0, 1, 2):
        src = np.arange(e) % 3
        dst = (np.arange(e) + 1) % 3
        t = np.arange(e, dtype=float)
        a = sep_partition(src, dst, t, 3, 4, k=0.5, chunk_size=0)
        b = sep_partition(src, dst, t, 3, 4, k=0.5, chunk_size=1)
        assert_same_partition(a, b)


def test_chunk_boundary_independence():
    """The result must not depend on where block boundaries fall."""
    rng = np.random.default_rng(23)
    src, dst, t, n = random_stream(rng, e_hi=3000)
    base = sep_partition(src, dst, t, n, 4, k=0.05, chunk_size=0)
    for cs in (1, 2, 3, 13, 100, 999, 10**6):
        got = sep_partition(src, dst, t, n, 4, k=0.05, chunk_size=cs)
        assert_same_partition(base, got)
