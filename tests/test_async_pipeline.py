"""Async epoch pipeline parity: the overlapped boundary and any pipeline
depth must be bit-identical to the serial oracle for all three trainers.

PR 9 makes the epoch boundary asynchronous (scan-only program + separable
Alg.2 sync dispatch + deferred loss drain in ``pac_train``, depth-
configurable ``EpochPrefetcher`` everywhere).  None of it may change a
single bit: the serial fused path stays the oracle, and these tests
assert exact equality of losses, params, memory, and metrics.  The
2-process CPU-cluster case (overlap vs serial across real processes)
lives in ``tests/test_pac_multihost.py``.
"""

import numpy as np
import jax
import pytest

from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.stream import write_graph_shards
from repro.tig.train import train_single, train_sharded

CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=50)


def _tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


def _losses_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _pac_case(num_parts=8):
    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         num_parts, k=0.05)
    return g, train_g, part


@pytest.mark.parametrize("plan", ["device", "host"])
def test_pac_overlap_matches_serial(plan):
    """Scan-only + dispatched sync + deferred loss drain == the fused
    serial oracle, bit for bit, for both plan modes (vmap layout)."""
    g, train_g, part = _pac_case()
    kw = dict(num_devices=4, epochs=2, seed=0, shuffle_parts=True,
              plan=plan)
    ser = pac_train(train_g, part, CFG, epoch_boundary="serial", **kw)
    ovl = pac_train(train_g, part, CFG, epoch_boundary="overlap", **kw)
    _losses_equal(ser.losses, ovl.losses)
    _tree_equal(ser.params, ovl.params)
    _tree_equal(ser.memory_states, ovl.memory_states)


def test_pac_depth_and_prefetch_off_match():
    """depth>1, depth=1, and the fully-serial prefetch=False loop all
    produce identical results — including downstream protocol metrics
    from the synchronized memories (eval_graph path)."""
    g, train_g, part = _pac_case()
    kw = dict(num_devices=4, epochs=2, seed=0, shuffle_parts=True,
              plan="device", eval_graph=g)
    base = pac_train(train_g, part, CFG, epoch_boundary="serial",
                     prefetch=False, **kw)
    d1 = pac_train(train_g, part, CFG, epoch_boundary="overlap",
                   depth=1, **kw)
    d3 = pac_train(train_g, part, CFG, epoch_boundary="overlap",
                   depth=3, **kw)
    for res in (d1, d3):
        _losses_equal(base.losses, res.losses)
        _tree_equal(base.params, res.params)
        _tree_equal(base.memory_states, res.memory_states)
        assert set(base.metrics) == set(res.metrics)
        for k in base.metrics:
            x, y = base.metrics[k], res.metrics[k]
            assert (np.isnan(x) and np.isnan(y)) or x == y, \
                f"{k}: {x} != {y}"


def test_train_single_depths_match():
    g = synthetic_tig("tiny", seed=13)
    base = train_single(g, CFG, epochs=2, prefetch=False)
    d1 = train_single(g, CFG, epochs=2, depth=1)
    d3 = train_single(g, CFG, epochs=2, depth=3)
    for res in (d1, d3):
        assert base.losses == res.losses
        assert base.val_ap == res.val_ap
        assert base.test_ap == res.test_ap
        assert base.test_ap_inductive == res.test_ap_inductive
        _tree_equal(base.params, res.params)
        _tree_equal(base.state, res.state)


def test_train_sharded_protocol_depths_match(tmp_path):
    g = synthetic_tig("tiny", seed=7)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=500)
    base = train_sharded(sh, CFG, epochs=2, protocol=True, patience=2,
                         prefetch=False,
                         ckpt_dir=str(tmp_path / "ck_base"))
    d2 = train_sharded(sh, CFG, epochs=2, protocol=True, patience=2,
                       depth=2, ckpt_dir=str(tmp_path / "ck_d2"))
    assert base.losses == d2.losses
    assert base.val_curve == d2.val_curve
    assert base.best_epoch == d2.best_epoch
    _tree_equal(base.params, d2.params)
    _tree_equal(base.state, d2.state)
    assert set(base.metrics) == set(d2.metrics)
    for k in base.metrics:
        x, y = base.metrics[k], d2.metrics[k]
        assert (np.isnan(x) and np.isnan(y)) or x == y, f"{k}: {x} != {y}"


# ---------------------------------------------- prefetcher failure modes

def _harnessed_prefetcher(spec, *, depth, epochs=6, stage=True):
    """An ``EpochPrefetcher`` whose build/stage callbacks run under the
    deterministic fault harness (``repro.faults``): the worker thread is
    the component under test, the injector decides where it dies."""
    from repro.faults import FaultInjector
    from repro.tig.stream import EpochPrefetcher

    inj = FaultInjector.parse(spec, process_index=0)
    built = []

    def build(ep):
        inj.fire("prefetch_worker", epoch=ep)
        built.append(ep)
        return {"epoch": ep}

    def to_device(plan):
        inj.fire("staging_oom")
        return dict(plan, staged=True)

    pf = EpochPrefetcher(build, epochs,
                         to_device=to_device if stage else None,
                         depth=depth)
    return pf, built


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_prefetcher_worker_fault_surfaces_at_get_and_poisons(depth):
    """An injected build failure must surface at the corresponding
    ``get`` — earlier epochs stay intact — and poison the pipeline: no
    further epoch is submitted after the failing one."""
    from repro.faults import InjectedFault

    pf, built = _harnessed_prefetcher("prefetch_worker@epoch=2",
                                      depth=depth)
    with pf:
        assert pf.get(0)["epoch"] == 0
        assert pf.get(1)["staged"]
        with pytest.raises(InjectedFault):
            pf.get(2)
    assert 2 not in built           # the faulted build produced nothing
    assert all(ep < 2 + depth for ep in built)  # nothing submitted past it


@pytest.mark.parametrize("depth", [1, 3])
def test_prefetcher_staging_fault_and_bounded_close(depth):
    """An injected staging OOM surfaces at ``get`` with the worker's slot
    released: ``close`` after the failure must join in bounded time (the
    regression here was a worker parked on the staging semaphore)."""
    pf, _built = _harnessed_prefetcher("staging_oom@at=2", depth=depth)
    with pf:
        assert pf.get(0)["staged"]
        with pytest.raises(MemoryError):
            pf.get(1)
    assert pf._worker is None       # close() actually joined the thread


def test_prefetcher_fault_then_fresh_pipeline_recovers():
    """The elastic contract at the pipeline level: after a poisoned
    prefetcher is closed, a FRESH prefetcher over the remaining epochs
    (what a restarted trainer builds) produces the same plans an
    undisturbed run would."""
    from repro.faults import InjectedFault

    pf, _ = _harnessed_prefetcher("prefetch_worker@epoch=1", depth=2)
    with pf:
        assert pf.get(0)["epoch"] == 0
        with pytest.raises(InjectedFault):
            pf.get(1)
    pf2, built2 = _harnessed_prefetcher("", depth=2)
    with pf2:
        got = [pf2.get(ep)["epoch"] for ep in range(1, 6)]
    assert got == list(range(1, 6))
    assert built2 == list(range(1, 6))  # finished epochs are never rebuilt


def test_pac_train_epoch_zero_kill_leaves_resumable_ckpt(tmp_path):
    """A staging fault AFTER the first checkpoint leaves a directory the
    next ``pac_train`` call resumes from — the single-process analogue of
    the 2-process host-kill case in ``test_elastic.py``."""
    from repro.faults import FaultInjector

    g, train_g, part = _pac_case()
    kw = dict(num_devices=4, seed=0, shuffle_parts=True, plan="device")
    d = str(tmp_path / "ckpt")

    full = pac_train(train_g, part, CFG, epochs=2, **kw)
    # staging call 3 = epoch 2's plan (epochs 0/1 stage as calls 1/2),
    # so the crash lands after epoch 1's checkpoint is on disk
    with pytest.raises(MemoryError):
        pac_train(train_g, part, CFG, epochs=3, ckpt_dir=d, ckpt_every=1,
                  faults=FaultInjector.parse("staging_oom@at=3",
                                             process_index=0), **kw)
    res = pac_train(train_g, part, CFG, epochs=2, ckpt_dir=d, resume=True,
                    **kw)
    assert res.losses == []          # everything up to epochs=2 was done
    _tree_equal(full.params, res.params)
