"""Async epoch pipeline parity: the overlapped boundary and any pipeline
depth must be bit-identical to the serial oracle for all three trainers.

PR 9 makes the epoch boundary asynchronous (scan-only program + separable
Alg.2 sync dispatch + deferred loss drain in ``pac_train``, depth-
configurable ``EpochPrefetcher`` everywhere).  None of it may change a
single bit: the serial fused path stays the oracle, and these tests
assert exact equality of losses, params, memory, and metrics.  The
2-process CPU-cluster case (overlap vs serial across real processes)
lives in ``tests/test_pac_multihost.py``.
"""

import numpy as np
import jax
import pytest

from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.stream import write_graph_shards
from repro.tig.train import train_single, train_sharded

CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=50)


def _tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


def _losses_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _pac_case(num_parts=8):
    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         num_parts, k=0.05)
    return g, train_g, part


@pytest.mark.parametrize("plan", ["device", "host"])
def test_pac_overlap_matches_serial(plan):
    """Scan-only + dispatched sync + deferred loss drain == the fused
    serial oracle, bit for bit, for both plan modes (vmap layout)."""
    g, train_g, part = _pac_case()
    kw = dict(num_devices=4, epochs=2, seed=0, shuffle_parts=True,
              plan=plan)
    ser = pac_train(train_g, part, CFG, epoch_boundary="serial", **kw)
    ovl = pac_train(train_g, part, CFG, epoch_boundary="overlap", **kw)
    _losses_equal(ser.losses, ovl.losses)
    _tree_equal(ser.params, ovl.params)
    _tree_equal(ser.memory_states, ovl.memory_states)


def test_pac_depth_and_prefetch_off_match():
    """depth>1, depth=1, and the fully-serial prefetch=False loop all
    produce identical results — including downstream protocol metrics
    from the synchronized memories (eval_graph path)."""
    g, train_g, part = _pac_case()
    kw = dict(num_devices=4, epochs=2, seed=0, shuffle_parts=True,
              plan="device", eval_graph=g)
    base = pac_train(train_g, part, CFG, epoch_boundary="serial",
                     prefetch=False, **kw)
    d1 = pac_train(train_g, part, CFG, epoch_boundary="overlap",
                   depth=1, **kw)
    d3 = pac_train(train_g, part, CFG, epoch_boundary="overlap",
                   depth=3, **kw)
    for res in (d1, d3):
        _losses_equal(base.losses, res.losses)
        _tree_equal(base.params, res.params)
        _tree_equal(base.memory_states, res.memory_states)
        assert set(base.metrics) == set(res.metrics)
        for k in base.metrics:
            x, y = base.metrics[k], res.metrics[k]
            assert (np.isnan(x) and np.isnan(y)) or x == y, \
                f"{k}: {x} != {y}"


def test_train_single_depths_match():
    g = synthetic_tig("tiny", seed=13)
    base = train_single(g, CFG, epochs=2, prefetch=False)
    d1 = train_single(g, CFG, epochs=2, depth=1)
    d3 = train_single(g, CFG, epochs=2, depth=3)
    for res in (d1, d3):
        assert base.losses == res.losses
        assert base.val_ap == res.val_ap
        assert base.test_ap == res.test_ap
        assert base.test_ap_inductive == res.test_ap_inductive
        _tree_equal(base.params, res.params)
        _tree_equal(base.state, res.state)


def test_train_sharded_protocol_depths_match(tmp_path):
    g = synthetic_tig("tiny", seed=7)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=500)
    base = train_sharded(sh, CFG, epochs=2, protocol=True, patience=2,
                         prefetch=False,
                         ckpt_dir=str(tmp_path / "ck_base"))
    d2 = train_sharded(sh, CFG, epochs=2, protocol=True, patience=2,
                       depth=2, ckpt_dir=str(tmp_path / "ck_d2"))
    assert base.losses == d2.losses
    assert base.val_curve == d2.val_curve
    assert base.best_epoch == d2.best_epoch
    _tree_equal(base.params, d2.params)
    _tree_equal(base.state, d2.state)
    assert set(base.metrics) == set(d2.metrics)
    for k in base.metrics:
        x, y = base.metrics[k], d2.metrics[k]
        assert (np.isnan(x) and np.isnan(y)) or x == y, f"{k}: {x} != {y}"
