"""Tests for the chunked streaming data plane (repro.tig.stream):
shard roundtrips, out-of-core JODIE ingestion, chunked device staging,
the epoch prefetcher, and the synthetic-generator rewire parity."""

import os

import numpy as np
import pytest

from repro.tig.batching import make_tables
from repro.tig.data import (
    _rewire_repeats,
    _rewire_repeats_reference,
    load_jodie_csv,
    synthetic_tig,
)
from repro.tig.stream import (
    EpochPrefetcher,
    ShardedStream,
    _parse_jodie_rows,
    _parse_jodie_rows_fast,
    iter_jodie_blocks,
    stage_device_tables,
    write_graph_shards,
    write_jodie_shards,
)

JODIE_CSV = """user_id,item_id,timestamp,state_label,f0,f1
0,0,1,0,0.5,1.5
1,0,2,0,0.25
2,1,3,1
1,2,4,,0.75,2.5,9.9
0,1,10,0,1.0,2.0,3.0
"""

NO_FEAT_CSV = """user_id,item_id,timestamp,state_label
0,0,1,0
1,1,2.5,1
0,1,3,0
"""


# ------------------------------------------------------------- shard format

def test_graph_shard_roundtrip(tmp_path):
    g = synthetic_tig("tiny", seed=3)
    sh = write_graph_shards(g, str(tmp_path / "tiny"), shard_edges=257)
    assert sh.num_shards == -(-g.num_edges // 257)
    assert sh.num_edges == g.num_edges
    re = ShardedStream.open(str(tmp_path / "tiny"))
    g2 = re.as_graph()
    np.testing.assert_array_equal(g2.src, g.src)
    np.testing.assert_array_equal(g2.dst, g.dst)
    np.testing.assert_array_equal(g2.t, g.t)
    np.testing.assert_array_equal(g2.labels, g.labels)
    np.testing.assert_allclose(g2.edge_feat, g.edge_feat)
    assert g2.num_nodes == g.num_nodes
    # columns and chunks are consistent with the arrays
    np.testing.assert_array_equal(re.column("src"), g.src)
    chunks = list(re.edge_chunks())
    assert sum(len(c[0]) for c in chunks) == g.num_edges
    np.testing.assert_array_equal(
        np.concatenate([c[3] for c in chunks]), np.arange(g.num_edges))
    # shard loads are memory-mapped, not copies
    assert isinstance(re.load(0, "efeat"), np.memmap)


def test_open_rejects_non_shard_dir(tmp_path):
    os.makedirs(tmp_path / "x", exist_ok=True)
    with open(tmp_path / "x" / "meta.json", "w") as f:
        f.write('{"format": "something-else"}')
    with pytest.raises(ValueError):
        ShardedStream.open(str(tmp_path / "x"))


# --------------------------------------------------------- JODIE ingestion

def test_load_jodie_csv_ragged_and_int_timestamps(tmp_path):
    """Regression: ragged feature rows, empty labels, and integer
    timestamps must parse — and never produce an (E, 0) feature slice."""
    p = tmp_path / "ml_x.csv"
    p.write_text(JODIE_CSV)
    g = load_jodie_csv(str(p), d_n=8)
    assert g.num_edges == 5
    # width = widest data row (3 features); short rows zero-padded
    assert g.edge_feat.shape == (5, 3)
    np.testing.assert_allclose(
        g.edge_feat[:4],
        [[0.5, 1.5, 0.0], [0.25, 0.0, 0.0], [0.0, 0.0, 0.0],
         [0.75, 2.5, 9.9]])
    assert g.labels.tolist() == [0, 0, 1, 0, 0]
    assert g.t.tolist() == [1.0, 2.0, 3.0, 4.0, 10.0]
    # bipartite offset: items live after the 3 users
    assert g.src.tolist() == [0, 1, 2, 1, 0]
    assert g.dst.tolist() == [3, 3, 4, 5, 4]
    assert g.node_feat.shape == (6, 8)


def test_load_jodie_csv_no_feature_columns(tmp_path):
    p = tmp_path / "ml_nofeat.csv"
    p.write_text(NO_FEAT_CSV)
    g = load_jodie_csv(str(p))
    assert g.edge_feat.shape == (3, 1)          # zero column, never (E, 0)
    np.testing.assert_array_equal(g.edge_feat, 0.0)
    assert g.t.tolist() == [1.0, 2.5, 3.0]


def test_write_jodie_shards_matches_in_memory_loader(tmp_path):
    p = tmp_path / "ml_x.csv"
    p.write_text(JODIE_CSV)
    sh = write_jodie_shards(str(p), str(tmp_path / "shards"), shard_edges=2)
    assert sh.num_shards == 3                   # 2 + 2 + 1 rows
    g_mem = load_jodie_csv(str(p), d_n=sh.dim_node)
    g_sh = sh.as_graph()
    np.testing.assert_array_equal(g_sh.src, g_mem.src)
    np.testing.assert_array_equal(g_sh.dst, g_mem.dst)
    np.testing.assert_array_equal(g_sh.t, g_mem.t)
    np.testing.assert_array_equal(g_sh.labels, g_mem.labels)
    np.testing.assert_allclose(g_sh.edge_feat, g_mem.edge_feat)
    assert g_sh.num_nodes == g_mem.num_nodes


def test_write_jodie_shards_rejects_unsorted(tmp_path):
    p = tmp_path / "ml_bad.csv"
    p.write_text("u,i,ts,l\n0,0,5,0\n1,1,4,0\n")
    with pytest.raises(ValueError, match="non-decreasing"):
        write_jodie_shards(str(p), str(tmp_path / "bad"))


def test_iter_jodie_blocks_block_sizes(tmp_path):
    p = tmp_path / "ml_x.csv"
    p.write_text(JODIE_CSV)
    blocks = list(iter_jodie_blocks(str(p), block_rows=2))
    assert [len(b[0]) for b in blocks] == [2, 2, 1]


# ----------------------------------------------- vectorized block parser

CLEAN_CSV = "user_id,item_id,timestamp,state_label,f0,f1\n" + "".join(
    f"{u},{u % 3},{ts},{ts % 2},{0.5 * u},{1.5 * ts}\n"
    for ts, u in enumerate(range(40)))


def test_fast_block_parser_matches_loop_on_clean_rows(tmp_path):
    p = tmp_path / "ml_clean.csv"
    p.write_text(CLEAN_CSV)
    fast = list(iter_jodie_blocks(str(p), block_rows=16, fast=True))
    slow = list(iter_jodie_blocks(str(p), block_rows=16, fast=False))
    assert len(fast) == len(slow) == 3
    for bf, bs in zip(fast, slow):
        for cf, cs in zip(bf, bs):
            np.testing.assert_array_equal(cf, cs)
            assert cf.dtype == cs.dtype
    # the clean block really takes the vectorized path
    lines = CLEAN_CSV.splitlines(keepends=True)[1:]
    assert _parse_jodie_rows_fast(lines, 2) is not None


def test_fast_parser_falls_back_on_ragged_blocks(tmp_path):
    # JODIE_CSV has ragged feature rows + an empty label -> the vectorized
    # parser must bow out (None) and the block reader must produce results
    # identical to the per-line loop.
    lines = JODIE_CSV.splitlines(keepends=True)[1:]
    assert _parse_jodie_rows_fast(lines, 3) is None
    p = tmp_path / "ml_x.csv"
    p.write_text(JODIE_CSV)
    fast = list(iter_jodie_blocks(str(p), fast=True))
    slow = list(iter_jodie_blocks(str(p), fast=False))
    for bf, bs in zip(fast, slow):
        for cf, cs in zip(bf, bs):
            np.testing.assert_array_equal(cf, cs)


def test_fast_parser_rejects_nonfinite_id_and_label_fields():
    # nan/inf in int-bound columns would cast to INT64_MIN; the fast path
    # must bow out so the per-line parser raises its proper diagnostic
    assert _parse_jodie_rows_fast(["nan,1,2.0,0,0.5\n"], 1) is None
    assert _parse_jodie_rows_fast(["0,inf,2.0,0,0.5\n"], 1) is None
    assert _parse_jodie_rows_fast(["0,1,2.0,nan,0.5\n"], 1) is None
    # nan in float columns (timestamp/features) is fine for both parsers
    ok = _parse_jodie_rows_fast(["0,1,nan,0,nan\n"], 1)
    assert ok is not None and np.isnan(ok[2][0]) and np.isnan(ok[4][0, 0])


def test_fast_parser_pads_missing_feature_width():
    # uniform 4-column rows but sniffed width 3: fast path must zero-pad
    lines = ["0,1,2,1\n", "1,2,3,0\n"]
    fast = _parse_jodie_rows_fast(lines, 3)
    slow = _parse_jodie_rows(lines, 3)
    assert fast is not None
    for cf, cs in zip(fast, slow):
        np.testing.assert_array_equal(cf, cs)


# --------------------------------------------------------- device staging

def test_stage_device_tables_matches_make_tables(tmp_path):
    g = synthetic_tig("tiny", seed=5)
    sh = write_graph_shards(g, str(tmp_path / "s"), shard_edges=123)
    staged = stage_device_tables(sh)
    ref = make_tables(g.edge_feat, np.zeros_like(g.node_feat))
    np.testing.assert_allclose(np.asarray(staged["efeat"]), ref["efeat"],
                               atol=0)
    assert staged["nfeat"].shape == (g.num_nodes + 1, g.dim_node)
    np.testing.assert_array_equal(np.asarray(staged["nfeat"]), 0.0)


# ------------------------------------------------------------- prefetcher

def test_prefetcher_order_and_results():
    built = []

    def build(ep):
        built.append(ep)
        return ep * 10

    pf = EpochPrefetcher(build, 4, to_device=lambda x: x + 1)
    got = [pf.get(ep) for ep in range(4)]
    assert got == [1, 11, 21, 31]
    assert built == [0, 1, 2, 3]                # serial submission order


def test_prefetcher_disabled_inline():
    pf = EpochPrefetcher(lambda ep: ep, 3, enabled=False)
    assert [pf.get(e) for e in range(3)] == [0, 1, 2]


def test_prefetcher_close_detaches_pipeline():
    pf = EpochPrefetcher(lambda ep: ep, 5)
    assert pf.get(0) == 0            # submits epoch 1 in flight
    pf.close()                       # early stop: drop pending plans
    assert pf._futures == {} and pf._worker is None


def test_prefetcher_propagates_exceptions():
    def build(ep):
        if ep == 1:
            raise RuntimeError("boom")
        return ep

    pf = EpochPrefetcher(build, 3)
    assert pf.get(0) == 0
    with pytest.raises(RuntimeError, match="boom"):
        pf.get(1)


def test_prefetcher_single_persistent_worker():
    """All plans are built by ONE worker thread (not one per epoch), and
    they build in submission order at any depth."""
    import threading

    tids, built = [], []

    def build(ep):
        tids.append(threading.get_ident())
        built.append(ep)
        return ep

    with EpochPrefetcher(build, 6, depth=3) as pf:
        got = [pf.get(e) for e in range(6)]
    assert got == list(range(6))
    assert built == list(range(6))              # in-order at depth 3
    assert len(set(tids)) == 1                  # one persistent worker
    assert tids[0] != threading.get_ident()     # ... and not this thread


def test_prefetcher_depth_gt1_matches_depth1():
    for depth in (1, 2, 4):
        with EpochPrefetcher(lambda ep: ep * 7, 5,
                             to_device=lambda x: x + 1, depth=depth) as pf:
            assert [pf.get(e) for e in range(5)] == \
                [e * 7 + 1 for e in range(5)]


def test_prefetcher_depth0_is_inline():
    built = []

    def build(ep):
        built.append(ep)
        return ep

    with EpochPrefetcher(build, 3, depth=0) as pf:
        assert pf._worker is None
        assert [pf.get(e) for e in range(3)] == [0, 1, 2]
        assert pf._worker is None               # never spawned a thread
    with pytest.raises(ValueError, match="depth"):
        EpochPrefetcher(build, 3, depth=-1)


def test_prefetcher_exception_at_get_cancels_pipeline():
    """A build error surfaces at get() of that epoch and poisons the rest
    of the pipeline (no half-built plans leak; close() stays bounded)."""
    def build(ep):
        if ep == 1:
            raise RuntimeError("boom")
        return ep

    with EpochPrefetcher(build, 6, depth=4) as pf:
        assert pf.get(0) == 0
        with pytest.raises(RuntimeError, match="boom"):
            pf.get(1)
        assert pf._futures == {}                # pending plans dropped


def test_prefetcher_early_close_with_parked_worker():
    """close() joins in bounded time even when the worker is parked on a
    full device-staging slot (the patience-early-stop path)."""
    import time

    staged = []

    def to_device(x):
        staged.append(x)
        return x

    with EpochPrefetcher(lambda ep: ep, 10, to_device=to_device,
                         depth=4) as pf:
        assert pf.get(0) == 0
        # give the worker time to build ahead and park on the single
        # staging slot (epoch 1 staged and unclaimed, epoch 2 waiting)
        deadline = time.monotonic() + 5.0
        while len(staged) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        t0 = time.monotonic()
        pf.close()
        assert time.monotonic() - t0 < 5.0      # bounded join
        assert pf._worker is None and pf._futures == {}
    # plans past the close must never have been device-staged in the
    # background after close() returned
    n_after = len(staged)
    time.sleep(0.05)
    assert len(staged) == n_after


# ------------------------------------------------- synthetic rewire parity

def test_rewire_repeats_bit_identical():
    rng = np.random.default_rng(0)
    for _ in range(20):
        ne = int(rng.integers(1, 3000))
        nu = int(rng.integers(1, 60))
        users = rng.integers(0, nu, ne)
        items = rng.integers(0, 500, ne)
        repeat = rng.random(ne) < rng.random()
        ref = _rewire_repeats_reference(users, items.copy(), repeat)
        got = _rewire_repeats(users, items, repeat)
        np.testing.assert_array_equal(ref, got)


def test_rewire_repeats_edge_cases():
    empty = _rewire_repeats(np.zeros(0, np.int64), np.zeros(0, np.int64),
                            np.zeros(0, bool))
    assert len(empty) == 0
    # all repeats: everything sticks to the user's first item
    users = np.zeros(5, np.int64)
    items = np.arange(5)
    out = _rewire_repeats(users, items, np.ones(5, bool))
    np.testing.assert_array_equal(out, np.zeros(5))


def test_write_jodie_shards_without_label_column(tmp_path):
    """Regression: a 3-column export must not fabricate all-zero labels."""
    p = tmp_path / "ml_min.csv"
    p.write_text("user_id,item_id,timestamp\n0,0,1\n1,0,2\n0,1,3\n")
    sh = write_jodie_shards(str(p), str(tmp_path / "min"))
    assert not sh.has_labels
    assert sh.as_graph().labels is None
