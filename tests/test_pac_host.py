"""Tests for PAC host-side logic: shuffle-combine, cycle schedule, memory sync."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_subgraph,
    cycle_schedule,
    derived_speedup,
    make_local_indices,
    sep_partition,
    shuffle_combine,
    sync_shared_memory,
)


def graph(seed=0, n=200, e=2000):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    t = np.sort(rng.uniform(0, 1, e))
    return src, dst, t, n


# ------------------------------------------------------------ shuffle-combine

def test_shuffle_combine_partition_of_parts():
    rng = np.random.default_rng(0)
    parts = [np.array([i * 10 + j for j in range(10)]) for i in range(8)]
    combined = shuffle_combine(parts, 4, rng)
    assert len(combined) == 4
    allnodes = np.sort(np.concatenate(combined))
    np.testing.assert_array_equal(allnodes, np.arange(80))


def test_shuffle_combine_requires_divisibility():
    with pytest.raises(ValueError):
        shuffle_combine([np.arange(3)] * 7, 4, np.random.default_rng(0))


def test_shuffle_combine_recovers_deleted_edges():
    """Edges dropped between small parts reappear when those parts merge."""
    src, dst, t, n = graph()
    res = sep_partition(src, dst, t, n, 8, k=0.0)
    kept_small = set(np.nonzero(res.edge_part >= 0)[0])
    node_lists = res.node_lists()
    rng = np.random.default_rng(1)
    recovered_any = False
    for _ in range(5):
        combined = shuffle_combine(node_lists, 4, rng)
        kept_comb = set()
        for nodes in combined:
            kept_comb |= set(build_subgraph(src, dst, nodes, n))
        # merging can only ADD edges relative to the 8-way split
        assert kept_small <= kept_comb
        if len(kept_comb) > len(kept_small):
            recovered_any = True
    assert recovered_any


def test_build_subgraph_both_endpoints():
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 0])
    nodes = np.array([0, 1, 2])
    idx = build_subgraph(src, dst, nodes, 4)
    np.testing.assert_array_equal(idx, [0, 1])


# ------------------------------------------------------------ local indices

def test_make_local_indices_padding_and_roundtrip():
    lists = [np.array([5, 1, 9]), np.array([0, 2, 3, 7, 8])]
    idx = make_local_indices(lists, 10)
    assert all(li.capacity == 5 for li in idx)
    assert idx[0].num_real == 3
    # globals sorted, padded with -1
    np.testing.assert_array_equal(idx[0].globals_, [1, 5, 9, -1, -1])
    # roundtrip: to_local of member nodes maps into globals_
    for li in idx:
        real = li.globals_[: li.num_real]
        np.testing.assert_array_equal(li.globals_[li.to_local[real]], real)
    # non-members are -1
    assert idx[0].to_local[0] == -1


# ------------------------------------------------------------ cycle schedule

def test_cycle_schedule_wraparound():
    sched = cycle_schedule([100, 40, 400, 10], batch_size=10)
    np.testing.assert_array_equal(sched.batches, [10, 4, 40, 1])
    assert sched.steps_per_epoch == 40
    # device 1 wraps: step 4 re-reads its batch 0
    np.testing.assert_array_equal(sched.batch_index(4), [4, 0, 4, 0])
    # cycle ends exactly at multiples of its batch count
    ends = np.stack([sched.is_cycle_end(s) for s in range(40)])
    np.testing.assert_array_equal(ends.sum(0), [4, 10, 1, 40])
    # final step ends every device's cycle only if divisible
    np.testing.assert_array_equal(sched.is_cycle_end(39), [True] * 4)


def test_cycle_schedule_every_batch_visited():
    sched = cycle_schedule([35, 17], batch_size=5)
    steps = sched.steps_per_epoch
    for dev, nb in enumerate(sched.batches):
        seen = {int(sched.batch_index(s)[dev]) for s in range(steps)}
        assert seen == set(range(nb))  # Alg.2: at least one full traversal


def test_derived_speedup():
    assert derived_speedup([100, 100, 100, 100]) == pytest.approx(4.0)
    assert derived_speedup([400, 0, 0, 0]) == pytest.approx(1.0)
    assert derived_speedup([200, 100, 50, 50]) == pytest.approx(2.0)


# ------------------------------------------------------------ memory sync

def test_sync_shared_memory_latest():
    n_dev, cap, d, s = 3, 6, 4, 2
    rng = np.random.default_rng(0)
    mem = rng.normal(size=(n_dev, cap, d))
    last = np.zeros((n_dev, cap))
    shared_local = np.array([[0, 1], [2, 3], [4, 5]])
    # device 1 has the freshest copy of shared node 0; device 2 of node 1
    last[1, 2] = 10.0
    last[2, 5] = 7.0
    out = sync_shared_memory(mem, last, shared_local, mode="latest")
    for k in range(n_dev):
        np.testing.assert_allclose(out[k, shared_local[k, 0]], mem[1, 2])
        np.testing.assert_allclose(out[k, shared_local[k, 1]], mem[2, 5])
    # non-shared rows untouched
    untouched = np.setdiff1d(np.arange(cap), shared_local[0])
    np.testing.assert_allclose(out[0, untouched], mem[0, untouched])


def test_sync_shared_memory_mean():
    n_dev, cap, d = 2, 3, 2
    mem = np.arange(n_dev * cap * d, dtype=float).reshape(n_dev, cap, d)
    last = np.zeros((n_dev, cap))
    shared_local = np.array([[1], [0]])
    out = sync_shared_memory(mem, last, shared_local, mode="mean")
    expect = (mem[0, 1] + mem[1, 0]) / 2
    np.testing.assert_allclose(out[0, 1], expect)
    np.testing.assert_allclose(out[1, 0], expect)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n_dev=st.integers(2, 6),
       s=st.integers(0, 4))
def test_sync_latest_idempotent_and_agreeing(seed, n_dev, s):
    rng = np.random.default_rng(seed)
    cap, d = max(s, 1) + 3, 5
    mem = rng.normal(size=(n_dev, cap, d))
    last = rng.uniform(size=(n_dev, cap))
    shared_local = np.stack(
        [rng.choice(cap, size=s, replace=False) for _ in range(n_dev)]
    ).astype(np.int64) if s else np.zeros((n_dev, 0), dtype=np.int64)
    out = sync_shared_memory(mem, last, shared_local, mode="latest")
    # all devices agree on shared rows
    for si in range(s):
        ref = out[0, shared_local[0, si]]
        for k in range(1, n_dev):
            np.testing.assert_allclose(out[k, shared_local[k, si]], ref)
    # idempotent (same last-update table)
    out2 = sync_shared_memory(out, last, shared_local, mode="latest")
    np.testing.assert_allclose(out2, out)
