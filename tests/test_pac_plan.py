"""Transfer-minimal PAC data plane tests.

Covers the device-side Alg.2 wrap-around (flat real-batch grids, on-device
``offset + s % n_batches`` gather) against the host-replay parity oracle,
the out-of-core ``plan_epoch`` localization from ``tig-shards-v1`` row
ranges, the protocol eval routing that reuses PAC's synchronized memory,
the ``epochs=0`` guard, and the compiled-program LRU.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sep_partition
from repro.tig import distributed
from repro.tig.data import synthetic_tig
from repro.tig.distributed import (
    globalize_memory,
    pac_train,
    plan_epoch,
)
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig, init_state
from repro.tig.stream import write_graph_shards
from repro.tig.train import time_scale_of

CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=50)


def setup_case(seed=0, num_parts=4, k=0.05):
    g = synthetic_tig("tiny", seed=seed)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, num_parts, k=k)
    return g, train_g, part


def _assert_tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


# ----------------------------------------------------- device-side wrap


def test_device_wrap_bit_identical_to_host_replay():
    """The on-device wrap-around gather must reproduce the host-replayed
    grids BIT-identically across epochs (losses, params, memories) — the
    replay path is the oracle the transfer-minimal plan replaces."""
    g, train_g, part = setup_case()
    kw = dict(num_devices=4, epochs=2, lr=2e-3, shuffle_parts=False)
    r_new = pac_train(train_g, part, CFG, **kw)
    r_old = pac_train(train_g, part, CFG, host_replay=True, **kw)
    for a, b in zip(r_new.losses, r_old.losses):
        np.testing.assert_array_equal(a, b)
    _assert_tree_equal(r_new.params, r_old.params)
    _assert_tree_equal(r_new.memory_states, r_old.memory_states)


def test_device_wrap_parity_with_shuffle_combine():
    """Same bit-parity under per-epoch shuffle-combine replanning (|P|>N:
    capacities/shapes change between epochs, exercising the program
    cache on both paths)."""
    g, train_g, part = setup_case(num_parts=8)
    kw = dict(num_devices=4, epochs=2, lr=2e-3, shuffle_parts=True)
    r_new = pac_train(train_g, part, CFG, **kw)
    r_old = pac_train(train_g, part, CFG, host_replay=True, **kw)
    for a, b in zip(r_new.losses, r_old.losses):
        np.testing.assert_array_equal(a, b)
    _assert_tree_equal(r_new.params, r_old.params)


# ------------------------------------------------- sharded localization


def test_sharded_plan_matches_in_memory(tmp_path):
    """plan_epoch straight off tig-shards-v1 row ranges must emit grids,
    offsets, and feature tables identical to the in-memory plan for the
    same node lists and RNG."""
    g, train_g, part = setup_case()
    sh = write_graph_shards(train_g, str(tmp_path / "sh"), shard_edges=300)

    rng = np.random.default_rng(0)
    p_mem = plan_epoch(train_g, part.node_lists(), part.shared_nodes,
                       CFG, rng, time_scale=time_scale_of(train_g.t))
    rng = np.random.default_rng(0)
    p_shd = plan_epoch(sh, part.node_lists(), part.shared_nodes, CFG, rng)

    assert p_shd.steps == p_mem.steps
    np.testing.assert_array_equal(p_shd.n_batches, p_mem.n_batches)
    np.testing.assert_array_equal(p_shd.offsets, p_mem.offsets)
    np.testing.assert_array_equal(p_shd.edges_per_device,
                                  p_mem.edges_per_device)
    for key in p_mem.batches:
        np.testing.assert_array_equal(p_shd.batches[key],
                                      p_mem.batches[key])
    np.testing.assert_array_equal(p_shd.nfeat_local, p_mem.nfeat_local)
    np.testing.assert_array_equal(p_shd.efeat_local, p_mem.efeat_local)
    np.testing.assert_array_equal(p_shd.shared_local, p_mem.shared_local)


def test_pac_train_sharded_end_to_end(tmp_path):
    """pac_train over a ShardedStream (train split) with a sharded
    eval_graph: no TemporalGraph is materialized anywhere on the PAC path,
    and losses/params/metrics match the in-memory run exactly."""
    g, train_g, part = setup_case()
    sh_train = write_graph_shards(train_g, str(tmp_path / "tr"),
                                  shard_edges=300)
    sh_full = write_graph_shards(g, str(tmp_path / "full"),
                                 shard_edges=400)
    kw = dict(num_devices=4, epochs=2, lr=2e-3, shuffle_parts=False)
    r_shd = pac_train(sh_train, part, CFG, eval_graph=sh_full, **kw)
    r_mem = pac_train(train_g, part, CFG, eval_graph=g, **kw)
    for a, b in zip(r_shd.losses, r_mem.losses):
        np.testing.assert_array_equal(a, b)
    _assert_tree_equal(r_shd.params, r_mem.params)
    assert r_shd.metrics is not None
    for key, v in r_mem.metrics.items():
        if np.isnan(v):
            assert np.isnan(r_shd.metrics[key]), key
        else:
            assert r_shd.metrics[key] == pytest.approx(v, abs=1e-12), key


# --------------------------------------------------- protocol eval path


def test_pac_eval_reuses_synced_memory():
    """pac_train(eval_graph=...) routes through run_protocol with PAC's
    globalized post-sync memory: the train replay is skipped (train_ap is
    NaN) and val/test metrics are present and sane."""
    g, train_g, part = setup_case()
    res = pac_train(train_g, part, CFG, num_devices=4, epochs=1,
                    shuffle_parts=False, eval_graph=g)
    m = res.metrics
    assert m is not None
    assert np.isnan(m["train_ap"])          # no replay-to-warm-memory pass
    for key in ("val_ap", "val_auc", "test_ap", "test_auc"):
        assert 0.0 <= m[key] <= 1.0
    assert {"val_ap_inductive", "test_ap_inductive", "node_auroc"} \
        <= set(m)


def test_globalize_memory_latest_rule():
    """Overlapping nodes resolve to the replica with the largest last-update
    time; times are rescaled into the consumer's units; non-hosted rows
    stay zero."""
    cfg = TIGConfig(flavor="tgn", dim=4, dim_time=4, dim_edge=4,
                    dim_node=4, num_neighbors=2, batch_size=8)
    num_nodes = 6
    # device 0 hosts {0, 2, 4}, device 1 hosts {2, 3} (node 2 overlaps)
    node_lists = [np.array([0, 2, 4]), np.array([2, 3])]
    cap = 3
    mem = np.zeros((2, cap + 1, 4), np.float32)
    last = np.zeros((2, cap + 1), np.float32)
    mem[0, :3] = [[1] * 4, [2] * 4, [3] * 4]    # rows of nodes 0, 2, 4
    last[0, :3] = [1.0, 5.0, 2.0]
    mem[1, :2] = [[9] * 4, [7] * 4]             # rows of nodes 2, 3
    last[1, :2] = [6.0, 3.0]
    states = {"mem": mem, "mem2": mem * 0.5, "last": last}
    plan = type("P", (), {"node_lists": node_lists})()

    out = globalize_memory(states, plan, num_nodes, cfg, time_rescale=2.0)
    m = np.asarray(out["mem"])
    l = np.asarray(out["last"])
    np.testing.assert_array_equal(m[0], np.full(4, 1.0))
    np.testing.assert_array_equal(m[2], np.full(4, 9.0))   # dev 1 is later
    np.testing.assert_array_equal(m[3], np.full(4, 7.0))
    np.testing.assert_array_equal(m[4], np.full(4, 3.0))
    np.testing.assert_array_equal(m[1], np.zeros(4))       # never hosted
    np.testing.assert_array_equal(m[5], np.zeros(4))
    assert l[2] == 12.0 and l[0] == 2.0                    # rescaled by 2
    # untouched keys come from a fresh init (pending buffers cleared)
    ref = init_state(cfg, num_nodes)
    np.testing.assert_array_equal(np.asarray(out["pend_ids"]),
                                  np.asarray(ref["pend_ids"]))


# --------------------------------------------------------- driver guards


def test_pac_train_epochs_zero():
    """epochs=0 must not raise (the old code hit NameError on states /
    last_plan): fresh stacked memories, an un-trained plan, no losses."""
    g, train_g, part = setup_case()
    res = pac_train(train_g, part, CFG, num_devices=4, epochs=0,
                    shuffle_parts=False)
    assert res.losses == []
    assert res.plan is not None
    assert res.memory_states["mem"].shape[0] == 4
    assert not res.memory_states["mem"].any()


def test_pac_program_cache_reuses_compiled_epochs(monkeypatch):
    """With a stable plan shape the epoch executor is built once for the
    whole run; the LRU key is (steps, capacity, edge_capacity)."""
    calls = []
    real = distributed.make_pac_epoch

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(distributed, "make_pac_epoch", counting)
    g, train_g, part = setup_case()
    pac_train(train_g, part, CFG, num_devices=4, epochs=3,
              shuffle_parts=False)
    assert len(calls) == 1


def test_pac_program_cache_handles_alternating_keys(monkeypatch):
    """Across shuffle-combine epochs the number of builds equals the number
    of DISTINCT (steps, capacity, edge_capacity) keys — revisited shapes
    reuse their compiled program instead of rebuilding every epoch."""
    calls = []
    real = distributed.make_pac_epoch

    def counting(cfg, opt, steps, capacity, **kw):
        calls.append((steps, capacity))
        return real(cfg, opt, steps, capacity, **kw)

    monkeypatch.setattr(distributed, "make_pac_epoch", counting)
    g, train_g, part = setup_case(num_parts=8)
    epochs = 3
    res = pac_train(train_g, part, CFG, num_devices=4, epochs=epochs,
                    shuffle_parts=True)
    # replicate the per-epoch planning to learn the true key sequence
    from repro.core.pac import shuffle_combine
    from repro.tig.train import epoch_rng

    keys = []
    for ep in range(epochs):
        rng_ep = epoch_rng(0, ep, 11)
        nl = shuffle_combine(part.node_lists(), 4, rng_ep)
        plan = plan_epoch(train_g, nl, part.shared_nodes, CFG, rng_ep,
                          time_scale=time_scale_of(train_g.t))
        keys.append((plan.steps, plan.capacity, plan.edge_capacity))
    assert len(calls) == len(set(keys))
    assert len(res.losses) == epochs
