"""Tests for the loop-aware HLO cost analyzer + roofline machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import MODEL_FLOPS, parse_collectives
from repro.roofline.hlo_cost import analyze_hlo_text


def compile_fn(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_plain_matmul_flops_exact():
    m, k, n = 256, 512, 128
    c = compile_fn(lambda a, b: a @ b,
                   jax.ShapeDtypeStruct((m, k), jnp.float32),
                   jax.ShapeDtypeStruct((k, n), jnp.float32))
    hc = analyze_hlo_text(c.as_text())
    assert hc.flops == 2 * m * k * n


def test_scan_flops_multiplied_by_trip_count():
    m, k = 128, 256

    def scanned(a, b):
        def body(x, _):
            return jnp.tanh(x @ b), None
        y, _ = jax.lax.scan(body, a, None, length=10)
        return y

    c = compile_fn(scanned,
                   jax.ShapeDtypeStruct((m, k), jnp.float32),
                   jax.ShapeDtypeStruct((k, k), jnp.float32))
    hc = analyze_hlo_text(c.as_text())
    assert hc.flops == 10 * 2 * m * k * k


def test_nested_scan_flops():
    m, k = 64, 128

    def nested(a, b):
        def inner(x, _):
            return x @ b, None

        def outer(x, _):
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None

        y, _ = jax.lax.scan(outer, a, None, length=4)
        return y

    c = compile_fn(nested,
                   jax.ShapeDtypeStruct((m, k), jnp.float32),
                   jax.ShapeDtypeStruct((k, k), jnp.float32))
    hc = analyze_hlo_text(c.as_text())
    assert hc.flops == 20 * 2 * m * k * k


def test_batched_dot_flops():
    b, m, k, n = 4, 32, 64, 16
    c = compile_fn(lambda x, y: jnp.einsum("bmk,bkn->bmn", x, y),
                   jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    hc = analyze_hlo_text(c.as_text())
    assert hc.flops == 2 * b * m * k * n


def test_grad_roughly_triples_flops():
    m = 128

    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    specs = (jax.ShapeDtypeStruct((m, m), jnp.float32),
             jax.ShapeDtypeStruct((m, m), jnp.float32))
    fwd = analyze_hlo_text(compile_fn(f, *specs).as_text())
    bwd = analyze_hlo_text(compile_fn(jax.grad(f), *specs).as_text())
    assert 2.0 <= bwd.flops / fwd.flops <= 4.0


def test_bytes_nonzero_and_sane():
    m = 256
    c = compile_fn(lambda a: a + 1.0,
                   jax.ShapeDtypeStruct((m, m), jnp.float32))
    hc = analyze_hlo_text(c.as_text())
    # read + write of a 256x256 f32
    assert hc.bytes >= 2 * m * m * 4
    assert hc.bytes <= 8 * m * m * 4


def test_collectives_counted_in_sharded_program():
    """psum over 4 forced host devices must show up as all-reduce bytes."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_cost import analyze_hlo_text
        mesh = jax.make_mesh((4,), ("d",))
        def f(x):
            return jax.lax.with_sharding_constraint(
                jnp.sum(x, axis=0, keepdims=True), P())
        sh = NamedSharding(mesh, P("d", None))
        from repro import compat
        with compat.set_mesh(mesh):
            c = jax.jit(f, in_shardings=(sh,),
                        out_shardings=NamedSharding(mesh, P())).lower(
                jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
        hc = analyze_hlo_text(c.as_text())
        assert hc.collective_bytes > 0, hc
        print("COLL_OK", hc.collective_bytes)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COLL_OK" in proc.stdout


def test_model_flops_formula():
    assert MODEL_FLOPS(1e9, 1e6, "train") == 6e15
    assert MODEL_FLOPS(1e9, 1e6, "infer") == 2e15


def test_parse_collectives_regex():
    hlo = """
  %ag = f32[128,256]{1,0} all-gather(%x), dimensions={0}
  %ar = bf16[64]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[32,32]{1,0} reduce-scatter(%z), dimensions={0}
"""
    st = parse_collectives(hlo)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.bytes_by_kind["all-gather"] == 128 * 256 * 4
    assert st.bytes_by_kind["all-reduce"] == 64 * 2 * 2  # doubled


# ------------------------------------------------- kernel HBM byte models

def test_kernel_bytes_fused_bwd_strictly_fewer():
    """Acceptance: the fused backward kernels move strictly fewer modeled
    HBM bytes than the oracle-VJP recompute path, across scales."""
    from repro.roofline.kernel_bytes import attn_bytes, gru_bytes
    for b in (64, 400, 4096):
        g_f = gru_bytes(b, 176, 128, direction="bwd", fused=True)
        g_o = gru_bytes(b, 176, 128, direction="bwd", fused=False)
        assert g_f.total < g_o.total, (b, g_f.total, g_o.total)
        a_f = attn_bytes(3 * b, 10, 2, 64, direction="bwd", fused=True)
        a_o = attn_bytes(3 * b, 10, 2, 64, direction="bwd", fused=False)
        assert a_f.total < a_o.total, (b, a_f.total, a_o.total)
        # forward fusion also wins
        assert gru_bytes(b, 176, 128, fused=True).total < \
            gru_bytes(b, 176, 128, fused=False).total
        assert attn_bytes(3 * b, 10, 2, 64, fused=True).total < \
            attn_bytes(3 * b, 10, 2, 64, fused=False).total


def test_flush_bytes_fused_is_o_rows_not_o_nodes():
    """The fused flush has no O(N) term: its forward bytes are flat in the
    node count, while the unfused table-based pipeline grows linearly."""
    from repro.roofline.kernel_bytes import flush_bytes
    f_small = flush_bytes(10_000, 400, 176, 128, fused=True)
    f_big = flush_bytes(10_000_000, 400, 176, 128, fused=True)
    assert f_small.total == f_big.total
    u_small = flush_bytes(10_000, 400, 176, 128, fused=False)
    u_big = flush_bytes(10_000_000, 400, 176, 128, fused=False)
    assert u_big.total > 100 * u_small.total / 2     # ~linear in N
    assert f_small.total < u_small.total


def test_step_pipeline_bytes_fused_wins_and_itemizes():
    from repro.roofline.kernel_bytes import step_pipeline_bytes
    out = step_pipeline_bytes(n_nodes=100_000, batch=200, d_msg=176,
                              d_mem=128, k_neighbors=10, n_heads=2)
    assert out["fused"] < out["unfused"]
    assert len(out["detail"]) == 8
    for p in out["detail"]:
        assert p.total == p.read_bytes + p.write_bytes
        assert all(v >= 0 for v in p.reads.values())


# ------------------------------------------------- PAC pod byte models

def test_pac_sync_bytes_scales_and_splits_dcn():
    """The shared-node sync model: timestamp gather + winner-masked psum;
    cross-host traffic is the ring hops that leave a host."""
    from repro.roofline.kernel_bytes import pac_sync_bytes
    one = pac_sync_bytes(n_shared=1000, d_mem=128, n_devices=4)
    assert one["cross_host"] == 0 and one["dcn_fraction"] == 0.0
    assert set(one["detail"]) == {"gather_ts", "psum_mem", "psum_mem2"}
    # the C1 epilogue gathers only timestamps: the gather term is ~d-fold
    # below the psum terms
    assert one["detail"]["gather_ts"] * 16 < one["detail"]["psum_mem"]
    two = pac_sync_bytes(n_shared=1000, d_mem=128, n_devices=4, n_hosts=2)
    assert two["per_device"] == one["per_device"]
    assert 0 < two["cross_host"] == int(two["per_device"] * 2 / 4)
    mean = pac_sync_bytes(n_shared=1000, d_mem=128, n_devices=4,
                          mode="mean")
    assert "psum_ts" in mean["detail"] and "gather_ts" not in mean["detail"]
    # more devices -> more link bytes per device (ring + gather terms)
    assert pac_sync_bytes(1000, 128, 8)["per_device"] > one["per_device"]


def test_pac_staging_sharded_strictly_below_replicated():
    """Acceptance (satellite): sharded-grid staging bytes are strictly
    below replicated staging for every >1-device mesh, per host and in
    total — replicated ships sum-of-all-rows to each device, sharded only
    the device's own padded rows."""
    from repro.roofline.kernel_bytes import pac_staging_bytes
    rows = [40, 11, 9, 5]            # imbalanced partitions
    events = [8000, 2200, 1800, 1000]
    out = pac_staging_bytes(rows, events, row_bytes=1050, n_hosts=2)
    assert len(out["replicated"]) == len(out["sharded"]) == 2
    for rep, sh in zip(out["replicated"], out["sharded"]):
        assert sh < rep
    assert out["total_sharded"] < out["total_replicated"]
    assert out["per_device_sharded"] < out["per_device_replicated"]
    # single device: the two layouts coincide (nothing to replicate)
    single = pac_staging_bytes([7], [100], row_bytes=1050)
    assert single["total_sharded"] == single["total_replicated"]


# ------------------------------------------- epoch-boundary bubble model

def test_pipeline_bubble_disciplines_ordered():
    """overlapped <= prefetch <= serial, and the amortized end drain
    shrinks with epoch count."""
    from repro.roofline.pipeline_bubble import pipeline_bubble
    kw = dict(plan_s=0.004, stage_s=0.002, sync_s=0.044, fetch_s=0.001,
              scan_s=0.050, dispatch_s=0.004)
    out = pipeline_bubble(epochs=3, **kw)
    # plan+stage fit behind the scan: no spill
    assert out["spill_s"] == 0.0
    assert out["overlapped_s"] <= out["prefetch_s"] <= out["serial_s"]
    assert out["serial_s"] == pytest.approx(0.004 + 0.002 + 0.044 + 0.001)
    assert out["prefetch_s"] == pytest.approx(0.044 + 0.001)
    assert out["overlapped_s"] == pytest.approx(0.004 + 0.045 / 3)
    assert out["speedup_vs_serial"] == pytest.approx(
        out["serial_s"] / out["overlapped_s"])
    more = pipeline_bubble(epochs=30, **kw)
    assert more["overlapped_s"] < out["overlapped_s"]


def test_pipeline_bubble_spill_and_guards():
    from repro.roofline.pipeline_bubble import pipeline_bubble
    # planning longer than the scan: the spill is exposed everywhere
    out = pipeline_bubble(plan_s=0.08, stage_s=0.02, sync_s=0.01,
                          fetch_s=0.0, scan_s=0.04, epochs=2)
    assert out["spill_s"] == pytest.approx(0.06)
    assert out["prefetch_s"] == pytest.approx(0.06 + 0.01)
    # degenerate all-zero boundary: speedups are inf, not a crash
    free = pipeline_bubble(plan_s=0, stage_s=0, sync_s=0, fetch_s=0,
                           scan_s=1, epochs=1)
    assert free["overlapped_s"] == 0 and free["speedup_vs_serial"] == \
        float("inf")
    with pytest.raises(ValueError, match="epochs"):
        pipeline_bubble(plan_s=0, stage_s=0, sync_s=0, fetch_s=0,
                        scan_s=0, epochs=0)
    with pytest.raises(ValueError, match="sync_s"):
        pipeline_bubble(plan_s=0, stage_s=0, sync_s=-1, fetch_s=0,
                        scan_s=0, epochs=1)


def test_boundary_component_seconds_links():
    from repro.roofline.pipeline_bubble import boundary_component_seconds
    out = boundary_component_seconds(sync_bytes=1.25e9, staging_bytes=8e9,
                                     plan_s=0.5)
    assert out["sync_s"] == pytest.approx(1.0)   # 1.25 GB at 1.25 GB/s
    assert out["stage_s"] == pytest.approx(1.0)  # 8 GB at 8 GB/s
    assert out["plan_s"] == 0.5
    with pytest.raises(ValueError, match="positive"):
        boundary_component_seconds(sync_bytes=1, staging_bytes=1,
                                   plan_s=0, dcn_gbps=0)
