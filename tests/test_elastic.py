"""Elastic PAC: deterministic fault injection, TIGER-style replayless
restarts, resume-from-checkpoint parity, and the 2-process CPU-cluster
host-kill recovery case.

The recovery acceptance oracle: kill original rank 1 with an injected
SIGKILL mid-epoch-1, let the surviving supervisor re-form a 1-process
world (picking up the lost host's device slots) and resume from the
atomic checkpoint — the final protocol metrics must match an undisturbed
single-process run of the same schedule within 1e-2 (measured: they are
bit-identical; the tolerance absorbs gloo reduction-order noise).
"""

import os
import socket
import subprocess
import sys

import numpy as np
import pytest

from repro.core import sep_partition
from repro.faults import (
    FaultInjector,
    HostLossError,
    InjectedFault,
    is_host_loss,
    parse_faults,
)
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig

CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=50)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------- fault injector

def test_parse_grammar():
    specs = parse_faults("host_kill@epoch=1,rank=1;"
                         "staging_oom@at=2;"
                         "sync_fail@prob=0.5,seed=7,action=raise")
    assert [s.site for s in specs] == ["host_kill", "staging_oom",
                                      "sync_fail"]
    assert specs[0].epoch == 1 and specs[0].rank == 1
    assert specs[0].resolved_action() == "kill"
    assert specs[1].at == 2
    assert specs[1].resolved_action() == "oom"
    assert specs[2].prob == 0.5 and specs[2].seed == 7
    assert parse_faults("") == [] and parse_faults(";") == []


def test_parse_rejects_unknown_args_and_actions():
    with pytest.raises(ValueError, match="unknown fault spec arg"):
        parse_faults("host_kill@bogus=1")
    with pytest.raises(ValueError, match="unknown fault action"):
        parse_faults("sync_fail@action=explode")


def test_fire_matches_epoch_and_fires_once():
    inj = FaultInjector.parse("sync_fail@epoch=2", process_index=0)
    inj.fire("sync_fail", epoch=0)
    inj.fire("sync_fail", epoch=1)
    with pytest.raises(InjectedFault):
        inj.fire("sync_fail", epoch=2)
    inj.fire("sync_fail", epoch=2)      # armed specs fire at most once
    assert not inj.armed


def test_fire_counts_calls_per_site():
    inj = FaultInjector.parse("staging_oom@at=3", process_index=0)
    inj.fire("staging_oom")
    inj.fire("other_site")              # separate counter
    inj.fire("staging_oom")
    with pytest.raises(MemoryError):
        inj.fire("staging_oom")


def test_rank_filter():
    inj = FaultInjector.parse("prefetch_worker@epoch=0,rank=1",
                              process_index=0)
    inj.fire("prefetch_worker", epoch=0)        # wrong rank: no-op
    inj = FaultInjector.parse("prefetch_worker@epoch=0,rank=1",
                              process_index=1)
    with pytest.raises(InjectedFault):
        inj.fire("prefetch_worker", epoch=0)


def test_prob_draws_are_deterministic():
    def outcomes():
        inj = FaultInjector.parse("sync_fail@prob=0.5,seed=7",
                                  process_index=0)
        hits = []
        for _ in range(20):
            try:
                inj.fire("sync_fail")
                hits.append(0)
            except InjectedFault:
                hits.append(1)
        return hits

    assert outcomes() == outcomes()
    assert sum(outcomes()) == 1         # armed specs fire at most once


def test_inert_injector_is_noop():
    inj = FaultInjector.from_env(env_var="REPRO_FAULTS_UNSET_FOR_TEST")
    inj.fire("host_kill", epoch=0)
    assert not inj.armed


def test_is_host_loss_classification():
    assert is_host_loss(HostLossError("peer gone"))
    assert is_host_loss(RuntimeError(
        "Gloo all-reduce failed: Connection closed by peer"))
    assert is_host_loss(RuntimeError(
        "DEADLINE_EXCEEDED: heartbeat timeout"))
    # the marker may sit anywhere in the cause chain
    try:
        try:
            raise OSError("Broken pipe")
        except OSError as inner:
            raise ValueError("staging failed") from inner
    except ValueError as chained:
        assert is_host_loss(chained)
    assert not is_host_loss(ValueError("shape mismatch for mem"))


# ------------------------------------------------- restarter warm protocol

def _protocol_case():
    from repro.tig.batching import make_tables
    from repro.tig.protocol import split_views
    from repro.tig.train import train_single
    import jax.numpy as jnp

    g = synthetic_tig("tiny", seed=0)
    res = train_single(g, CFG, epochs=1, seed=0)
    splits = split_views(g)
    tables_j = {k: jnp.asarray(v) for k, v in
                make_tables(g.edge_feat, g.node_feat).items()}
    return g, res.params, splits, tables_j


def test_restart_warm_matches_state_oracle(tmp_path):
    """``warm="restart"`` must land within tolerance of the replay-built
    memory scored through the SAME protocol path (``warm="state"``), and
    the restarter must survive a save/load roundtrip bit-for-bit."""
    from repro.tig.protocol import run_protocol
    from repro.tig.restart import (build_restarter, load_restarter,
                                   restart_memory, save_restarter)

    _g, params, splits, tables_j = _protocol_case()
    rst, replay_state = build_restarter(params, CFG, splits, tables_j,
                                        seed=0, steps=200)
    oracle = run_protocol(params, CFG, splits, tables_j, seed=0,
                          warm="state", state=replay_state)
    restart = run_protocol(params, CFG, splits, tables_j, seed=0,
                           warm="restart", restarter=rst)
    for key in ("val_ap", "test_ap", "val_auc", "test_auc"):
        assert abs(restart[key] - oracle[key]) <= 0.05, \
            f"{key}: restart {restart[key]:.4f} vs oracle {oracle[key]:.4f}"

    path = str(tmp_path / "restarter.npz")
    save_restarter(path, rst)
    rst2 = load_restarter(path, CFG)
    assert rst2.fit_mse == pytest.approx(rst.fit_mse)
    s1 = restart_memory(rst, splits.num_nodes, tables_j)
    s2 = restart_memory(rst2, splits.num_nodes, tables_j)
    for key in s1:
        np.testing.assert_array_equal(np.asarray(s1[key]),
                                      np.asarray(s2[key]), err_msg=key)


def test_run_protocol_warm_validation():
    from repro.tig.protocol import run_protocol

    _g, params, splits, tables_j = _protocol_case()
    with pytest.raises(ValueError, match="restart"):
        run_protocol(params, CFG, splits, tables_j, warm="restart")
    with pytest.raises(ValueError, match="state"):
        run_protocol(params, CFG, splits, tables_j, warm="state")
    with pytest.raises(ValueError, match="warm"):
        run_protocol(params, CFG, splits, tables_j, warm="bogus")


# ------------------------------------------------------ pac_train recovery

def _pac_case(num_parts=8):
    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         num_parts, k=0.05)
    return g, train_g, part


def _tree_equal(a, b):
    import jax
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


def test_pac_resume_is_bit_identical(tmp_path):
    """Kill-and-resume parity: 2 epochs + checkpoint, then resume to 3
    epochs == an undisturbed 3-epoch run, bit for bit (params, memory,
    and the resumed epoch's losses)."""
    _g, train_g, part = _pac_case()
    kw = dict(num_devices=4, seed=0, shuffle_parts=True, plan="device")
    d = str(tmp_path / "ckpt")

    full = pac_train(train_g, part, CFG, epochs=3, **kw)
    pac_train(train_g, part, CFG, epochs=2, ckpt_dir=d, ckpt_every=1, **kw)
    res = pac_train(train_g, part, CFG, epochs=3, ckpt_dir=d, resume=True,
                    **kw)
    _tree_equal(full.params, res.params)
    _tree_equal(full.memory_states, res.memory_states)
    assert len(res.losses) == 1         # only the resumed epoch ran
    np.testing.assert_array_equal(np.asarray(full.losses[2]),
                                  np.asarray(res.losses[0]))


def test_pac_train_fault_sites(tmp_path):
    _g, train_g, part = _pac_case()
    kw = dict(num_devices=4, epochs=2, seed=0, plan="device")

    with pytest.raises(InjectedFault):
        pac_train(train_g, part, CFG,
                  faults=FaultInjector.parse("prefetch_worker@epoch=1",
                                             process_index=0), **kw)
    with pytest.raises(MemoryError):
        pac_train(train_g, part, CFG,
                  faults=FaultInjector.parse("staging_oom@at=1",
                                             process_index=0), **kw)
    with pytest.raises(InjectedFault):
        pac_train(train_g, part, CFG,
                  faults=FaultInjector.parse("sync_fail@epoch=0",
                                             process_index=0), **kw)
    with pytest.raises(ValueError, match="resume"):
        pac_train(train_g, part, CFG, resume=True, **kw)


def test_pac_eval_warm_restart_saves_restarter(tmp_path):
    """``eval_warm="restart"`` scores the protocol through the restarter
    AND persists the fitted head next to the checkpoints, so a recovered
    process can warm memory without replay."""
    g, train_g, part = _pac_case()
    d = str(tmp_path / "ckpt")
    res = pac_train(train_g, part, CFG, num_devices=4, epochs=2, seed=0,
                    plan="device", eval_graph=g, eval_warm="restart",
                    ckpt_dir=d, ckpt_every=1)
    assert res.metrics is not None and 0.4 < res.metrics["val_ap"] <= 1.0
    assert os.path.isfile(os.path.join(d, "restarter.npz"))
    assert os.path.isfile(os.path.join(d, "ckpt_00000001.npz"))


# ------------------------------------------------ 2-process host-kill case

def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _elastic_cmd(run_dir, *, process_id, port, out=None):
    cmd = [sys.executable, "-u", "-m", "repro.launch.pac_cluster",
           "--elastic", "--run-dir", str(run_dir),
           "--num-processes", "2", "--process-id", str(process_id),
           "--coordinator", f"127.0.0.1:{port}",
           "--local-devices", "2", "--epochs", "2", "--parts", "8",
           "--seed", "0", "--grid-layout", "sharded",
           "--ckpt-every", "1", "--max-restarts", "2",
           "--heartbeat-interval", "0.25", "--heartbeat-timeout", "5"]
    if out is not None:
        cmd += ["--out", str(out)]
    return cmd


def test_elastic_cluster_recovers_from_host_kill(tmp_path):
    """Kill original rank 1 (injected SIGKILL, epoch 1) mid-run: its
    supervisor marks the host lost and exits 0; rank 0's worker dies on
    the broken collective, its supervisor re-forms a 1-process world with
    all 4 device slots and resumes from the epoch-0 checkpoint.  Final
    metrics match an undisturbed single-process run within 1e-2."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FAULTS", None)
    kill_env = dict(env, REPRO_FAULTS="host_kill@epoch=1,rank=1")

    run_dir = tmp_path / "run"
    out = tmp_path / "recovered.npz"
    port = _free_port()
    procs = [
        subprocess.Popen(
            _elastic_cmd(run_dir, process_id=0, port=port, out=out),
            cwd=REPO, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True),
        subprocess.Popen(
            _elastic_cmd(run_dir, process_id=1, port=port),
            cwd=REPO, env=kill_env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True),
    ]
    logs = []
    try:
        for p in procs:
            stdout, _ = p.communicate(timeout=600)
            logs.append(stdout)
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        raise
    if any(p.returncode == 17 or "CLUSTER_UNAVAILABLE" in log
           for p, log in zip(procs, logs)):
        pytest.skip(f"CPU cluster unavailable: {logs[0][-500:]}")

    assert procs[0].returncode == 0, logs[0][-3000:]
    assert procs[1].returncode == 0, logs[1][-3000:]
    assert "FAULT_INJECTED: host_kill" in logs[1]
    assert "HOST_LOST" in logs[1]
    assert "survivors = [0]" in logs[0]
    assert "PAC_RESUME: step 0" in logs[0]
    assert (run_dir / "lost_1").exists()
    assert out.exists(), "recovered run wrote no output"

    oracle_out = tmp_path / "oracle.npz"
    proc = subprocess.run(
        [sys.executable, "-u", "-m", "repro.launch.pac_cluster",
         "--num-processes", "1", "--process-id", "0",
         "--coordinator", f"127.0.0.1:{_free_port()}",
         "--local-devices", "4", "--epochs", "2", "--parts", "8",
         "--seed", "0", "--grid-layout", "sharded",
         "--out", str(oracle_out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]

    rec, org = np.load(out), np.load(oracle_out)
    for key in org.files:
        if key.startswith("metric_"):
            np.testing.assert_allclose(rec[key], org[key], atol=1e-2,
                                       err_msg=key)
    for key in [k for k in org.files if k.startswith("param_")]:
        np.testing.assert_allclose(rec[key], org[key], atol=1e-3,
                                   err_msg=key)
