"""MoE dispatch correctness: the sort-based capacity dispatch must equal
the dense mixture-of-experts reference when nothing is dropped."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.models.moe import moe_apply, moe_init


def dense_moe_reference(p, x, top_k, act):
    """Compute every expert for every token, combine with renormalized
    top-k gates — the semantic ground truth (O(T*E*d*f), test-only)."""
    from repro.models.layers import _act

    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    h = _act(act, jnp.einsum("td,edf->tef", x, p["wi"].astype(x.dtype)))
    if "wg" in p:
        h = h * jnp.einsum("td,edf->tef", x, p["wg"].astype(x.dtype))
    y_all = jnp.einsum("tef,efd->ted", h, p["wo"].astype(x.dtype))
    gates = jnp.zeros(probs.shape, x.dtype)
    gates = gates.at[jnp.arange(x.shape[0])[:, None], top_i].set(
        top_p.astype(x.dtype))
    return jnp.einsum("te,ted->td", gates, y_all)


@pytest.mark.parametrize("act", ["swiglu", "gelu"])
@pytest.mark.parametrize("t,e,k", [(64, 8, 2), (128, 16, 4), (32, 4, 1)])
def test_sorted_dispatch_matches_dense(t, e, k, act):
    d, f = 32, 48
    key = jax.random.PRNGKey(0)
    p = moe_init(key, d, f, e, act)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    y_sorted, aux = moe_apply(p, x, top_k=k, act=act, dropless=True)
    y_dense = dense_moe_reference(p, x, k, act)
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_reduce_output_norm():
    """With a tight capacity, dropped tokens contribute zero — the output
    is a strict 'subset' of the dropless one."""
    d, f, e, k, t = 16, 24, 4, 2, 64
    p = moe_init(jax.random.PRNGKey(2), d, f, e, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d))
    y_full, _ = moe_apply(p, x, top_k=k, act="swiglu", dropless=True)
    y_tight, _ = moe_apply(p, x, top_k=k, act="swiglu",
                           capacity_factor=0.25)
    # tight capacity must zero-out some tokens' expert contributions
    assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_full).sum())


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 200))
def test_dispatch_property_random(seed):
    d, f, e, k, t = 8, 12, 4, 2, 40
    p = moe_init(jax.random.PRNGKey(seed), d, f, e, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, d))
    y, aux = moe_apply(p, x, top_k=k, act="swiglu", dropless=True)
    assert np.isfinite(np.asarray(y)).all()
    y_dense = dense_moe_reference(p, x, k, "swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-3)


def test_sharded_moe_matches_pjit_single_device():
    """moe_apply_sharded under a 1x1 mesh must equal the pjit path."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import moe_apply, moe_apply_sharded, moe_init
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        d, f, e, k, t = 16, 24, 4, 2, 64
        p = moe_init(jax.random.PRNGKey(0), d, f, e, "swiglu")
        x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
        from repro import compat
        with compat.set_mesh(mesh):
            y_ref, aux_ref = moe_apply(p, x, top_k=k, act="swiglu",
                                       dropless=True)
            y_sm, aux_sm = jax.jit(
                lambda p, x: moe_apply_sharded(
                    p, x, top_k=k, act="swiglu", capacity_factor=100.0,
                    token_axes="data"))(p, x)
        np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-3)
        # aux: per-shard mean-of-products vs global product-of-means —
        # the standard distributed load-balance estimator difference
        assert abs(float(aux_sm) - float(aux_ref)) < 5e-3
        print("SHARDED_MOE_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_MOE_OK" in proc.stdout
