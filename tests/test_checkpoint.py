"""Checkpoint hardening: atomic tmp+rename writes, corrupt/partial-step
tolerance in ``latest_step``, and the subset-restore contract the elastic
recovery path depends on."""

import json
import os

import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _tree(x=1.0):
    return {"params": {"w": np.full((3, 2), x, np.float32),
                       "b": np.zeros((2,), np.float32)},
            "opt_state": {"mu": {"w": np.ones((3, 2), np.float32)}},
            "state": {"mem": np.arange(6, dtype=np.float32)}}


def test_save_leaves_no_tmp_files(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _tree(), metadata={"epoch": 0})
    names = sorted(os.listdir(d))
    assert names == ["ckpt_00000000.json", "ckpt_00000000.npz"]
    assert not any(".tmp" in n for n in names)


def test_latest_step_skips_truncated_npz(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _tree(1.0))
    save_checkpoint(d, 1, _tree(2.0))
    npz1 = os.path.join(d, "ckpt_00000001.npz")
    with open(npz1, "r+b") as f:         # tear the newest step's zip
        f.truncate(os.path.getsize(npz1) // 2)
    assert latest_step(d) == 0
    restored = restore_checkpoint(d, 0, _tree())
    np.testing.assert_array_equal(restored["params"]["w"],
                                  _tree(1.0)["params"]["w"])


def test_latest_step_skips_manifestless_and_bad_manifest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree())
    # a lone npz (killed between the two renames) must not count
    np.savez(os.path.join(d, "ckpt_00000007.npz"), x=np.zeros(1))
    # an unparsable manifest must not count either
    save_checkpoint(d, 5, _tree())
    with open(os.path.join(d, "ckpt_00000005.json"), "w") as f:
        f.write("{not json")
    assert latest_step(d) == 3


def test_latest_step_empty_and_missing_dir(tmp_path):
    assert latest_step(str(tmp_path)) is None
    assert latest_step(str(tmp_path / "nope")) is None


def test_manifest_contents(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 2, _tree(), metadata={"epoch": 2, "val_ap": 0.5})
    with open(os.path.join(d, "ckpt_00000002.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 2
    assert manifest["metadata"] == {"epoch": 2, "val_ap": 0.5}
    assert manifest["num_arrays"] == 4


def test_subset_restore_from_superset(tmp_path):
    """The elastic contract: a periodic {params, opt_state, state} save
    must restore into a smaller {params, state} template (extra keys in
    the checkpoint are allowed)."""
    d = str(tmp_path)
    full = _tree(3.0)
    save_checkpoint(d, 0, full)
    sub = restore_checkpoint(d, 0, {"params": full["params"],
                                    "state": full["state"]})
    assert sorted(sub) == ["params", "state"]
    np.testing.assert_array_equal(sub["params"]["w"], full["params"]["w"])


def test_missing_keys_raise_value_error_naming_them(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"params": _tree()["params"]})
    with pytest.raises(ValueError, match="opt_state"):
        restore_checkpoint(d, 0, _tree())
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(d, 1, _tree())


def test_restore_shape_mismatch(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _tree())
    bad = _tree()
    bad["params"]["w"] = np.zeros((4, 4), np.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(d, 0, bad)
