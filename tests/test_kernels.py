"""Pallas kernel validation: interpret=True vs the pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_flush import fused_flush_fwd
from repro.kernels.fused_gru import fused_gru
from repro.kernels.rwkv6_scan import rwkv6_chunked
from repro.kernels.temporal_attn import temporal_attn


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# -------------------------------------------------------------- fused GRU

@pytest.mark.parametrize("b,d_in,d_h", [
    (8, 16, 16), (64, 48, 32), (100, 112, 64), (256, 128, 128), (3, 7, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_gru_matches_ref(b, d_in, d_h, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = rand(ks[0], (b, d_in), dtype)
    h = rand(ks[1], (b, d_h), dtype)
    wx = rand(ks[2], (d_in, 3 * d_h), dtype, 0.3)
    wh = rand(ks[3], (d_h, 3 * d_h), dtype, 0.3)
    bx = rand(ks[4], (3 * d_h,), dtype, 0.1)
    bh = rand(ks[5], (3 * d_h,), dtype, 0.1)
    got = fused_gru(x, h, wx, wh, bx, bh, interpret=True, block_b=32)
    want = ref.gru_ref(x, h, wx, wh, bx, bh)
    # bf16: the kernel accumulates gates in f32 (preferred_element_type)
    # while the jnp oracle matmuls in bf16 — allow bf16-rounding slack.
    tol = 1e-5 if dtype == jnp.float32 else 1.5e-1
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 70), d=st.sampled_from([8, 24, 40]),
       seed=st.integers(0, 100))
def test_fused_gru_property(b, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = rand(ks[0], (b, d))
    h = rand(ks[1], (b, d))
    wx = rand(ks[2], (d, 3 * d), scale=0.3)
    wh = rand(ks[3], (d, 3 * d), scale=0.3)
    bx = rand(ks[4], (3 * d,), scale=0.1)
    bh = rand(ks[5], (3 * d,), scale=0.1)
    got = fused_gru(x, h, wx, wh, bx, bh, interpret=True, block_b=16)
    want = ref.gru_ref(x, h, wx, wh, bx, bh)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    # GRU output is a convex mix of candidate (|.|<=1) and h
    assert np.all(np.abs(got) <= np.maximum(np.abs(h), 1.0) + 1e-5)


# ------------------------------------------------------ temporal attention

@pytest.mark.parametrize("b,k,h,d", [
    (16, 4, 2, 8), (64, 10, 2, 16), (33, 20, 4, 32), (5, 1, 1, 4),
])
def test_temporal_attn_matches_ref(b, k, h, d):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = rand(ks[0], (b, h, d))
    kk = rand(ks[1], (b, k, h, d))
    v = rand(ks[2], (b, k, h, d))
    mask = jax.random.uniform(ks[3], (b, k)) > 0.3
    got = temporal_attn(q, kk, v, mask, interpret=True, block_b=16)
    want = ref.temporal_attention_ref(q, kk, v, mask)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_temporal_attn_empty_rows_zero():
    b, k, h, d = 8, 5, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (b, h, d))
    kk = rand(ks[1], (b, k, h, d))
    v = rand(ks[2], (b, k, h, d))
    mask = np.zeros((b, k), bool)
    mask[0, :] = True  # only row 0 has neighbors
    got = np.asarray(temporal_attn(q, kk, v, jnp.asarray(mask),
                                   interpret=True))
    assert np.abs(got[1:]).max() == 0.0
    assert np.abs(got[0]).max() > 0.0


# ------------------------------------------------------------- fused flush

def flush_args(key, n, rows, dm, d, id_hi=None):
    ks = jax.random.split(key, 8)
    ids = jax.random.randint(ks[0], (rows,), 0,
                             (id_hi or n) + 1).astype(jnp.int32)
    return (ids,
            rand(ks[1], (rows, dm)),
            jax.random.uniform(ks[2], (rows,)) * 5.0,
            rand(ks[3], (n + 1, d)),
            jax.random.uniform(ks[4], (n + 1,)),
            rand(ks[5], (dm, 3 * d), scale=0.3),
            rand(ks[6], (d, 3 * d), scale=0.3),
            rand(ks[7], (3 * d,), scale=0.1),
            jnp.zeros((3 * d,)))


# (deterministic fused-flush parity sweeps live in test_kernel_grads.py,
# which has no optional-dep guard and runs everywhere tier-1 runs; only
# the hypothesis property test stays here)

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), rows=st.sampled_from([4, 16, 30]),
       n=st.sampled_from([5, 40]))
def test_fused_flush_property(seed, rows, n):
    args = flush_args(jax.random.PRNGKey(seed), n, rows, 8, 8)
    got = fused_flush_fwd(*args, interpret=True)
    want = ref.flush_ref(*args)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


# --------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 128, 16), (2, 2, 256, 32), (1, 4, 512, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, h, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (b, h, s, d), dtype)
    k = rand(ks[1], (b, h, s, d), dtype)
    v = rand(ks[2], (b, h, s, d), dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 200])
def test_flash_attention_sliding_window(window):
    b, h, s, d = 1, 2, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (b, h, s, d))
    k = rand(ks[1], (b, h, s, d))
    v = rand(ks[2], (b, h, s, d))
    got = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_flash_attention_noncausal():
    b, h, s, d = 1, 1, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (rand(ki, (b, h, s, d)) for ki in ks)
    got = flash_attention(q, k, v, causal=False, interpret=True,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------- RWKV6 WKV

def wkv_inputs(key, b, h, s, dk, dv, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = rand(ks[0], (b, h, s, dk), dtype)
    k = rand(ks[1], (b, h, s, dk), dtype)
    v = rand(ks[2], (b, h, s, dv), dtype)
    # decay in (~0.7, 1.0): the regime trained RWKV models live in
    w = jnp.exp(-jnp.exp(
        rand(ks[3], (b, h, s, dk)) * 0.5 - 2.0)).astype(dtype)
    u = rand(ks[4], (h, dk))
    return r, k, v, w, u


@pytest.mark.parametrize("b,h,s,dk,dv,chunk", [
    (1, 1, 64, 16, 16, 16), (2, 2, 128, 32, 32, 32),
    (1, 2, 256, 64, 64, 64), (1, 1, 128, 8, 24, 64),
])
def test_rwkv6_chunked_matches_scan(b, h, s, dk, dv, chunk):
    r, k, v, w, u = wkv_inputs(jax.random.PRNGKey(6), b, h, s, dk, dv)
    got_o, got_s = rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    want_o, want_s = ref.rwkv6_ref(r, k, v, w, u, return_state=True)
    np.testing.assert_allclose(got_o, want_o, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(got_s, want_s, atol=2e-4, rtol=2e-4)


def test_rwkv6_initial_state_continuation():
    """Processing [first half] then [second half | state] == full sequence."""
    b, h, s, dk, dv = 1, 2, 128, 16, 16
    r, k, v, w, u = wkv_inputs(jax.random.PRNGKey(7), b, h, s, dk, dv)
    full_o, full_s = rwkv6_chunked(r, k, v, w, u, chunk=32, interpret=True)
    half = s // 2
    o1, s1 = rwkv6_chunked(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                           w[:, :, :half], u, chunk=32, interpret=True)
    o2, s2 = rwkv6_chunked(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                           w[:, :, half:], u, state=s1, chunk=32,
                           interpret=True)
    np.testing.assert_allclose(np.concatenate([o1, o2], axis=2), full_o,
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s2, full_s, atol=2e-4, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50), chunk=st.sampled_from([8, 16, 32]))
def test_rwkv6_property_random(seed, chunk):
    b, h, s, dk, dv = 1, 1, 64, 8, 8
    r, k, v, w, u = wkv_inputs(jax.random.PRNGKey(seed), b, h, s, dk, dv)
    got_o, _ = rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    want_o = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(got_o, want_o, atol=3e-4, rtol=3e-4)
