"""Device-side epoch planning tests (PR 6).

Three layers: (1) the T-CSR samplers — the pure-jnp oracle
(``kernels.ref.sample_ref``) and the Pallas kernel body on the interpret
backend — must match ``ChronoNeighborIndex.sample`` bit-for-bit on crafted
edge cases (degree-0 nodes, every-neighbor-newer-than-the-boundary,
K larger than any degree, out-of-core builds with empty chunks);
(2) the trainers — ``train_single`` / ``train_sharded`` / ``pac_train``
with ``plan="device"`` must be bit-identical to host planning (losses,
params, memory, metrics); (3) the supporting utilities — the shared LRU
(``tig.cache.lru_get``), the prefetcher context manager, and the roofline
H2D model's host-vs-device ordering.
"""

import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.neighbor_sample import neighbor_sample_fwd
from repro.kernels.ref import sample_ref
from repro.roofline.kernel_bytes import epoch_plan_bytes, sample_bytes
from repro.tig.cache import lru_get
from repro.tig.data import synthetic_tig
from repro.tig.models import TIGConfig
from repro.tig.sampler import ChronoNeighborIndex
from repro.tig.stream import EpochPrefetcher, write_graph_shards
from repro.tig.train import train_single, train_sharded

CFG = TIGConfig(dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=128)


def _device_sample(index, nodes, batch_of, *, backend):
    tcsr = {k: jnp.asarray(v) for k, v in index.device_export().items()}
    nodes = jnp.asarray(nodes, jnp.int32)
    batch_of = jnp.asarray(batch_of, jnp.int32)
    if backend == "interpret":
        out = neighbor_sample_fwd(
            tcsr["indptr"], tcsr["nbr"], tcsr["t"], tcsr["eidx"],
            tcsr["bat"], nodes, batch_of, k=index.k, interpret=True)
    else:
        out = ops.neighbor_sample(tcsr, nodes, batch_of, index.k,
                                  backend=backend)
    return tuple(np.asarray(x) for x in out)


def _assert_matches_host(index, nodes, batch_of):
    """Both device samplers == the host index, including the f64->f32 cast
    the export applies to times (the engine grids are f32 either way)."""
    hb, ht, he = index.sample(np.asarray(nodes, np.int64),
                              np.asarray(batch_of))
    for backend in ("xla", "interpret"):
        db, dt, de = _device_sample(index, nodes, batch_of, backend=backend)
        np.testing.assert_array_equal(db, hb, err_msg=backend)
        np.testing.assert_array_equal(de, he, err_msg=backend)
        np.testing.assert_array_equal(dt, ht.astype(np.float32),
                                      err_msg=backend)


# ------------------------------------------------------ T-CSR edge cases


def _crafted_index(k=4, batch_size=2):
    """8 nodes; node 7 has degree 0; node 0 appears only in the LAST batch
    (all neighbors newer than any earlier boundary); node 1 has degree 1
    (< K); node 2 is a hub with degree > K."""
    src = np.array([2, 2, 2, 2, 2, 1, 3, 0])
    dst = np.array([3, 4, 5, 6, 4, 2, 2, 2])
    t = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0])
    eidx = np.arange(len(src))
    return ChronoNeighborIndex(src, dst, t, eidx, 8, k, batch_size)


def test_sampler_edge_cases_match_host():
    index = _crafted_index()
    nodes = np.array([0, 1, 2, 3, 4, 5, 6, 7, 0, 2])
    for b in range(index.num_batches):
        _assert_matches_host(index, nodes, b)
    # per-row batch indices (the engine's fused 3B-row call shape)
    per_row = np.arange(len(nodes)) % index.num_batches
    _assert_matches_host(index, nodes, per_row)


def test_sampler_degree_zero_and_all_newer_rows_are_fill():
    index = _crafted_index()
    for backend in ("xla", "interpret"):
        ids, tms, eix = _device_sample(index, [7, 0], 0, backend=backend)
        np.testing.assert_array_equal(ids, -1)      # degree 0 / all newer
        np.testing.assert_array_equal(eix, -1)
        np.testing.assert_array_equal(tms, -1.0)


def test_sampler_k_larger_than_any_degree():
    src = np.array([0, 1]); dst = np.array([1, 2])
    t = np.array([1.0, 2.0]); eidx = np.arange(2)
    index = ChronoNeighborIndex(src, dst, t, eidx, 3, 8, 1)
    nodes = np.array([0, 1, 2])
    for b in range(index.num_batches):
        _assert_matches_host(index, nodes, b)


def test_sampler_empty_stream():
    empty = np.array([], dtype=np.int64)
    index = ChronoNeighborIndex(empty, empty, empty.astype(float), empty,
                                5, 3, 4)
    _assert_matches_host(index, np.array([0, 2, 4]), 0)


def test_sampler_from_chunks_with_empty_shard():
    src = np.array([2, 2, 2, 2, 2, 1, 3, 0])
    dst = np.array([3, 4, 5, 6, 4, 2, 2, 2])
    t = np.arange(1.0, 9.0)
    eidx = np.arange(8)
    one_shot = ChronoNeighborIndex(src, dst, t, eidx, 8, 4, 2)
    empty = np.array([], dtype=np.int64)
    chunks = [
        (src[:3], dst[:3], t[:3], eidx[:3]),
        (empty, empty, empty.astype(float), empty),      # empty shard
        (src[3:], dst[3:], t[3:], eidx[3:]),
    ]
    chunked = ChronoNeighborIndex.from_chunks(chunks, 8, 4, 2)
    for key, a in one_shot.device_export().items():
        np.testing.assert_array_equal(chunked.device_export()[key], a,
                                      err_msg=key)
    nodes = np.arange(8)
    for b in range(chunked.num_batches):
        _assert_matches_host(chunked, nodes, b)


def test_device_export_composes_by_offset():
    """Two exports concatenated with offset indptr (the PAC flat layout)
    sample identically to each export alone."""
    ia, ib = _crafted_index(), _crafted_index(k=4, batch_size=2)
    ea, eb = ia.device_export(), ib.device_export()
    base = np.int32(len(ea["nbr"]))
    flat = {k: np.concatenate([ea[k], eb[k]])
            for k in ("nbr", "t", "eidx", "bat")}
    ref_ids, ref_t, ref_e = sample_ref(
        ea["indptr"], ea["nbr"], ea["t"], ea["eidx"], ea["bat"],
        jnp.arange(8, dtype=jnp.int32), jnp.int32(1), 4)
    ids, tms, eix = sample_ref(
        eb["indptr"] + base, flat["nbr"], flat["t"], flat["eidx"],
        flat["bat"], jnp.arange(8, dtype=jnp.int32), jnp.int32(1), 4)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    np.testing.assert_array_equal(np.asarray(tms), np.asarray(ref_t))
    np.testing.assert_array_equal(np.asarray(eix), np.asarray(ref_e))


# --------------------------------------------- trainer host/device parity


def _tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_train_single_device_plan_bit_identical():
    g = synthetic_tig("tiny", seed=3)
    a = train_single(g, CFG, epochs=2, seed=0, plan="host")
    b = train_single(g, CFG, epochs=2, seed=0, plan="device")
    assert a.losses == b.losses
    assert a.val_ap == b.val_ap and a.test_ap == b.test_ap
    assert (a.test_ap_inductive == b.test_ap_inductive
            or (np.isnan(a.test_ap_inductive)
                and np.isnan(b.test_ap_inductive)))
    _tree_equal(a.params, b.params)
    _tree_equal(a.state, b.state)


def test_train_sharded_device_plan_bit_identical(tmp_path):
    g = synthetic_tig("tiny", seed=3)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=313)
    kw = dict(epochs=2, protocol=True, patience=2, seed=0)
    a = train_sharded(sh, CFG, plan="host", **kw)
    b = train_sharded(sh, CFG, plan="device", **kw)
    assert a.losses == b.losses and a.val_curve == b.val_curve
    assert a.best_epoch == b.best_epoch
    for key, v in a.metrics.items():
        w = b.metrics[key]
        assert (np.isnan(v) and np.isnan(w)) or v == w, key


def test_pac_train_device_plan_bit_identical():
    from repro.core import sep_partition
    from repro.tig.distributed import pac_train
    from repro.tig.graph import chronological_split

    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50)
    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, 4, k=0.05)
    kw = dict(num_devices=4, epochs=2, lr=2e-3, shuffle_parts=False)
    a = pac_train(train_g, part, cfg, plan="host", **kw)
    b = pac_train(train_g, part, cfg, plan="device", **kw)
    for la, lb in zip(a.losses, b.losses):
        np.testing.assert_array_equal(la, lb)
    _tree_equal(a.params, b.params)
    _tree_equal(a.memory_states, b.memory_states)


def test_pac_train_rejects_device_plan_with_host_replay():
    from repro.tig.distributed import plan_epoch

    g = synthetic_tig("tiny", seed=0)
    with pytest.raises(ValueError, match="host_replay"):
        plan_epoch(g, [np.arange(g.num_nodes)], np.zeros(0, np.int64),
                   CFG, np.random.default_rng(0), host_replay=True,
                   plan="device")


def test_build_batch_program_plan_validation():
    from repro.tig.batching import build_batch_program
    from repro.tig.train import graph_as_stream

    g = synthetic_tig("tiny", seed=0)
    stream, _ = graph_as_stream(g)
    with pytest.raises(ValueError, match="plan="):
        build_batch_program(stream, CFG, np.random.default_rng(0),
                            plan="gpu")
    batches, _ = build_batch_program(stream, CFG, np.random.default_rng(0),
                                     plan="device")
    assert not any(k.startswith("nbr") for k in batches)
    assert {"src", "dst", "neg", "t", "eidx", "valid"} <= set(batches)


# ----------------------------------------------------------- lru_get


def test_lru_get_builds_once_and_moves_hits_to_back():
    cache, built = {}, []

    def make(v):
        return lambda: built.append(v) or v

    for v in ("a", "b", "c"):
        assert lru_get(cache, v, 3, make(v)) == v
    assert lru_get(cache, "a", 3, make("a")) == "a"      # hit, no rebuild
    assert built == ["a", "b", "c"]
    # "b" is now least-recently-used; inserting "d" evicts it
    lru_get(cache, "d", 3, make("d"))
    assert list(cache) == ["c", "a", "d"]
    lru_get(cache, "b", 3, make("b"))
    assert built == ["a", "b", "c", "d", "b"]
    assert list(cache) == ["a", "d", "b"]


def test_lru_get_max_size_one():
    cache = {}
    assert lru_get(cache, 1, 1, lambda: "x") == "x"
    assert lru_get(cache, 2, 1, lambda: "y") == "y"
    assert list(cache) == [2]


# ------------------------------------------- prefetcher context manager


def test_prefetcher_context_manager_joins_on_exception():
    started = threading.Event()
    release = threading.Event()
    workers = []

    def build(i):
        if i == 1:                      # the in-flight prefetched epoch
            workers.append(threading.current_thread())
            started.set()
            release.wait(timeout=10)
        return i

    pf = EpochPrefetcher(build, 4, enabled=True)
    with pytest.raises(RuntimeError, match="boom"):
        with pf as entered:
            assert entered is pf
            assert pf.get(0) == 0       # kicks off epoch 1 on the worker
            assert started.wait(timeout=10)
            release.set()
            raise RuntimeError("boom")
    # __exit__ must have joined the worker and dropped pending epochs
    assert pf._worker is None and pf._futures == {}
    assert workers and not workers[0].is_alive()


def test_prefetcher_context_manager_plain_use():
    with EpochPrefetcher(lambda i: i * i, 3, enabled=True) as pf:
        assert [pf.get(i) for i in range(3)] == [0, 1, 4]
    assert pf._worker is None and pf._futures == {}


# ------------------------------------------------------- roofline model


def test_epoch_plan_bytes_device_strictly_below_host():
    for steps, batch, k, n, ev in ((118, 100, 5, 9227, 2 * 11_000),
                                   (1000, 200, 10, 100_000, 2_000_000)):
        m = epoch_plan_bytes(steps, batch, k, n, ev)
        assert m["device"] < m["host"]
        assert m["host"] == sum(m["host_detail"].values())
        assert m["device"] == sum(m["device_detail"].values())
        # records are shipped by BOTH plans; only the grids/T-CSR differ
        assert m["host_detail"]["records"] == m["device_detail"]["records"]


def test_sample_bytes_itemization():
    ob = sample_bytes(rows=300, k=5, total_events=22_000)
    assert ob.total == ob.read_bytes + ob.write_bytes > 0
    assert set(ob.writes) == {"ids", "times", "eidx"}
    # probe traffic grows with log2(events), window traffic with K
    assert sample_bytes(300, 5, 1 << 20).reads["bisect_probes"] > \
        ob.reads["bisect_probes"]
    assert sample_bytes(300, 10, 22_000).reads["nbr_window"] == \
        2 * ob.reads["nbr_window"]
