"""Tests for optimizer, schedules, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import LMDataConfig, packed_batches
from repro.optim import (
    adamw,
    clip_by_global_norm,
    constant_schedule,
    cosine_decay_schedule,
    linear_warmup_cosine,
    sgd,
)


# ---------------------------------------------------------------- optimizer

def quadratic(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1) ** 2)


def test_adamw_converges_quadratic():
    params = {"w": jnp.zeros(4), "b": jnp.zeros(3)}
    opt = adamw(lr=0.1)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(quadratic)(p)
        return opt.apply(g, s, p)

    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(params["w"], 3.0, atol=1e-2)
    np.testing.assert_allclose(params["b"], -1.0, atol=1e-2)


def test_weight_decay_shrinks():
    params = {"w": jnp.ones(4) * 10}
    opt = adamw(lr=0.1, weight_decay=0.1)
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(4)}
    p2, _ = opt.apply(zero_g, state, params)
    assert float(p2["w"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(100) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_sgd_momentum_converges():
    params = {"w": jnp.zeros(2)}
    opt = sgd(lr=0.05, momentum=0.9)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state = opt.apply(g, state, params)
    np.testing.assert_allclose(params["w"], 1.0, atol=1e-3)


def test_schedules_shapes_and_monotonicity():
    s1 = constant_schedule(1e-3)
    assert float(s1(jnp.asarray(100))) == pytest.approx(1e-3)
    s2 = cosine_decay_schedule(1.0, 100)
    assert float(s2(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(s2(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    s3 = linear_warmup_cosine(1.0, 10, 100)
    assert float(s3(jnp.asarray(5))) == pytest.approx(0.5)
    vals = [float(s3(jnp.asarray(t))) for t in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


# ------------------------------------------------------------- data pipeline

def test_packed_batches_shapes_and_alignment():
    cfg = LMDataConfig(vocab=128, seq_len=32, global_batch=4)
    it = packed_batches(cfg)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 32)
    assert b1["targets"].shape == (4, 32)
    # next-token alignment: targets are tokens shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
    assert b1["tokens"].max() < 128
    b2 = next(it)
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_corpus_has_learnable_structure():
    """Phrase reuse => repeated bigrams far above uniform chance."""
    cfg = LMDataConfig(vocab=1024, seq_len=256, global_batch=8)
    b = next(packed_batches(cfg))
    toks = b["tokens"].ravel()
    bigrams = set(zip(toks[:-1].tolist(), toks[1:].tolist()))
    # with heavy phrase reuse, distinct bigrams << total positions
    assert len(bigrams) < 0.8 * (len(toks) - 1)


# ------------------------------------------------------------- checkpointing

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones(4)},
        "opt": [jnp.zeros(2), jnp.full((2, 2), 7.0)],
    }
    d = str(tmp_path)
    save_checkpoint(d, 42, tree, metadata={"note": "test"})
    assert latest_step(d) == 42
    target = jax.tree.map(jnp.zeros_like, tree)
    restored = restore_checkpoint(d, 42, target)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, {"w": jnp.zeros(4)})


def test_checkpoint_multiple_steps(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 5):
        save_checkpoint(d, s, {"w": jnp.full(2, float(s))})
    assert latest_step(d) == 20
    r = restore_checkpoint(d, 20, {"w": jnp.zeros(2)})
    np.testing.assert_array_equal(r["w"], [20.0, 20.0])


# ------------------------------------------------------------------ sampling

def test_sampling_modes():
    import jax
    from repro.models.sampling import sample_tokens
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)))
    greedy = sample_tokens(key, logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    # top-k restricts support to the k best logits
    tk = sample_tokens(key, logits, temperature=1.0, top_k=5)
    kth = jax.lax.top_k(logits, 5)[0][:, -1]
    chosen = jnp.take_along_axis(logits, tk[:, None], 1)[:, 0]
    assert bool((chosen >= kth).all())
    # top-p never picks below the nucleus cutoff
    tp = sample_tokens(key, logits, temperature=0.7, top_p=0.5)
    assert tp.shape == (8,)
    # different keys -> different draws (at temperature)
    a = sample_tokens(jax.random.PRNGKey(1), logits, temperature=2.0)
    b = sample_tokens(jax.random.PRNGKey(2), logits, temperature=2.0)
    assert not np.array_equal(np.asarray(a), np.asarray(b))
