"""End-to-end behaviour test: the full SPEED pipeline of the paper.

synthetic TIG -> chronological split -> SEP partitioning -> PAC multi-device
training (loop-within-epoch, memory backup/restore, shared-node sync,
shuffle-combine) -> downstream evaluation -- all on CPU at reduced scale.
"""

import numpy as np

from repro.core import (
    partition_stats,
    sep_partition,
    thm1_rf_bound,
)
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import evaluate_params, train_single


def test_speed_pipeline_end_to_end():
    g = synthetic_tig("small", seed=42)
    train_g, val_g, test_g, _ = chronological_split(g)

    # --- SEP: partition the training stream into 8 small parts ----------
    k = 0.05
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, 8, k=k)
    stats = partition_stats(part)
    from repro.core import replication_factor
    assert replication_factor(part, denominator="all") <= thm1_rf_bound(
        np.ceil(k * g.num_nodes) / g.num_nodes, 8) + 1e-9
    assert stats.edge_cut < 0.5

    # --- PAC: shuffle-combine 8 -> 4 devices, 2 epochs ------------------
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=32,
                    dim_node=32, num_neighbors=4, batch_size=100)
    res = pac_train(train_g, part, cfg, num_devices=4, epochs=2,
                    lr=2e-3, shuffle_parts=True)
    per_epoch = res.mean_loss_per_epoch()
    assert per_epoch[-1] <= per_epoch[0] + 0.05
    assert res.derived_speedup > 2.0

    # --- downstream: PAC-trained params stay competitive ----------------
    ev = evaluate_params(g, cfg, res.params)
    assert np.isfinite(ev["val_ap"]) and ev["test_ap"] > 0.55


def test_single_device_baseline_trains():
    g = synthetic_tig("tiny", seed=13)
    cfg = TIGConfig(flavor="tige", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=64)
    res = train_single(g, cfg, epochs=2)
    assert res.losses[-1] < res.losses[0] + 0.05
    assert res.test_ap > 0.5
