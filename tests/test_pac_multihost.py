"""Pod-scale PAC tests: row-range-sharded grid layout parity against the
replicated oracle, per-process (local_ranks) planning, and a real
2-process CPU cluster (gloo + ``jax.distributed.initialize``) compared to
the single-process shard_map path."""

import os
import socket
import subprocess
import sys

import numpy as np
import pytest

from repro.core import sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train, plan_epoch
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import time_scale_of

CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=50)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def setup_case(seed=0, num_parts=4, k=0.05, name="tiny"):
    g = synthetic_tig(name, seed=seed)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, num_parts, k=k)
    return g, train_g, part


def test_sharded_layout_is_bit_identical_to_replicated():
    """The acceptance oracle: the row-range-sharded grid layout must be
    EXACTLY equal (not allclose) to the replicated flat layout — metrics,
    params and memory — across 2 epochs with a shuffle-combine resync."""
    g, train_g, part = setup_case(num_parts=8)
    kw = dict(num_devices=4, epochs=2, seed=0, shuffle_parts=True,
              plan="device", eval_graph=g)
    rep = pac_train(train_g, part, CFG, grid_layout="replicated", **kw)
    shd = pac_train(train_g, part, CFG, grid_layout="sharded", **kw)

    for la, lb in zip(rep.losses, shd.losses):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(shd.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for key in ("mem", "mem2", "last"):
        np.testing.assert_array_equal(rep.memory_states[key],
                                      shd.memory_states[key])
    assert rep.metrics and sorted(rep.metrics) == sorted(shd.metrics)
    for key in rep.metrics:
        np.testing.assert_array_equal(np.asarray(rep.metrics[key]),
                                      np.asarray(shd.metrics[key]))
    # and the sharded layout is why: each device's H2D input is a strict
    # subset of the replicated broadcast
    assert shd.plan.device_input_bytes() < rep.plan.device_input_bytes()


def test_local_ranks_plan_matches_full_plan_rows():
    """A process planning only its own devices (local_ranks) must derive
    row-for-row the same sharded plan as full planning — the multi-host
    staging contract — while materializing fewer bytes."""
    g, train_g, part = setup_case()
    scale = time_scale_of(train_g.t)

    def plan(**kw):
        return plan_epoch(train_g, part.node_lists(), part.shared_nodes,
                          CFG, np.random.default_rng(0), time_scale=scale,
                          plan="device", **kw)

    full = plan(layout="sharded")
    assert full.layout == "sharded"
    assert (full.offsets == 0).all()
    rows_cap = int(full.n_batches.max())
    assert full.batches["src"].shape[:2] == (4, rows_cap)

    for ranks in ([0, 1], [2, 3], [1]):
        local = plan(layout="sharded", local_ranks=ranks)
        # global schedule is identical on every process
        np.testing.assert_array_equal(local.n_batches, full.n_batches)
        np.testing.assert_array_equal(local.edges_per_device,
                                      full.edges_per_device)
        assert local.steps == full.steps
        assert local.capacity == full.capacity
        np.testing.assert_array_equal(local.local_ranks, ranks)
        # materialized rows are exactly the full plan's rows for `ranks`
        for key in full.batches:
            np.testing.assert_array_equal(local.batches[key],
                                          full.batches[key][ranks])
        for key in full.tcsr:
            np.testing.assert_array_equal(local.tcsr[key],
                                          full.tcsr[key][ranks])
        np.testing.assert_array_equal(local.nfeat_local,
                                      full.nfeat_local[ranks])
        np.testing.assert_array_equal(local.efeat_local,
                                      full.efeat_local[ranks])
        assert local.plan_bytes() == full.plan_bytes() * len(ranks) // 4

    with pytest.raises(ValueError):
        plan(layout="replicated", local_ranks=[0, 1])
    with pytest.raises(ValueError):
        plan(layout="sharded", host_replay=True)


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _cluster_cmd(out, *, num_processes, process_id, local_devices, port,
                 epoch_boundary="overlap"):
    return [sys.executable, "-u", "-m", "repro.launch.pac_cluster",
            "--num-processes", str(num_processes),
            "--process-id", str(process_id),
            "--coordinator", f"127.0.0.1:{port}",
            "--local-devices", str(local_devices),
            "--epochs", "2", "--parts", "8", "--seed", "0",
            "--grid-layout", "sharded",
            "--epoch-boundary", epoch_boundary, "--out", str(out)]


def test_two_process_cluster_matches_single_process(tmp_path):
    """Spawn a real 2-process CPU cluster (2 devices per process, gloo
    collectives) and compare against the single-process 4-device shard_map
    path.  The two processes must agree bit-for-bit with each other;
    against the single process, protocol metrics are bit-identical and
    params/losses/memory agree to collective-reduction-order tolerance
    (gloo vs single-process XLA reductions associate differently).

    The cluster runs the PR 9 async boundary (``--epoch-boundary
    overlap``, the default: split scan+sync, deferred loss drain across
    real processes) while the single-process comparison runs the fused
    serial oracle — so this comparison is also the cross-process
    pipelined-vs-serial parity case."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)

    outs = [tmp_path / "p0.npz", tmp_path / "p1.npz"]
    for attempt in range(2):
        port = _free_port()
        procs = [
            subprocess.Popen(
                _cluster_cmd(outs[pid], num_processes=2, process_id=pid,
                             local_devices=2, port=port),
                cwd=REPO, env=env, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True)
            for pid in range(2)
        ]
        logs = []
        try:
            for p in procs:
                out, _ = p.communicate(timeout=600)
                logs.append(out)
        except subprocess.TimeoutExpired:
            for p in procs:
                p.kill()
            raise
        if any(p.returncode == 17 or "CLUSTER_UNAVAILABLE" in log
               for p, log in zip(procs, logs)):
            pytest.skip(f"CPU cluster unavailable: {logs[0][-500:]}")
        if all(p.returncode == 0 for p in procs):
            break
        if any(p.returncode > 0 for p in procs):  # a real error, not a
            break                                 # coordinator signal-kill
    if (any(p.returncode < 0 for p in procs)
            and not any(p.returncode > 0 for p in procs)):
        pytest.skip("cluster killed by coordinator twice (startup-skew "
                    f"flake): {[p.returncode for p in procs]}")
    for p, log in zip(procs, logs):
        assert p.returncode == 0, log[-3000:]

    single_out = tmp_path / "single.npz"
    proc = subprocess.run(
        _cluster_cmd(single_out, num_processes=1, process_id=0,
                     local_devices=4, port=_free_port(),
                     epoch_boundary="serial"),
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]

    p0 = np.load(outs[0])
    p1 = np.load(outs[1])
    sg = np.load(single_out)
    assert sorted(p0.files) == sorted(p1.files) == sorted(sg.files)
    # SPMD: both processes hold the same replicated result, bit-for-bit
    for key in p0.files:
        np.testing.assert_array_equal(p0[key], p1[key], err_msg=key)
    for key in sg.files:
        if key.startswith("metric_"):
            np.testing.assert_array_equal(p0[key], sg[key], err_msg=key)
        else:
            np.testing.assert_allclose(p0[key], sg[key], atol=1e-4,
                                       err_msg=key)
