"""Property tests: the vectorized ``ChronoNeighborIndex`` must be an exact
drop-in for replaying the old ``RecentNeighborBuffer`` sample/update loop —
same ids / times / edge indices, same oldest->newest order, same -1 padding —
including repeated-node batches, tied timestamps, and history continuation.
"""

import numpy as np
import pytest

from repro.tig.batching import LocalStream, build_batch_program
from repro.tig.models import TIGConfig
from repro.tig.sampler import (
    ChronoNeighborIndex,
    NeighborSnapshot,
    RecentNeighborBuffer,
)


def random_stream(rng, n_nodes, n_edges, t_lo=0, t_hi=10):
    """Chronological stream with heavy node repetition and tied times."""
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    t = np.sort(rng.integers(t_lo, t_hi, n_edges).astype(np.float64))
    eidx = np.arange(n_edges, dtype=np.int64)
    return src, dst, t, eidx


def replay_equal(src, dst, t, eidx, n_nodes, k, b,
                 history=None, buf=None):
    """Assert batch-by-batch equality of index sampling vs buffer replay."""
    idx = ChronoNeighborIndex(src, dst, t, eidx, n_nodes, k, b,
                              history=history)
    buf = buf or RecentNeighborBuffer(n_nodes, k)
    nodes = np.arange(n_nodes)
    for bi in range(max(1, -(-len(src) // b))):
        lo, hi = bi * b, min((bi + 1) * b, len(src))
        want = buf.sample(nodes)
        got = idx.sample(nodes, bi)
        for w, g, name in zip(want, got, ("ids", "times", "eidx")):
            np.testing.assert_array_equal(g, w, err_msg=f"batch {bi} {name}")
        buf.update(src[lo:hi], dst[lo:hi], t[lo:hi], eidx[lo:hi])
    snap = idx.final_snapshot()
    ref = buf.snapshot()
    np.testing.assert_array_equal(snap.nbr, ref.nbr)
    np.testing.assert_array_equal(snap.time, ref.time)
    np.testing.assert_array_equal(snap.eidx, ref.eidx)
    return snap, buf


@pytest.mark.parametrize("seed", range(8))
def test_index_equals_ring_buffer_replay(seed):
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 20))
    n_edges = int(rng.integers(1, 120))
    k = int(rng.integers(1, 6))
    b = int(rng.integers(1, 12))
    src, dst, t, eidx = random_stream(rng, n_nodes, n_edges)
    replay_equal(src, dst, t, eidx, n_nodes, k, b)


@pytest.mark.parametrize("seed", range(4))
def test_index_history_continuation(seed):
    """val/test continuation: an index built with the train-split snapshot
    must keep matching a ring buffer that never stopped."""
    rng = np.random.default_rng(100 + seed)
    n_nodes, k, b = 15, 3, 7
    src, dst, t, eidx = random_stream(rng, n_nodes, 60)
    snap, buf = replay_equal(src, dst, t, eidx, n_nodes, k, b)
    src2, dst2, t2, e2 = random_stream(rng, n_nodes, 40, t_lo=10, t_hi=20)
    e2 = e2 + len(src)
    replay_equal(src2, dst2, t2, e2, n_nodes, k, b,
                 history=snap, buf=buf)


def test_index_no_future_leakage():
    """A sample at batch bi must only contain edges from earlier batches."""
    rng = np.random.default_rng(7)
    n_nodes, n_edges, k, b = 10, 80, 4, 9
    src, dst, t, eidx = random_stream(rng, n_nodes, n_edges)
    idx = ChronoNeighborIndex(src, dst, t, eidx, n_nodes, k, b)
    for bi in range(-(-n_edges // b)):
        _, _, eix = idx.sample(np.arange(n_nodes), bi)
        real = eix[eix >= 0]
        assert (real < bi * b).all(), f"future edge leaked into batch {bi}"
    # before anything streamed: completely empty
    ids, tms, eix = idx.sample(np.arange(n_nodes), 0)
    assert (ids == -1).all() and (tms == -1.0).all() and (eix == -1).all()


def test_build_batch_program_matches_per_batch_sampling():
    """The fully pre-staged (steps, ...) program must contain exactly the
    neighbors the old sample-then-update per-batch loop produced."""
    rng = np.random.default_rng(3)
    n_nodes, n_edges, k, b = 18, 75, 4, 10
    src, dst, t, eidx = random_stream(rng, n_nodes, n_edges)
    stream = LocalStream(src=src, dst=dst, t=t.astype(np.float64),
                         eidx=eidx, num_local_nodes=n_nodes)
    cfg = TIGConfig(flavor="tgn", dim=8, dim_time=4, dim_edge=4, dim_node=4,
                    num_neighbors=k, batch_size=b)
    stacked, _ = build_batch_program(stream, cfg, np.random.default_rng(0))
    steps = stacked["src"].shape[0]
    assert steps == -(-n_edges // b)

    buf = RecentNeighborBuffer(n_nodes, k)
    for bi in range(steps):
        lo, hi = bi * b, min((bi + 1) * b, n_edges)
        for role in ("src", "dst", "neg"):
            ids = stacked[role][bi]
            valid = stacked["valid"][bi]
            alive = (ids >= 0) & valid
            clean = np.where(alive, ids, 0)
            nb, nt, ne = buf.sample(clean)
            nb[~alive] = -1
            ne[~alive] = -1
            np.testing.assert_array_equal(stacked[f"nbr_{role}"][bi], nb)
            np.testing.assert_array_equal(stacked[f"nbre_{role}"][bi], ne)
            np.testing.assert_allclose(stacked[f"nbrt_{role}"][bi],
                                       nt.astype(np.float32))
        buf.update(src[lo:hi], dst[lo:hi], t[lo:hi], eidx[lo:hi])


def test_empty_history_equals_no_history():
    rng = np.random.default_rng(11)
    src, dst, t, eidx = random_stream(rng, 8, 30)
    a = ChronoNeighborIndex(src, dst, t, eidx, 8, 3, 5)
    b = ChronoNeighborIndex(src, dst, t, eidx, 8, 3, 5,
                            history=NeighborSnapshot.empty(8, 3))
    ga = a.sample(np.arange(8), 3)
    gb = b.sample(np.arange(8), 3)
    for x, y in zip(ga, gb):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------------- hypothesis sweep
# guarded per-test (not importorskip) so the deterministic tests above
# still run when the optional dependency is absent

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000),
           n_nodes=st.integers(1, 25),
           n_edges=st.integers(0, 90),
           k=st.integers(1, 7),
           b=st.integers(1, 13),
           t_hi=st.integers(1, 8))
    def test_index_equivalence_property(seed, n_nodes, n_edges, k, b, t_hi):
        rng = np.random.default_rng(seed)
        src, dst, t, eidx = random_stream(rng, n_nodes, n_edges, t_hi=t_hi)
        replay_equal(src, dst, t, eidx, n_nodes, k, b)


# ---------------------------------------------------- chunked T-CSR build

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_from_chunks_equals_one_shot(seed):
    """The out-of-core counting-sort build must produce the one-shot
    constructor's arrays verbatim, for arbitrary chunk boundaries, tied
    timestamps, and history continuation."""
    rng = np.random.default_rng(seed)
    n, e = int(rng.integers(3, 40)), int(rng.integers(0, 800))
    src, dst, t, eidx = random_stream(rng, n, e)
    k = int(rng.integers(1, 6))
    b = int(rng.integers(1, 50))
    hist = None
    if seed % 2:
        buf = RecentNeighborBuffer(n, k)
        hs, hd, ht, he = random_stream(rng, n, 64, t_lo=-100, t_hi=-50)
        buf.update(hs, hd, ht, he)
        hist = buf.snapshot()
    one = ChronoNeighborIndex(src, dst, t, eidx, n, k, b, history=hist)
    n_chunks = int(rng.integers(1, 7))
    cuts = np.sort(rng.integers(0, e + 1, n_chunks - 1)).tolist()
    bounds = [0, *cuts, e]
    chunks = [(src[a:c], dst[a:c], t[a:c], eidx[a:c])
              for a, c in zip(bounds[:-1], bounds[1:])]
    two = ChronoNeighborIndex.from_chunks(chunks, n, k, b, history=hist)
    for f in ("_nbr", "_t", "_e", "_bkey", "_indptr"):
        np.testing.assert_array_equal(
            getattr(one, f), getattr(two, f), err_msg=f)
    assert one.num_batches == two.num_batches
    q = rng.integers(0, n, 32)
    b_of = rng.integers(0, one.num_batches + 1, 32)
    for a_, b_ in zip(one.sample(q, b_of), two.sample(q, b_of)):
        np.testing.assert_array_equal(a_, b_)


def test_from_chunks_callable_factory():
    """A zero-arg chunk factory (the out-of-core path) is re-iterated for
    each pass and matches the sequence form."""
    rng = np.random.default_rng(9)
    src, dst, t, eidx = random_stream(rng, 20, 300)
    chunks = [(src[a:a + 77], dst[a:a + 77], t[a:a + 77], eidx[a:a + 77])
              for a in range(0, 300, 77)]
    a = ChronoNeighborIndex.from_chunks(chunks, 20, 4, 13)
    b = ChronoNeighborIndex.from_chunks(lambda: iter(chunks), 20, 4, 13)
    for f in ("_nbr", "_t", "_e", "_bkey", "_indptr"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


def test_build_batch_program_accepts_prebuilt_index():
    rng = np.random.default_rng(4)
    src, dst, t, eidx = random_stream(rng, 25, 400)
    cfg = TIGConfig(dim=8, dim_time=4, dim_edge=4, dim_node=4,
                    num_neighbors=3, batch_size=32)
    stream = LocalStream(src=src, dst=dst, t=t, eidx=eidx,
                         num_local_nodes=25)
    idx = ChronoNeighborIndex.from_chunks(
        [(src, dst, t, eidx)], 25, cfg.num_neighbors, cfg.batch_size)
    a, _ = build_batch_program(stream, cfg, np.random.default_rng(0))
    b, _ = build_batch_program(stream, cfg, np.random.default_rng(0),
                               index=idx)
    for key in a:
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)
    with pytest.raises(ValueError):
        build_batch_program(stream, cfg, np.random.default_rng(0),
                            index=idx, history=NeighborSnapshot.empty(25, 3))


def test_from_chunks_accepts_one_shot_generator():
    """Regression: a plain generator must not leave the index uninitialized
    (both counting passes need every chunk)."""
    rng = np.random.default_rng(12)
    src, dst, t, eidx = random_stream(rng, 15, 200)
    chunks = [(src[a:a + 64], dst[a:a + 64], t[a:a + 64], eidx[a:a + 64])
              for a in range(0, 200, 64)]
    a = ChronoNeighborIndex.from_chunks(chunks, 15, 3, 10)
    b = ChronoNeighborIndex.from_chunks((c for c in chunks), 15, 3, 10)
    for f in ("_nbr", "_t", "_e", "_bkey", "_indptr"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
