"""Property tests: the vectorized ``ChronoNeighborIndex`` must be an exact
drop-in for replaying the old ``RecentNeighborBuffer`` sample/update loop —
same ids / times / edge indices, same oldest->newest order, same -1 padding —
including repeated-node batches, tied timestamps, and history continuation.
"""

import numpy as np
import pytest

from repro.tig.batching import LocalStream, build_batch_program
from repro.tig.models import TIGConfig
from repro.tig.sampler import (
    ChronoNeighborIndex,
    NeighborSnapshot,
    RecentNeighborBuffer,
)


def random_stream(rng, n_nodes, n_edges, t_lo=0, t_hi=10):
    """Chronological stream with heavy node repetition and tied times."""
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    t = np.sort(rng.integers(t_lo, t_hi, n_edges).astype(np.float64))
    eidx = np.arange(n_edges, dtype=np.int64)
    return src, dst, t, eidx


def replay_equal(src, dst, t, eidx, n_nodes, k, b,
                 history=None, buf=None):
    """Assert batch-by-batch equality of index sampling vs buffer replay."""
    idx = ChronoNeighborIndex(src, dst, t, eidx, n_nodes, k, b,
                              history=history)
    buf = buf or RecentNeighborBuffer(n_nodes, k)
    nodes = np.arange(n_nodes)
    for bi in range(max(1, -(-len(src) // b))):
        lo, hi = bi * b, min((bi + 1) * b, len(src))
        want = buf.sample(nodes)
        got = idx.sample(nodes, bi)
        for w, g, name in zip(want, got, ("ids", "times", "eidx")):
            np.testing.assert_array_equal(g, w, err_msg=f"batch {bi} {name}")
        buf.update(src[lo:hi], dst[lo:hi], t[lo:hi], eidx[lo:hi])
    snap = idx.final_snapshot()
    ref = buf.snapshot()
    np.testing.assert_array_equal(snap.nbr, ref.nbr)
    np.testing.assert_array_equal(snap.time, ref.time)
    np.testing.assert_array_equal(snap.eidx, ref.eidx)
    return snap, buf


@pytest.mark.parametrize("seed", range(8))
def test_index_equals_ring_buffer_replay(seed):
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 20))
    n_edges = int(rng.integers(1, 120))
    k = int(rng.integers(1, 6))
    b = int(rng.integers(1, 12))
    src, dst, t, eidx = random_stream(rng, n_nodes, n_edges)
    replay_equal(src, dst, t, eidx, n_nodes, k, b)


@pytest.mark.parametrize("seed", range(4))
def test_index_history_continuation(seed):
    """val/test continuation: an index built with the train-split snapshot
    must keep matching a ring buffer that never stopped."""
    rng = np.random.default_rng(100 + seed)
    n_nodes, k, b = 15, 3, 7
    src, dst, t, eidx = random_stream(rng, n_nodes, 60)
    snap, buf = replay_equal(src, dst, t, eidx, n_nodes, k, b)
    src2, dst2, t2, e2 = random_stream(rng, n_nodes, 40, t_lo=10, t_hi=20)
    e2 = e2 + len(src)
    replay_equal(src2, dst2, t2, e2, n_nodes, k, b,
                 history=snap, buf=buf)


def test_index_no_future_leakage():
    """A sample at batch bi must only contain edges from earlier batches."""
    rng = np.random.default_rng(7)
    n_nodes, n_edges, k, b = 10, 80, 4, 9
    src, dst, t, eidx = random_stream(rng, n_nodes, n_edges)
    idx = ChronoNeighborIndex(src, dst, t, eidx, n_nodes, k, b)
    for bi in range(-(-n_edges // b)):
        _, _, eix = idx.sample(np.arange(n_nodes), bi)
        real = eix[eix >= 0]
        assert (real < bi * b).all(), f"future edge leaked into batch {bi}"
    # before anything streamed: completely empty
    ids, tms, eix = idx.sample(np.arange(n_nodes), 0)
    assert (ids == -1).all() and (tms == -1.0).all() and (eix == -1).all()


def test_build_batch_program_matches_per_batch_sampling():
    """The fully pre-staged (steps, ...) program must contain exactly the
    neighbors the old sample-then-update per-batch loop produced."""
    rng = np.random.default_rng(3)
    n_nodes, n_edges, k, b = 18, 75, 4, 10
    src, dst, t, eidx = random_stream(rng, n_nodes, n_edges)
    stream = LocalStream(src=src, dst=dst, t=t.astype(np.float64),
                         eidx=eidx, num_local_nodes=n_nodes)
    cfg = TIGConfig(flavor="tgn", dim=8, dim_time=4, dim_edge=4, dim_node=4,
                    num_neighbors=k, batch_size=b)
    stacked, _ = build_batch_program(stream, cfg, np.random.default_rng(0))
    steps = stacked["src"].shape[0]
    assert steps == -(-n_edges // b)

    buf = RecentNeighborBuffer(n_nodes, k)
    for bi in range(steps):
        lo, hi = bi * b, min((bi + 1) * b, n_edges)
        for role in ("src", "dst", "neg"):
            ids = stacked[role][bi]
            valid = stacked["valid"][bi]
            alive = (ids >= 0) & valid
            clean = np.where(alive, ids, 0)
            nb, nt, ne = buf.sample(clean)
            nb[~alive] = -1
            ne[~alive] = -1
            np.testing.assert_array_equal(stacked[f"nbr_{role}"][bi], nb)
            np.testing.assert_array_equal(stacked[f"nbre_{role}"][bi], ne)
            np.testing.assert_allclose(stacked[f"nbrt_{role}"][bi],
                                       nt.astype(np.float32))
        buf.update(src[lo:hi], dst[lo:hi], t[lo:hi], eidx[lo:hi])


def test_empty_history_equals_no_history():
    rng = np.random.default_rng(11)
    src, dst, t, eidx = random_stream(rng, 8, 30)
    a = ChronoNeighborIndex(src, dst, t, eidx, 8, 3, 5)
    b = ChronoNeighborIndex(src, dst, t, eidx, 8, 3, 5,
                            history=NeighborSnapshot.empty(8, 3))
    ga = a.sample(np.arange(8), 3)
    gb = b.sample(np.arange(8), 3)
    for x, y in zip(ga, gb):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------------- hypothesis sweep
# guarded per-test (not importorskip) so the deterministic tests above
# still run when the optional dependency is absent

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000),
           n_nodes=st.integers(1, 25),
           n_edges=st.integers(0, 90),
           k=st.integers(1, 7),
           b=st.integers(1, 13),
           t_hi=st.integers(1, 8))
    def test_index_equivalence_property(seed, n_nodes, n_edges, k, b, t_hi):
        rng = np.random.default_rng(seed)
        src, dst, t, eidx = random_stream(rng, n_nodes, n_edges, t_hi=t_hi)
        replay_equal(src, dst, t, eidx, n_nodes, k, b)
