"""Multi-layer temporal attention + MXU lane-padding tests (PR 7).

Four layers of guarantees:

(1) the stacked ``lax.scan`` attention fold at L == 1 is bit-identical to
    the direct single-layer module, and compiles ONE layer block (the
    dot_general count in the jaxpr is independent of L);
(2) the ops-boundary lane padding (``kernels/ops.py``) is value-invariant:
    padded interpret-mode kernel launches match the UNPADDED ``ref.py``
    oracles at 1e-5, forward and backward, on deliberately odd dims;
(3) windowed temporal-neighbor sampling (the per-layer K-windows of the
    multi-layer fold) agrees between the host index, the jnp oracle and
    the Pallas kernel body;
(4) end to end: ``n_layers=2`` trains under ``plan="device"`` in
    train_single / pac_train bit-identically to ``plan="host"``, and
    train_sharded runs it.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.neighbor_sample import neighbor_sample_fwd
from repro.tig.data import synthetic_tig
from repro.tig.models import TIGConfig
from repro.tig.modules import (attn_init, stacked_attn_init,
                               stacked_temporal_attention,
                               temporal_attention)
from repro.tig.sampler import ChronoNeighborIndex
from repro.tig.train import train_single

CFG2 = TIGConfig(dim=16, dim_time=8, dim_edge=16, dim_node=16,
                 num_neighbors=4, batch_size=128, n_layers=2)


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


# ------------------------------------------------ stacked fold == direct


def _attn_inputs(key, b=32, k=5, d=16, d_extra=12, d_kv=24):
    ks = jax.random.split(key, 5)
    h0 = rand(ks[0], (b, d))
    extra = rand(ks[1], (b, d_extra))
    kv = rand(ks[2], (b, k, d_kv))
    mask = jax.random.bernoulli(ks[3], 0.7, (b, k))
    mask = mask.at[0].set(False)            # a zero-neighbor row
    p = attn_init(ks[4], d + d_extra, d_kv, d, n_heads=2)
    return p, h0, extra, kv, mask


def test_stacked_scan_l1_matches_direct():
    """Same math, two lowerings: the scanned fold compiles its body as one
    XLA program while the direct path runs op-by-op, so cross-lowering
    bitwise identity is not guaranteed — 1e-6 is (f32 rounding only).
    The MODEL keeps the direct code path for n_layers == 1 (models.py), so
    production n_layers=1 results are bit-identical by construction."""
    p, h0, extra, kv, mask = _attn_inputs(jax.random.PRNGKey(0))
    p_stack = jax.tree.map(lambda x: x[None], p)
    got = stacked_temporal_attention(p_stack, h0, extra, kv[None],
                                     mask[None], n_heads=2)
    want = temporal_attention(p, jnp.concatenate([h0, extra], axis=-1),
                              kv, mask, n_heads=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def _count_dot_general(jaxpr) -> int:
    """Recursively count dot_general eqns — scan bodies count ONCE."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            n += 1
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                n += _count_dot_general(sub)
    return n


def _sub_jaxprs(v):
    if hasattr(v, "eqns"):                  # Jaxpr
        yield v
    elif hasattr(v, "jaxpr"):               # ClosedJaxpr
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def test_stacked_fold_compiles_one_layer_block():
    """The jaxpr dot_general count must NOT grow with n_layers — the scan
    traces its layer body once (no L-unrolled graph)."""
    key = jax.random.PRNGKey(1)
    counts = {}
    for n_layers in (2, 3):
        _, h0, extra, kv1, mask1 = _attn_inputs(key)
        p_stack = stacked_attn_init(jax.random.PRNGKey(2), n_layers,
                                    h0.shape[1] + extra.shape[1],
                                    kv1.shape[-1], h0.shape[1], 2)
        kv = jnp.broadcast_to(kv1[None], (n_layers,) + kv1.shape)
        mask = jnp.broadcast_to(mask1[None], (n_layers,) + mask1.shape)

        def fwd(p, kv=kv, mask=mask, h0=h0, extra=extra):
            return stacked_temporal_attention(p, h0, extra, kv, mask,
                                              n_heads=2).sum()

        counts[n_layers] = (
            _count_dot_general(jax.make_jaxpr(fwd)(p_stack).jaxpr),
            _count_dot_general(jax.make_jaxpr(jax.grad(fwd))(p_stack).jaxpr),
        )
    assert counts[2] == counts[3], counts
    assert counts[2][0] > 0


def test_stacked_l2_refines_not_repeats():
    """With 2 distinct layers the fold must differ from either single layer
    applied alone (the carry actually threads through)."""
    p, h0, extra, kv, mask = _attn_inputs(jax.random.PRNGKey(3))
    p_stack = stacked_attn_init(jax.random.PRNGKey(4), 2,
                                h0.shape[1] + extra.shape[1],
                                kv.shape[-1], h0.shape[1], 2)
    kv2 = jnp.stack([kv, kv])
    mask2 = jnp.stack([mask, mask])
    out = stacked_temporal_attention(p_stack, h0, extra, kv2, mask2,
                                     n_heads=2)
    for l in range(2):
        p_l = jax.tree.map(lambda x, l=l: x[l], p_stack)
        single = temporal_attention(p_l, jnp.concatenate([h0, extra], -1),
                                    kv, mask, n_heads=2)
        assert not np.allclose(np.asarray(out), np.asarray(single))
    # and it equals the hand-unrolled 2-step fold
    p0 = jax.tree.map(lambda x: x[0], p_stack)
    p1 = jax.tree.map(lambda x: x[1], p_stack)
    h1 = temporal_attention(p0, jnp.concatenate([h0, extra], -1), kv, mask,
                            n_heads=2)
    h2 = temporal_attention(p1, jnp.concatenate([h1, extra], -1), kv, mask,
                            n_heads=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(h2),
                               atol=1e-6, rtol=1e-5)


# ------------------------------------ lane padding is value-invariant


def test_padded_gru_matches_unpadded_ref():
    """Odd dims force real padding (20 -> 128, 24 -> 128); the interpret
    launch must match the raw oracle fwd + bwd at 1e-5."""
    b, d_in, d_h = 16, 20, 24
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    args = (rand(ks[0], (b, d_in)), rand(ks[1], (b, d_h)),
            rand(ks[2], (d_in, 3 * d_h), 0.3),
            rand(ks[3], (d_h, 3 * d_h), 0.3),
            rand(ks[4], (3 * d_h,), 0.1), rand(ks[5], (3 * d_h,), 0.1))

    got = ops.gru(*args, backend="interpret")
    want = ref.gru_ref(*args)
    assert got.shape == want.shape == (b, d_h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)

    g_pad = jax.grad(lambda *a: ops.gru(*a, backend="interpret").sum(),
                     argnums=tuple(range(6)))(*args)
    g_ref = jax.grad(lambda *a: ref.gru_ref(*a).sum(),
                     argnums=tuple(range(6)))(*args)
    for gp, gr in zip(g_pad, g_ref):
        assert gp.shape == gr.shape
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr),
                                   atol=1e-5, rtol=1e-5)


def test_padded_attention_matches_unpadded_ref():
    """D=12 -> 128 lanes and K=5 -> 8 sublanes; padded slots are masked,
    q is pre-scaled so the 1/sqrt(D) normalization is preserved."""
    b, k, h, d = 16, 5, 2, 12
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = rand(ks[0], (b, h, d))
    kk = rand(ks[1], (b, k, h, d))
    vv = rand(ks[2], (b, k, h, d))
    mask = jax.random.bernoulli(ks[3], 0.7, (b, k))
    mask = mask.at[0].set(False)

    got = ops.temporal_attention(q, kk, vv, mask, backend="interpret")
    want = ref.temporal_attention_ref(q, kk, vv, mask)
    assert got.shape == want.shape == (b, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[0]), 0.0)

    def loss_pad(q, kk, vv):
        return ops.temporal_attention(q, kk, vv, mask,
                                      backend="interpret").sum()

    def loss_ref(q, kk, vv):
        return ref.temporal_attention_ref(q, kk, vv, mask).sum()

    g_pad = jax.grad(loss_pad, argnums=(0, 1, 2))(q, kk, vv)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, kk, vv)
    for gp, gr in zip(g_pad, g_ref):
        assert gp.shape == gr.shape
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr),
                                   atol=1e-5, rtol=1e-5)


def test_padded_flush_matches_unpadded_ref():
    """d_msg=20 -> 128 (msg cols + wx rows only; the aliased memory table
    keeps its raw width)."""
    n, rows, dm, d = 12, 10, 20, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 8)
    ids = jax.random.randint(ks[0], (rows,), 0, n + 1).astype(jnp.int32)
    args = (ids, rand(ks[1], (rows, dm)),
            jax.random.uniform(ks[2], (rows,)) * 5.0,
            rand(ks[3], (n + 1, d)), jax.random.uniform(ks[4], (n + 1,)),
            rand(ks[5], (dm, 3 * d), 0.3), rand(ks[6], (d, 3 * d), 0.3),
            rand(ks[7], (3 * d,), 0.1), jnp.zeros((3 * d,)))

    got = ops.fused_flush(*args, backend="interpret")
    want = ref.flush_ref(*args)
    for a, b_, name in zip(got, want, ("mem", "last", "mbar")):
        assert a.shape == b_.shape, name
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5, rtol=1e-5, err_msg=name)

    def loss(backend, msg, mem, wx):
        a = (ids, msg, args[2], mem, args[4], wx) + args[6:]
        mem2, last2, mbar = ops.fused_flush(*a, backend=backend)
        return mem2.sum() + mbar.sum()

    g_pad = jax.grad(lambda *a: loss("interpret", *a),
                     argnums=(0, 1, 2))(args[1], args[3], args[5])
    g_ref = jax.grad(lambda *a: loss("xla", *a),
                     argnums=(0, 1, 2))(args[1], args[3], args[5])
    for gp, gr in zip(g_pad, g_ref):
        assert gp.shape == gr.shape
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr),
                                   atol=1e-5, rtol=1e-5)


def test_lane_pad_helpers_agree():
    from repro.roofline.kernel_bytes import lane_pad, sublane_pad
    for n in (1, 16, 127, 128, 129, 384):
        assert ops.lane_pad(n) == lane_pad(n)
        assert lane_pad(n) % 128 == 0 and lane_pad(n) >= n
        assert sublane_pad(n) % 8 == 0 and sublane_pad(n) >= n
    assert lane_pad(128) == 128 and sublane_pad(16) == 16   # aligned: no-op


# ----------------------------------------- windowed neighbor sampling


def _crafted_index(k=3, batch_size=2):
    src = np.array([2, 2, 2, 2, 2, 1, 3, 0, 2, 2])
    dst = np.array([3, 4, 5, 6, 4, 2, 2, 2, 5, 6])
    t = np.arange(1.0, 11.0)
    return ChronoNeighborIndex(src, dst, t, np.arange(len(src)), 8, k,
                               batch_size)


def test_windowed_sampling_host_oracle_kernel_agree():
    index = _crafted_index()
    depth = 3
    tcsr = {k: jnp.asarray(v)
            for k, v in index.device_export(depth=depth).items()}
    nodes = np.array([0, 1, 2, 3, 4, 5, 6, 7, 2, 2])
    for b in range(index.num_batches):
        for w in range(depth):
            hb, ht, he = index.sample(nodes.astype(np.int64),
                                      np.full(len(nodes), b), window=w)
            nj = jnp.asarray(nodes, jnp.int32)
            bj = jnp.full((len(nodes),), b, jnp.int32)
            for label, (db, dt, de) in {
                "oracle": ref.sample_ref(
                    tcsr["indptr"], tcsr["nbr"], tcsr["t"], tcsr["eidx"],
                    tcsr["bat"], nj, bj, index.k, w),
                "kernel": neighbor_sample_fwd(
                    tcsr["indptr"], tcsr["nbr"], tcsr["t"], tcsr["eidx"],
                    tcsr["bat"], nj, bj, k=index.k, interpret=True,
                    window=w),
                "ops": ops.neighbor_sample(
                    tcsr, nj, bj, index.k, backend="xla",
                    window=jnp.full((len(nodes),), w, jnp.int32)),
            }.items():
                msg = f"batch={b} window={w} {label}"
                np.testing.assert_array_equal(np.asarray(db), hb,
                                              err_msg=msg)
                np.testing.assert_array_equal(
                    np.asarray(dt), ht.astype(np.float32), err_msg=msg)
                np.testing.assert_array_equal(np.asarray(de), he,
                                              err_msg=msg)


def test_window_zero_is_default_path():
    index = _crafted_index()
    tcsr = {k: jnp.asarray(v) for k, v in index.device_export().items()}
    nodes = jnp.arange(8, dtype=jnp.int32)
    a = ref.sample_ref(tcsr["indptr"], tcsr["nbr"], tcsr["t"],
                       tcsr["eidx"], tcsr["bat"], nodes, jnp.int32(1),
                       index.k)
    bwin = ref.sample_ref(tcsr["indptr"], tcsr["nbr"], tcsr["t"],
                          tcsr["eidx"], tcsr["bat"], nodes, jnp.int32(1),
                          index.k, 0)
    for x, y in zip(a, bwin):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_older_windows_are_older_events():
    """Window w+1's events all precede window w's (per node, where both
    are non-empty) — the fold's deeper layers look further back."""
    index = _crafted_index()
    nodes = np.full(4, 2, dtype=np.int64)       # the hub node
    bo = np.full(4, index.num_batches - 1)
    _, t0, _ = index.sample(nodes, bo, window=0)
    _, t1, _ = index.sample(nodes, bo, window=1)
    real0, real1 = t0[t0 >= 0], t1[t1 >= 0]
    assert len(real0) and len(real1)
    assert real1.max() < real0.min()


# --------------------------------------------------- end-to-end parity


def _tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_train_single_two_layers_device_plan_bit_identical():
    g = synthetic_tig("tiny", seed=3)
    a = train_single(g, CFG2, epochs=2, seed=0, plan="host")
    b = train_single(g, CFG2, epochs=2, seed=0, plan="device")
    assert a.losses == b.losses
    assert a.val_ap == b.val_ap and a.test_ap == b.test_ap
    _tree_equal(a.params, b.params)
    _tree_equal(a.state, b.state)
    assert all(np.isfinite(l) for l in a.losses)


def test_train_single_two_layers_differs_from_one_layer():
    """n_layers must actually change the computation."""
    g = synthetic_tig("tiny", seed=3)
    import dataclasses
    one = train_single(g, dataclasses.replace(CFG2, n_layers=1),
                       epochs=1, seed=0)
    two = train_single(g, CFG2, epochs=1, seed=0)
    assert one.losses != two.losses


def test_pac_train_two_layers_device_plan_bit_identical():
    from repro.core import sep_partition
    from repro.tig.distributed import pac_train
    from repro.tig.graph import chronological_split

    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=50,
                    n_layers=2)
    g = synthetic_tig("tiny", seed=0)
    train_g, _, _, _ = chronological_split(g)
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, 4, k=0.05)
    kw = dict(num_devices=4, epochs=2, lr=2e-3, shuffle_parts=False)
    a = pac_train(train_g, part, cfg, plan="host", **kw)
    b = pac_train(train_g, part, cfg, plan="device", **kw)
    for la, lb in zip(a.losses, b.losses):
        np.testing.assert_array_equal(la, lb)
    _tree_equal(a.params, b.params)
    _tree_equal(a.memory_states, b.memory_states)


def test_train_sharded_two_layers_smoke(tmp_path):
    from repro.tig.stream import write_graph_shards
    from repro.tig.train import train_sharded

    g = synthetic_tig("tiny", seed=3)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=313)
    res = train_sharded(sh, CFG2, epochs=1, seed=0, plan="device")
    assert all(np.isfinite(l) for l in res.losses)
