"""Tests for the TIG substrate: sampler, metrics, models, single training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.tig.batching import build_batches, make_tables
from repro.tig.data import synthetic_tig, PRESETS
from repro.tig.evaluation import average_precision, roc_auc
from repro.tig.graph import chronological_split
from repro.tig.models import (
    FLAVORS,
    TIGConfig,
    init_params,
    init_state,
    step_loss,
)
from repro.tig.sampler import RecentNeighborBuffer
from repro.tig.train import graph_as_stream, make_train_step, train_single
from repro.optim import adamw


CFG = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=32)


# ------------------------------------------------------------------ dataset

def test_synthetic_presets_shapes():
    g = synthetic_tig("tiny", seed=1)
    s = g.stats()
    assert s["num_edges"] == PRESETS["tiny"]["num_edges"]
    assert (np.diff(g.t) >= 0).all()
    assert g.src.max() < g.num_nodes and g.dst.max() < g.num_nodes
    # bipartite: users strictly below items
    assert g.src.max() < g.dst.min()


def test_chronological_split_fractions_and_inductive():
    g = synthetic_tig("tiny", seed=2)
    tr, va, te, ind = chronological_split(g)
    assert tr.num_edges == int(0.7 * g.num_edges)
    assert tr.t.max() <= va.t.min() + 1e-9
    assert va.t.max() <= te.t.min() + 1e-9
    seen = np.zeros(g.num_nodes, bool)
    seen[tr.src] = True
    seen[tr.dst] = True
    assert not seen[ind].any()


# ------------------------------------------------------------------ sampler

def test_sampler_no_future_leakage_and_recency():
    buf = RecentNeighborBuffer(10, k=3)
    ids, tms, eix = buf.sample(np.array([0]))
    assert (ids == -1).all()
    buf.update(np.array([0, 0, 0, 0]), np.array([1, 2, 3, 4]),
               np.array([1.0, 2.0, 3.0, 4.0]), np.array([0, 1, 2, 3]))
    ids, tms, eix = buf.sample(np.array([0]))
    # only the K=3 most recent survive, oldest->newest
    np.testing.assert_array_equal(ids[0], [2, 3, 4])
    np.testing.assert_array_equal(tms[0], [2.0, 3.0, 4.0])
    np.testing.assert_array_equal(eix[0], [1, 2, 3])
    # symmetric insertion
    ids, _, _ = buf.sample(np.array([4]))
    assert 0 in set(ids[0].tolist())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), k=st.integers(1, 6))
def test_sampler_times_sorted_property(seed, k):
    rng = np.random.default_rng(seed)
    buf = RecentNeighborBuffer(20, k=k)
    for i in range(5):
        e = rng.integers(1, 8)
        buf.update(rng.integers(0, 20, e), rng.integers(0, 20, e),
                   np.sort(rng.uniform(i, i + 1, e)),
                   rng.integers(0, 100, e))
    ids, tms, _ = buf.sample(np.arange(20))
    real = ids >= 0
    # within each row, stored times are non-decreasing (oldest->newest)
    for r in range(20):
        row_t = tms[r][real[r]]
        assert (np.diff(row_t) >= 0).all()


# ------------------------------------------------------------------ metrics

def test_average_precision_perfect_and_random():
    y = np.array([1, 1, 0, 0])
    assert average_precision(y, np.array([4, 3, 2, 1])) == 1.0
    assert average_precision(y, np.array([1, 2, 3, 4])) < 0.6


def test_roc_auc_known_values():
    y = np.array([1, 0, 1, 0])
    assert roc_auc(y, np.array([0.9, 0.1, 0.8, 0.2])) == 1.0
    assert roc_auc(y, np.array([0.1, 0.9, 0.2, 0.8])) == 0.0
    assert roc_auc(y, np.array([0.5, 0.5, 0.5, 0.5])) == 0.5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(5, 60))
def test_roc_auc_matches_bruteforce(seed, n):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n).astype(bool)
    s = rng.normal(size=n)
    if y.all() or not y.any():
        return
    pos, neg = s[y], s[~y]
    brute = np.mean((pos[:, None] > neg[None, :]) * 1.0
                    + 0.5 * (pos[:, None] == neg[None, :]))
    assert roc_auc(y, s) == pytest.approx(brute, abs=1e-9)


# ------------------------------------------------------------------ models

@pytest.mark.parametrize("flavor", FLAVORS)
def test_step_loss_shapes_and_finiteness(flavor):
    cfg = TIGConfig(flavor=flavor, dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=32)
    g = synthetic_tig("tiny", seed=3)
    stream, tables = graph_as_stream(g)
    rng = np.random.default_rng(0)
    batches = build_batches(stream, cfg, rng)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, g.num_nodes)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    for batch in batches[:3]:
        bj = {k: jnp.asarray(v) for k, v in batch.items() if k != "labels"}
        loss, (state, aux) = step_loss(params, state, bj, tables_j, cfg)
        assert jnp.isfinite(loss)
        assert aux["pos_logit"].shape == (cfg.batch_size,)
        assert jnp.isfinite(state["mem"]).all()
        # dump row stays zero
        assert (state["mem"][-1] == 0).all()


def test_memory_updates_only_touched_nodes():
    cfg = CFG
    g = synthetic_tig("tiny", seed=4)
    stream, tables = graph_as_stream(g)
    rng = np.random.default_rng(0)
    batches = build_batches(stream, cfg, rng)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, g.num_nodes)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    b0 = {k: jnp.asarray(v) for k, v in batches[0].items() if k != "labels"}
    b1 = {k: jnp.asarray(v) for k, v in batches[1].items() if k != "labels"}
    _, (state1, _) = step_loss(params, state, b0, tables_j, cfg)
    _, (state2, _) = step_loss(params, state1, b1, tables_j, cfg)
    # after step 2, exactly the nodes of batch 0 have been memory-updated
    touched = set(np.asarray(batches[0]["src"]).tolist()) | \
        set(np.asarray(batches[0]["dst"]).tolist())
    touched.discard(-1)
    mem = np.asarray(state2["mem"])
    changed = np.nonzero(np.abs(mem).sum(-1) > 0)[0]
    assert set(changed.tolist()) <= touched


def test_gradients_reach_all_params():
    cfg = TIGConfig(flavor="tgn", dim=16, dim_time=8, dim_edge=16,
                    dim_node=16, num_neighbors=4, batch_size=32,
                    message_fn="mlp", dim_msg=24)
    g = synthetic_tig("tiny", seed=5)
    stream, tables = graph_as_stream(g)
    rng = np.random.default_rng(0)
    batches = build_batches(stream, cfg, rng)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, g.num_nodes)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}

    def two_step_loss(p):
        s = state
        total = 0.0
        for b in batches[:2]:
            bj = {k: jnp.asarray(v) for k, v in b.items() if k != "labels"}
            l, (s, _) = step_loss(p, s, bj, tables_j, cfg)
            total = total + l
        return total

    grads = jax.grad(two_step_loss)(params)
    norms = {k: float(sum(jnp.abs(leaf).sum()
                          for leaf in jax.tree.leaves(v)))
             for k, v in grads.items()}
    # the message-store trick must deliver gradient to MSG and UPD params
    assert norms["upd"] > 0, norms
    assert norms["msg"] > 0, norms
    assert norms["attn"] > 0 and norms["dec"] > 0 and norms["time"] > 0


def test_training_reduces_loss():
    g = synthetic_tig("tiny", seed=6)
    res = train_single(g, CFG, epochs=3, lr=2e-3)
    assert res.losses[-1] < res.losses[0]
    assert res.val_ap > 0.5 and res.test_ap > 0.5


def test_padding_invariance():
    """A short (padded) batch must give the same loss as its unpadded
    content — the valid mask fully isolates padding."""
    cfg = CFG
    g = synthetic_tig("tiny", seed=7)
    stream, tables = graph_as_stream(g)
    rng = np.random.default_rng(0)
    batches = build_batches(stream, cfg, rng)
    last = batches[-1]  # tail batch (padded unless exact multiple)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, g.num_nodes)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    bj = {k: jnp.asarray(v) for k, v in last.items() if k != "labels"}
    loss, (st1, _) = step_loss(params, state, bj, tables_j, cfg)
    # corrupt the padded region wildly: loss and state must not change
    corrupt = dict(bj)
    v = np.asarray(last["valid"])
    if v.all():
        return  # no padding in this draw
    for key in ("src", "dst", "neg"):
        arr = np.asarray(last[key]).copy()
        arr[~v] = 0  # a real node id, but masked out
        corrupt[key] = jnp.asarray(arr)
    loss2, (st2, _) = step_loss(params, state, corrupt, tables_j, cfg)
    assert jnp.allclose(loss, loss2)
    assert jnp.allclose(st1["mem"], st2["mem"])
