"""Gradient parity for the Pallas backward kernels (and the fused flush).

The fused backward kernels (interpret mode on CPU) must reproduce the XLA
oracle gradients: through the raw ops, through ``flush_pending``, and
through a full ``step_loss`` training step for the GRU flavors.  This
module deliberately has no optional-dep guard — it runs everywhere
tier-1 runs.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fused_flush import fused_flush_fwd
from repro.kernels.fused_gru import fused_gru_bwd
from repro.kernels.temporal_attn import temporal_attn_bwd
from repro.tig.batching import build_batches
from repro.tig.data import synthetic_tig
from repro.tig.models import (
    TIGConfig,
    flush_pending,
    init_params,
    init_state,
    step_loss,
)
from repro.tig.train import graph_as_stream

TOL = 1e-5


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


def assert_tree_close(got, want, tol=TOL, label=""):
    flat_g, _ = jax.tree.flatten(got)
    flat_w, _ = jax.tree.flatten(want)
    assert len(flat_g) == len(flat_w)
    for i, (a, b) in enumerate(zip(flat_g, flat_w)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=tol, rtol=tol,
            err_msg=f"{label} leaf {i}")


# ----------------------------------------------------------------- raw ops

@pytest.mark.parametrize("b,d_in,d_h", [
    (8, 16, 16), (100, 48, 32), (33, 7, 5),     # incl. ragged last block
])
def test_gru_fused_bwd_matches_oracle(b, d_in, d_h):
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    args = (rand(ks[0], (b, d_in)), rand(ks[1], (b, d_h)),
            rand(ks[2], (d_in, 3 * d_h), 0.3),
            rand(ks[3], (d_h, 3 * d_h), 0.3),
            rand(ks[4], (3 * d_h,), 0.1), rand(ks[5], (3 * d_h,), 0.1))
    g = rand(ks[6], (b, d_h))
    want = jax.grad(
        lambda *a: jnp.sum(ref.gru_ref(*a) * g), argnums=(0, 1, 2, 3, 4, 5)
    )(*args)
    got = jax.grad(
        lambda *a: jnp.sum(
            ops.gru(*a, backend="interpret", bwd="fused") * g),
        argnums=(0, 1, 2, 3, 4, 5))(*args)
    assert_tree_close(got, want, label="gru")
    # the raw backward kernel agrees too (block boundary crossed: block_b=16)
    raw = fused_gru_bwd(g, *args, block_b=16, interpret=True)
    assert_tree_close(raw, want, label="gru raw kernel")


@pytest.mark.parametrize("b,k,h,d", [(16, 4, 2, 8), (33, 5, 1, 4)])
def test_temporal_attn_fused_bwd_matches_oracle(b, k, h, d):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q, kk, v = (rand(ks[0], (b, h, d)), rand(ks[1], (b, k, h, d)),
                rand(ks[2], (b, k, h, d)))
    mask = jax.random.uniform(ks[3], (b, k)) > 0.3
    mask = mask.at[0].set(False)        # a zero-neighbor row
    g = rand(ks[4], (b, h, d))
    want = jax.grad(
        lambda *a: jnp.sum(ref.temporal_attention_ref(*a, mask) * g),
        argnums=(0, 1, 2))(q, kk, v)
    got = jax.grad(
        lambda *a: jnp.sum(ops.temporal_attention(
            *a, mask, backend="interpret", bwd="fused") * g),
        argnums=(0, 1, 2))(q, kk, v)
    assert_tree_close(got, want, label="attn")
    raw = temporal_attn_bwd(g, q, kk, v, mask, block_b=16, interpret=True)
    assert_tree_close(raw, want, label="attn raw kernel")
    # zero-neighbor rows get exactly zero input gradients
    assert np.abs(np.asarray(raw[0][0])).max() == 0.0


def test_gru_oracle_bwd_mode_still_works():
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    args = (rand(ks[0], (12, 8)), rand(ks[1], (12, 8)),
            rand(ks[2], (8, 24), 0.3), rand(ks[3], (8, 24), 0.3),
            rand(ks[4], (24,), 0.1), rand(ks[5], (24,), 0.1))
    want = jax.grad(lambda *a: jnp.sum(ref.gru_ref(*a)),
                    argnums=(0, 1))(*args)
    got = jax.grad(
        lambda *a: jnp.sum(ops.gru(*a, backend="interpret", bwd="oracle")),
        argnums=(0, 1))(*args)
    assert_tree_close(got, want, label="gru oracle bwd")


# -------------------------------------------------------------- fused flush

def flush_inputs(seed=3, n=40, rows=24, dm=20, d=16, dup_heavy=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    hi = n // 4 if dup_heavy else n     # force duplicate ids
    ids = jax.random.randint(ks[0], (rows,), 0, hi + 1).astype(jnp.int32)
    ids = ids.at[-2:].set(n)            # padding rows -> dump row
    return (ids,
            rand(ks[1], (rows, dm)),
            jax.random.uniform(ks[2], (rows,)) * 10,
            rand(ks[3], (n + 1, d)),
            jax.random.uniform(ks[4], (n + 1,)),
            rand(ks[5], (dm, 3 * d), 0.3),
            rand(ks[6], (d, 3 * d), 0.3),
            rand(ks[7], (3 * d,), 0.1),
            jnp.zeros((3 * d,)))


def test_fused_flush_forward_matches_oracle():
    args = flush_inputs()
    want = ref.flush_ref(*args)
    got = fused_flush_fwd(*args, interpret=True)
    for name, a, b in zip(("mem", "last", "mbar"), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6, err_msg=name)
    # untouched memory rows are bit-identical (aliased in place)
    touched = set(np.asarray(args[0]).tolist())
    mem_in, mem_out = np.asarray(args[3]), np.asarray(got[0])
    for r in range(mem_in.shape[0] - 1):
        if r not in touched:
            np.testing.assert_array_equal(mem_out[r], mem_in[r])


@pytest.mark.parametrize("n,rows,dm,d", [
    (30, 16, 12, 8), (100, 64, 48, 32), (9, 24, 20, 16),  # heavy duplicates
])
def test_fused_flush_forward_shape_sweep(n, rows, dm, d):
    args = flush_inputs(seed=10, n=n, rows=rows, dm=dm, d=d,
                        dup_heavy=False)
    got = fused_flush_fwd(*args, interpret=True)
    want = ref.flush_ref(*args)
    for name, a, b in zip(("mem", "last", "mbar"), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6, err_msg=name)


def test_fused_flush_all_padding_is_noop():
    n, rows, dm, d = 20, 8, 12, 8
    args = list(flush_inputs(seed=11, n=n, rows=rows, dm=dm, d=d))
    args[0] = jnp.full((rows,), n, jnp.int32)      # every row -> dump
    mem_out, last_out, mbar = fused_flush_fwd(*args, interpret=True)
    np.testing.assert_array_equal(np.asarray(mem_out[:-1]),
                                  np.asarray(args[3][:-1]))
    assert np.abs(np.asarray(mem_out[-1])).max() == 0.0
    assert np.abs(np.asarray(last_out[-1])).max() == 0.0
    assert np.abs(np.asarray(mbar)).max() == 0.0


def test_fused_flush_grads_match_oracle():
    args = flush_inputs(seed=4)

    def loss(f):
        def inner(msg, mem, wx, wh, bx, bh):
            m, l, mb = f(args[0], msg, args[2], mem, args[4],
                         wx, wh, bx, bh)
            return jnp.sum(m * m) + jnp.sum(l) + jnp.sum(mb)
        return inner

    diff = (args[1], args[3], args[5], args[6], args[7], args[8])
    want = jax.grad(loss(ref.flush_ref), argnums=tuple(range(6)))(*diff)
    got = jax.grad(
        loss(lambda *a: ops.fused_flush(*a, backend="interpret")),
        argnums=tuple(range(6)))(*diff)
    assert_tree_close(got, want, label="flush")


def test_flush_pending_pallas_matches_xla_path():
    """Whole flush_pending: fused kernel vs the inline XLA aggregation."""
    for flavor in ("tgn", "tige"):
        cfg_x = TIGConfig(flavor=flavor, dim=16, dim_time=8, dim_edge=16,
                          dim_node=16, num_neighbors=4, batch_size=8)
        cfg_p = TIGConfig(flavor=flavor, dim=16, dim_time=8, dim_edge=16,
                          dim_node=16, num_neighbors=4, batch_size=8,
                          use_pallas=True, kernel_backend="interpret")
        params = init_params(jax.random.PRNGKey(0), cfg_x)
        state = init_state(cfg_x, 30)
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        state["mem"] = rand(ks[0], state["mem"].shape)
        state["pend_ids"] = jax.random.randint(
            ks[1], state["pend_ids"].shape, 0, 31).astype(jnp.int32)
        state["pend_raw"] = rand(ks[2], state["pend_raw"].shape)
        state["pend_t"] = jnp.linspace(0.0, 1.0, 16)
        out_x = flush_pending(params, cfg_x, dict(state))
        out_p = flush_pending(params, cfg_p, dict(state))
        for key in ("mem", "mem2", "last"):
            np.testing.assert_allclose(
                np.asarray(out_p[key]), np.asarray(out_x[key]),
                atol=1e-6, rtol=1e-6, err_msg=f"{flavor}/{key}")


# ------------------------------------------------------- full training step

def _step_setup(flavor):
    cfg_kw = dict(flavor=flavor, dim=16, dim_time=8, dim_edge=16,
                  dim_node=16, num_neighbors=4, batch_size=32,
                  message_fn="mlp", dim_msg=24)
    cfg_x = TIGConfig(**cfg_kw)
    g = synthetic_tig("tiny", seed=7)
    stream, tables = graph_as_stream(g)
    batches = build_batches(stream, cfg_x, np.random.default_rng(0))
    params = init_params(jax.random.PRNGKey(0), cfg_x)
    state = init_state(cfg_x, g.num_nodes)
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    bjs = [{k: jnp.asarray(v) for k, v in b.items() if k != "labels"}
           for b in batches[:2]]
    return cfg_kw, params, state, tables_j, bjs


def _two_step_grads(cfg, params, state, tables_j, bjs):
    def loss(p):
        s, total = state, 0.0
        for bj in bjs:           # 2 steps: flush sees real pending messages
            l, (s, _) = step_loss(p, s, bj, tables_j, cfg)
            total = total + l
        return total
    return jax.grad(loss)(params)


@pytest.mark.parametrize("flavor", ["tgn", "tige"])
def test_step_loss_grad_parity_fused_bwd(flavor):
    cfg_kw, params, state, tables_j, bjs = _step_setup(flavor)
    want = _two_step_grads(TIGConfig(**cfg_kw), params, state, tables_j,
                           bjs)
    got = _two_step_grads(
        TIGConfig(**cfg_kw, use_pallas=True, kernel_backend="interpret"),
        params, state, tables_j, bjs)
    assert_tree_close(got, want, tol=TOL, label=f"step_loss {flavor}")


def test_step_loss_grad_parity_oracle_bwd(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BWD", "oracle")
    cfg_kw, params, state, tables_j, bjs = _step_setup("tgn")
    want = _two_step_grads(TIGConfig(**cfg_kw), params, state, tables_j,
                           bjs)
    got = _two_step_grads(
        TIGConfig(**cfg_kw, use_pallas=True, kernel_backend="interpret"),
        params, state, tables_j, bjs)
    assert_tree_close(got, want, tol=TOL, label="step_loss oracle bwd")


def test_bwd_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BWD", raising=False)
    assert ops.default_bwd() == "fused"
    monkeypatch.setenv("REPRO_KERNEL_BWD", "oracle")
    assert ops.default_bwd() == "oracle"
    monkeypatch.setenv("REPRO_KERNEL_BWD", "bogus")
    with pytest.raises(ValueError):
        ops.default_bwd()
