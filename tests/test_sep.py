"""Unit + property tests for the SEP streaming partitioner (Alg.1, Thm.1/2)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (
    degree_centrality,
    edge_cut_fraction,
    greedy_partition,
    hdrf_partition,
    kl_partition,
    ldg_partition,
    partition_stats,
    random_partition,
    replication_factor,
    sep_partition,
    temporal_centrality,
    thm1_rf_bound,
    thm2_ec_bound,
    top_k_hubs,
)
from repro.core.metrics import fit_power_law_alpha


def make_graph(seed=0, num_nodes=400, num_edges=3000, zipf=1.7):
    """Bipartite power-law temporal interaction graph where every node has
    at least one edge (so RF denominators match the theorems)."""
    rng = np.random.default_rng(seed)
    half = num_nodes // 2
    src = rng.zipf(zipf, num_edges) % half
    dst = half + (rng.zipf(zipf, num_edges) % (num_nodes - half))
    # guarantee every node appears at least once
    all_src = np.arange(half)
    all_dst = half + np.arange(num_nodes - half)
    src = np.concatenate([all_src, src])
    dst = np.concatenate([rng.integers(half, num_nodes, half), dst])
    src = np.concatenate([src, rng.integers(0, half, num_nodes - half)])
    dst = np.concatenate([dst, all_dst])
    e = len(src)
    t = np.sort(rng.uniform(0.0, 1e6, e))
    perm = rng.permutation(e)
    src, dst = src[perm], dst[perm]  # decouple id from time order
    return src.astype(np.int64), dst.astype(np.int64), t, num_nodes


# ---------------------------------------------------------------- centrality

def test_temporal_centrality_recency_weighting():
    # node 0 has one OLD edge, node 1 one RECENT edge, both degree 1.
    src = np.array([0, 1])
    dst = np.array([2, 3])
    t = np.array([0.0, 100.0])
    c = temporal_centrality(src, dst, t, 4, beta=0.9)
    assert c[1] > c[0]
    assert c[3] > c[2]


def test_degree_vs_temporal_centrality_disagree():
    # high-degree-but-stale node loses to low-degree-but-fresh under decay.
    src = np.array([0, 0, 0, 0, 1])
    dst = np.array([2, 3, 4, 5, 6])
    t = np.array([0.0, 1.0, 2.0, 3.0, 1000.0])
    deg = degree_centrality(src, dst, 7)
    tc = temporal_centrality(src, dst, t, 7, beta=0.99,
                             normalize_time=False)
    assert deg[0] > deg[1]
    assert tc[1] > tc[0]


def test_top_k_hubs_sizes():
    c = np.arange(100, dtype=float)
    assert top_k_hubs(c, 0.0).sum() == 0
    assert top_k_hubs(c, 0.05).sum() == 5
    assert top_k_hubs(c, 1.0).sum() == 100
    # the hubs really are the largest
    assert top_k_hubs(c, 0.05)[95:].all()


# ---------------------------------------------------------------- SEP Alg.1

@pytest.mark.parametrize("k", [0.0, 0.02, 0.1])
@pytest.mark.parametrize("num_parts", [2, 4, 8])
def test_sep_invariants(k, num_parts):
    src, dst, t, n = make_graph()
    res = sep_partition(src, dst, t, n, num_parts, k=k)
    pop = np.array([int(m).bit_count() for m in res.node_masks])

    # every node with an edge is placed somewhere
    assert (pop > 0).all()

    # non-hubs never replicate (Thm.1 construction)
    nonhub = ~res.hubs
    assert (pop[nonhub] <= 1).all()

    # shared nodes are exactly the hub subset that replicated, and are
    # broadcast to all partitions (Alg.1 line 20)
    assert set(res.shared_nodes) <= set(np.nonzero(res.hubs)[0])
    if len(res.shared_nodes):
        assert (pop[res.shared_nodes] == num_parts).all()

    # kept edges have both endpoints in the assigned partition
    kept = res.edge_part >= 0
    p = res.edge_part[kept].astype(np.uint64)
    bit = np.uint64(1)
    assert ((res.node_masks[src[kept]] >> p) & bit).all()
    assert ((res.node_masks[dst[kept]] >> p) & bit).all()

    # k == 0: no replication at all
    if k == 0.0:
        assert len(res.shared_nodes) == 0
        assert replication_factor(res) == 1.0


@pytest.mark.parametrize("num_parts", [2, 4, 8])
def test_thm1_rf_bound(num_parts):
    src, dst, t, n = make_graph()
    for k in (0.0, 0.05, 0.2):
        res = sep_partition(src, dst, t, n, num_parts, k=k)
        # ceil() in hub count gives a hair of slack over the continuous bound
        bound = thm1_rf_bound(np.ceil(k * n) / n, num_parts)
        assert replication_factor(res, denominator="all") <= bound + 1e-9


def test_edge_cut_only_from_case3():
    # with k=1 (all hubs) there are no Case-3 discards -> zero edge cut
    src, dst, t, n = make_graph()
    res = sep_partition(src, dst, t, n, 4, k=1.0)
    assert edge_cut_fraction(res) == 0.0


def test_more_hubs_less_cut():
    src, dst, t, n = make_graph(num_edges=5000)
    cuts = [
        edge_cut_fraction(sep_partition(src, dst, t, n, 4, k=k))
        for k in (0.0, 0.05, 0.2, 1.0)
    ]
    assert cuts[0] >= cuts[-1]
    assert cuts[-1] == 0.0


def test_load_balance_edges():
    src, dst, t, n = make_graph(num_edges=6000)
    res = sep_partition(src, dst, t, n, 4, k=0.05)
    counts = res.edge_counts()
    assert counts.max() <= 1.3 * max(counts.min(), 1)


def test_thm2_ec_bound_degree_centrality():
    # Thm.2 is stated for degree centrality on a power-law graph.
    src, dst, t, n = make_graph(num_edges=4000, zipf=2.2)
    deg = degree_centrality(src, dst, n)
    alpha = fit_power_law_alpha(deg)
    m = max(float(deg[deg > 0].min()), 1.0)
    for k in (0.05, 0.2):
        res = sep_partition(
            src, dst, t, n, 4, k=k, centrality=deg
        )
        bound = thm2_ec_bound(n, len(src), k, m, alpha)
        assert edge_cut_fraction(res) <= min(bound, 1.0) + 1e-9


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    num_parts=st.sampled_from([2, 3, 4, 8]),
    k=st.floats(0.0, 1.0),
    n_edges=st.integers(50, 400),
)
def test_sep_property_random_graphs(seed, num_parts, k, n_edges):
    rng = np.random.default_rng(seed)
    n = 60
    src = rng.integers(0, n, n_edges)
    dst = rng.integers(0, n, n_edges)
    t = np.sort(rng.uniform(0, 1.0, n_edges))
    res = sep_partition(src, dst, t, n, num_parts, k=k)
    pop = np.array([int(m).bit_count() for m in res.node_masks])
    touched = np.zeros(n, dtype=bool)
    touched[src] = True
    touched[dst] = True
    # placed iff touched
    assert ((pop > 0) == touched).all()
    # non-hub single placement
    assert (pop[~res.hubs] <= 1).all()
    # edge containment
    kept = res.edge_part >= 0
    p = res.edge_part[kept].astype(np.uint64)
    assert ((res.node_masks[src[kept]] >> p) & np.uint64(1)).all()
    assert ((res.node_masks[dst[kept]] >> p) & np.uint64(1)).all()
    # partition ids within range
    assert res.edge_part.max() < num_parts
    # every partition bit within range
    assert (res.node_masks < (np.uint64(1) << np.uint64(num_parts))).all()


# ------------------------------------------------------------- baselines

def test_hdrf_no_discards_and_balance():
    src, dst, t, n = make_graph()
    res = hdrf_partition(src, dst, n, 4)
    assert edge_cut_fraction(res) == 0.0
    counts = res.edge_counts()
    assert counts.max() <= 1.2 * counts.min() + 8


def test_hdrf_equals_sep_topk1_structure():
    """Paper §III-B: unrestricted top_k degenerates SEP to HDRF."""
    src, dst, t, n = make_graph(num_edges=1500)
    deg = degree_centrality(src, dst, n)
    a = sep_partition(src, dst, t, n, 4, k=1.0, centrality=deg,
                      shared_to_all=False)
    b = hdrf_partition(src, dst, n, 4)
    np.testing.assert_array_equal(a.edge_part, b.edge_part)


def test_greedy_runs():
    src, dst, t, n = make_graph(num_edges=1000)
    res = greedy_partition(src, dst, n, 4)
    assert edge_cut_fraction(res) == 0.0


def test_random_partition_balance():
    src, dst, t, n = make_graph(num_edges=4000)
    res = random_partition(src, dst, n, 4, seed=1)
    counts = res.edge_counts()
    assert counts.sum() == len(src)
    assert counts.std() < 0.1 * counts.mean()


def test_ldg_edge_cut_partition():
    src, dst, t, n = make_graph(num_edges=1500)
    res = ldg_partition(src, dst, n, 4)
    # edge-cut method: every node in exactly one partition
    pop = np.array([int(m).bit_count() for m in res.node_masks])
    assert (pop == 1).all()
    assert replication_factor(res) == 1.0


def test_kl_partition_node_balanced():
    src, dst, t, n = make_graph(num_edges=800, num_nodes=120)
    res = kl_partition(src, dst, n, 4)
    counts = res.node_counts()
    assert counts.max() - counts.min() <= 2
    with pytest.raises(ValueError):
        kl_partition(src, dst, n, 3)


def test_partition_stats_fields():
    src, dst, t, n = make_graph(num_edges=600)
    s = partition_stats(sep_partition(src, dst, t, n, 4, k=0.05))
    assert s.num_parts == 4
    assert 0 <= s.edge_cut <= 1
    assert s.replication_factor >= 1.0
    assert s.elapsed_s > 0
