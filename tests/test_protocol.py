"""Protocol-layer tests: zero-copy split views, valid-aligned inductive
masks (regression for the truncation bug), and the sharded-vs-in-memory
parity the quality path promises (identical batch plan => identical
metrics)."""

import os

import numpy as np
import pytest
import jax

from repro.tig.batching import build_batch_program
from repro.tig.data import synthetic_tig
from repro.tig.engine import make_eval_epoch
from repro.tig.evaluation import link_prediction_metrics
from repro.tig.models import TIGConfig, init_params, init_state
from repro.tig.protocol import (
    device_batches,
    inductive_node_mask,
    run_protocol,
    score_stream,
    split_bounds,
    split_views,
)
from repro.tig.stream import (
    ShardedStream,
    stage_device_tables,
    write_graph_shards,
)
from repro.tig.train import evaluate_params, graph_as_stream, train_sharded

CFG = TIGConfig(dim=16, dim_time=8, dim_edge=16, dim_node=16,
                num_neighbors=4, batch_size=128)


def _metrics_equal(a: dict, b: dict, keys=None):
    for k in keys or set(a) & set(b):
        x, y = a[k], b[k]
        assert (np.isnan(x) and np.isnan(y)) or x == y, \
            f"{k}: {x} != {y}"


# ------------------------------------------------------------- split views

def test_split_views_cover_disjoint_chronological_zero_copy():
    g = synthetic_tig("tiny", seed=3)
    s = split_views(g)
    n_tr, n_va = s.bounds
    assert 0 < n_tr < n_va < g.num_edges
    assert (s.train.num_edges, s.val.num_edges, s.test.num_edges) == \
        (n_tr, n_va - n_tr, g.num_edges - n_va)
    # cover: concatenated views reproduce the stream, in order
    np.testing.assert_array_equal(
        np.concatenate([s.train.src, s.val.src, s.test.src]), g.src)
    np.testing.assert_array_equal(
        np.concatenate([s.train.eidx, s.val.eidx, s.test.eidx]),
        np.arange(g.num_edges))
    # chronological: row ranges respect time order
    assert s.train.t.max() <= s.val.t.min() <= s.val.t.max() \
        <= s.test.t.min()
    # zero-copy: all three views slice ONE backing column (no sub-graphs)
    assert s.train.src.base is s.val.src.base is s.test.src.base
    assert s.train.src.base is not None
    assert s.train.t.base is s.test.t.base
    # inductive mask matches the one-shot definition
    seen = np.zeros(g.num_nodes, bool)
    seen[g.src[:n_tr]] = True
    seen[g.dst[:n_tr]] = True
    np.testing.assert_array_equal(s.inductive, ~seen)


def test_inductive_node_mask_chunked_equals_one_shot():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 500, 10_000)
    dst = rng.integers(0, 500, 10_000)
    ref = inductive_node_mask(src, dst, 500)
    for chunk in (1, 7, 4096):
        np.testing.assert_array_equal(
            inductive_node_mask(src, dst, 500, chunk_edges=chunk), ref)


def test_split_views_sharded_equals_graph(tmp_path):
    g = synthetic_tig("tiny", seed=5)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=313)
    a, b = split_views(sh), split_views(g)
    assert a.bounds == b.bounds and a.time_scale == b.time_scale
    np.testing.assert_array_equal(a.inductive, b.inductive)
    np.testing.assert_array_equal(a.neg_pool, b.neg_pool)
    for va, vb in zip(a.views, b.views):
        np.testing.assert_array_equal(va.src, vb.src)
        np.testing.assert_array_equal(va.dst, vb.dst)
        np.testing.assert_array_equal(va.t, vb.t)
        np.testing.assert_array_equal(va.labels, vb.labels)


# ------------------------------------------- inductive-mask alignment fix

def _eval_setup(seed=0):
    g = synthetic_tig("tiny", seed=seed)          # 1200 edges
    stream, tables = graph_as_stream(g)
    import jax.numpy as jnp
    tables_j = {k: jnp.asarray(v) for k, v in tables.items()}
    rng = np.random.default_rng(seed)
    batches, _ = build_batch_program(stream, CFG, rng)
    params = init_params(jax.random.PRNGKey(seed), CFG)
    return g, batches, tables_j, params


def _raw_logits(params, batches, tables_j):
    eval_fn = make_eval_epoch(CFG)
    state = init_state(CFG, int(tables_j["nfeat"].shape[0]) - 1)
    _state, aux = eval_fn(params, state, device_batches(batches), tables_j)
    valid = np.asarray(batches["valid"]).reshape(-1)
    pos = np.asarray(aux["pos_logit"]).reshape(-1)[valid]
    neg = np.asarray(aux["neg_logit"]).reshape(-1)[valid]
    return valid, pos, neg


def test_inductive_mask_partially_padded_final_batch():
    """Regression: with 1200 % 128 != 0 the final batch is partially
    padded; a per-edge mask and the equivalent grid-shaped mask (junk in
    the padding slots) must produce identical inductive metrics, equal to
    metrics computed on the masked logit subset directly."""
    g, batches, tables_j, params = _eval_setup(seed=1)
    n_edges = g.num_edges
    steps, b = batches["valid"].shape
    assert steps * b > n_edges            # partially-padded final batch

    rng = np.random.default_rng(7)
    mask_edge = rng.random(n_edges) < 0.3
    mask_grid = np.ones(steps * b, bool)  # junk True in padding slots
    mask_grid[:n_edges] = mask_edge

    eval_fn = make_eval_epoch(CFG)
    N = g.num_nodes

    def score(mask):
        return score_stream(params, CFG, init_state(CFG, N), batches,
                            tables_j, eval_fn, inductive_edge_mask=mask)

    res_edge, res_grid = score(mask_edge), score(mask_grid.reshape(steps, b))
    valid, pos, neg = _raw_logits(params, batches, tables_j)
    want = link_prediction_metrics(pos[mask_edge], neg[mask_edge])
    for res in (res_edge, res_grid):
        assert res["ap_inductive"] == want["ap"]
        assert res["auc_inductive"] == want["auc"]


def test_inductive_mask_never_truncates_against_filtered_logits():
    """The old ``mask[: len(pos)]`` silently misaligned whenever ``valid``
    dropped a non-padding row: a full-stream per-edge mask must now be
    rejected, and a grid-shaped mask must align through ``valid``."""
    g, batches, tables_j, params = _eval_setup(seed=2)
    n_edges = g.num_edges
    steps, b = batches["valid"].shape
    batches["valid"][0, 1] = False        # mask out a real mid-stream edge

    rng = np.random.default_rng(11)
    mask_edge = rng.random(n_edges) < 0.4          # stale per-edge length
    mask_grid = np.zeros(steps * b, bool)
    mask_grid[:n_edges] = mask_edge

    eval_fn = make_eval_epoch(CFG)
    state = init_state(CFG, g.num_nodes)
    res = score_stream(params, CFG, state, batches, tables_j, eval_fn,
                       inductive_edge_mask=mask_grid)
    valid, pos, neg = _raw_logits(params, batches, tables_j)
    m = mask_grid[valid]
    want = link_prediction_metrics(pos[m], neg[m])
    assert res["ap_inductive"] == want["ap"]
    assert res["auc_inductive"] == want["auc"]

    with pytest.raises(ValueError, match="inductive_edge_mask"):
        score_stream(params, CFG, init_state(CFG, g.num_nodes), batches,
                     tables_j, eval_fn, inductive_edge_mask=mask_edge)


# ----------------------------------------------------- protocol parity

def test_run_protocol_sharded_matches_evaluate_params(tmp_path):
    """Acceptance: run_protocol over ShardedStream views == in-memory
    evaluate_params (identical batch plan => identical metrics), and
    prefetch on/off is bit-identical."""
    g = synthetic_tig("tiny", seed=2)
    params = init_params(jax.random.PRNGKey(0), CFG)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=311)

    splits = split_views(sh)
    tables_j = stage_device_tables(sh)
    got = run_protocol(params, CFG, splits, tables_j, seed=3)
    ref = evaluate_params(g, CFG, params, seed=3)
    _metrics_equal(got, ref)
    for k in ("val_ap", "val_auc", "test_ap", "test_auc"):
        assert 0.0 <= got[k] <= 1.0

    serial = run_protocol(params, CFG, splits, tables_j, seed=3,
                          prefetch=False)
    _metrics_equal(got, serial)


def test_train_sharded_protocol_end_to_end(tmp_path):
    """train_sharded(protocol=True): trains on the 70% view only, selects
    on val, and reports through the same driver — metrics must equal
    evaluate_params(best params) on the materialized graph."""
    g = synthetic_tig("tiny", seed=4)
    sh = write_graph_shards(g, str(tmp_path / "sh"), shard_edges=500)
    res = train_sharded(sh, CFG, epochs=3, protocol=True, patience=2,
                        seed=1)
    assert res.metrics is not None
    assert len(res.val_curve) == len(res.losses) <= 3
    assert res.best_epoch == int(np.argmax(res.val_curve))
    for k in ("val_ap", "val_auc", "test_ap", "test_auc"):
        assert 0.0 <= res.metrics[k] <= 1.0
    assert {"val_ap_inductive", "test_ap_inductive",
            "test_auc_inductive", "node_auroc"} <= set(res.metrics)

    ev = evaluate_params(sh.as_graph(), CFG, res.params, seed=1)
    _metrics_equal(res.metrics, ev)


def test_train_sharded_checkpoint_dir_and_early_stop_invariants(tmp_path):
    g = synthetic_tig("tiny", seed=9)
    sh = write_graph_shards(g, str(tmp_path / "sh"))
    ck = str(tmp_path / "ck")
    res = train_sharded(sh, CFG, epochs=2, protocol=True, patience=1,
                        seed=0, ckpt_dir=ck)
    # best-val params were kept via repro/checkpoint in the caller's dir
    assert os.path.exists(
        os.path.join(ck, f"ckpt_{res.best_epoch:08d}.npz"))
    assert len(res.val_curve) <= 2


def test_make_eval_epoch_program_cache():
    a = make_eval_epoch(CFG)
    b = make_eval_epoch(TIGConfig(**{
        f.name: getattr(CFG, f.name)
        for f in CFG.__dataclass_fields__.values()}))
    assert a is b
    assert make_eval_epoch(CFG, collect_embeddings=True) is not a


# ------------------------------------------------- hypothesis properties
# guarded per-test (not importorskip) so the deterministic tests above
# still run when the optional dependency is absent

try:
    from hypothesis import assume, given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=80, deadline=None)
    @given(e=st.integers(0, 100_000),
           tf=st.floats(0.05, 0.95),
           vf=st.floats(0.0, 0.5))
    def test_split_bounds_disjoint_chronological_cover(e, tf, vf):
        assume(tf + vf <= 1.0)
        n_tr, n_va = split_bounds(e, tf, vf)
        # row ranges [0,n_tr) [n_tr,n_va) [n_va,e): disjoint by
        # construction iff the bounds are ordered, covering iff they end
        # at e; chronological because rows are one sorted stream.
        assert 0 <= n_tr <= n_va <= e
        assert n_tr + (n_va - n_tr) + (e - n_va) == e
