"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant (<=2 layers, d_model<=512, <=4 experts), runs one forward +
one train step on CPU with finite loss and correct shapes, plus a decode
step; dense-family archs additionally verify decode == prefill exactly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.models import (
    forward,
    init_cache,
    init_params,
    loss_fn,
    make_train_step,
    serve_step,
)
from repro.models.model import fill_enc_cache
from repro.optim import adamw

ARCHS = [a for a in list_archs() if a != "speed-tig"]
B, S = 2, 16


def make_batch(cfg, rng, b=B, s=S):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s))),
    }
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        f = cfg.frontend_tokens
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, f, cfg.d_model)), jnp.float32)
        batch["positions3"] = jnp.asarray(
            np.tile(np.arange(s + f)[None, None, :], (b, 3, 1)))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(1)
    params = init_params(jax.random.PRNGKey(1), cfg)
    opt = adamw(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch(cfg, rng)
    l0 = None
    for i in range(4):
        params, opt_state, metrics = step(params, opt_state, batch)
        assert bool(jnp.isfinite(metrics["loss"])), arch
        if l0 is None:
            l0 = float(metrics["loss"])
    # repeated steps on the same batch must reduce loss (learnability)
    assert float(metrics["loss"]) < l0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(2)
    params = init_params(jax.random.PRNGKey(2), cfg)
    cache = init_cache(cfg, 1, B, S)
    step = jax.jit(lambda p, c, b: serve_step(p, c, b, cfg))
    b_t = {"token": jnp.asarray(rng.integers(0, cfg.vocab, (B,))),
           "pos": jnp.zeros((B,), jnp.int32)}
    if cfg.enc_dec:
        cache = init_cache(cfg, 1, B, S, enc_len=8)
        frames = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)),
                             jnp.float32)
        cache = fill_enc_cache(params, cache, frames, cfg)
    logits, new_cache = step(params, cache, b_t)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache must actually change
    changed = jax.tree.map(
        lambda a, b: bool((np.asarray(a, np.float32)
                           != np.asarray(b, np.float32)).any()),
        cache, new_cache)
    assert any(jax.tree.leaves(changed)), arch


DECODE_EXACT = [a for a in ARCHS
                if a not in ("seamless-m4t-medium", "qwen2-vl-7b")]


@pytest.mark.parametrize("arch", DECODE_EXACT)
def test_decode_matches_prefill(arch):
    """Token-by-token decode must reproduce the full-sequence forward
    (the KV cache / recurrent-state plumbing is exact)."""
    cfg = get_config(arch, reduced=True)
    if cfg.is_moe:  # avoid chunk-dependent capacity drops in the comparison
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(3)
    params = init_params(jax.random.PRNGKey(3), cfg)
    tokens = rng.integers(0, cfg.vocab, (B, S))
    batch = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(tokens)}
    full, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    cache = init_cache(cfg, 1, B, S)
    step = jax.jit(lambda p, c, b: serve_step(p, c, b, cfg))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache,
                         {"token": jnp.asarray(tokens[:, t]),
                          "pos": jnp.full((B,), t)})
        outs.append(np.asarray(lg, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_sliding_window_ring_cache():
    """SWA ring cache (starcoder2 long-context path): decoding past the
    window must equal a full-cache decode with window masking."""
    cfg = get_config("starcoder2-3b", reduced=True)   # window=64
    cfg = dataclasses.replace(cfg, window=8)
    rng = np.random.default_rng(4)
    params = init_params(jax.random.PRNGKey(4), cfg)
    s = 24  # 3x window
    tokens = rng.integers(0, cfg.vocab, (B, s))
    batch = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(tokens)}
    full, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    cache = init_cache(cfg, 1, B, s)       # ring: min(s, window)=8 slots
    assert cache["k"].shape[2] == 8
    step = jax.jit(lambda p, c, b: serve_step(p, c, b, cfg))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache,
                         {"token": jnp.asarray(tokens[:, t]),
                          "pos": jnp.full((B,), t)})
        outs.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_match_analytic():
    """ArchConfig.param_count() (used for MODEL_FLOPS) must track the real
    initialized parameter tree within 2%."""
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        real = sum(x.size for x in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert abs(real - approx) / real < 0.02, (arch, real, approx)


def test_input_shapes_table():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["train_4k"].kind == "train"
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
