"""Quickstart: the SPEED pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Generates a small temporal interaction graph, partitions its training
stream with SEP (Alg.1), trains a TGN backbone with PAC on 4 simulated
devices (Alg.2: lockstep wrap-around, memory backup/restore, shared-node
sync), and evaluates link prediction.
"""

import numpy as np

from repro.core import partition_stats, sep_partition
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.train import evaluate_params


def main():
    # 1) a temporal interaction graph (users x items, power-law, bursty)
    g = synthetic_tig("small", seed=0)
    print("graph:", g.stats())
    train_g, val_g, test_g, _ = chronological_split(g)

    # 2) SEP: stream the training edges into 8 balanced vertex-cut parts,
    #    replicating only the top-5% time-decay-centrality hubs
    part = sep_partition(train_g.src, train_g.dst, train_g.t,
                         g.num_nodes, num_parts=8, k=0.05)
    print("partition:", partition_stats(part))

    # 3) PAC: shuffle-combine 8 parts -> 4 devices, train 3 epochs
    cfg = TIGConfig(flavor="tgn", dim=32, dim_time=16, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=5, batch_size=100)
    res = pac_train(train_g, part, cfg, num_devices=4, epochs=3, lr=2e-3)
    print(f"losses/epoch: {res.mean_loss_per_epoch().round(4).tolist()}  "
          f"derived speedup: {res.derived_speedup:.2f}x")

    # 4) downstream: link prediction AP
    ev = evaluate_params(g, cfg, res.params)
    print(f"test AP {ev['test_ap']:.3f} "
          f"(inductive {ev['test_ap_inductive']:.3f})")


if __name__ == "__main__":
    main()
