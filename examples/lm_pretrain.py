"""LM pretraining example: train a reduced assigned-architecture config for
a few hundred steps on the synthetic corpus (CPU; the full configs are
exercised via the dry-run on the production mesh).

    PYTHONPATH=src python examples/lm_pretrain.py --arch olmoe-1b-7b \
        --steps 300 --batch 8 --seq 128
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import LMDataConfig, packed_batches
from repro.models import init_params, make_train_step
from repro.optim import adamw, linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b",
                    choices=[a for a in list_archs() if a != "speed-tig"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"{args.arch} (reduced): "
          f"{sum(x.size for x in jax.tree.leaves(params))/1e6:.2f}M params")
    opt = adamw(lr=linear_warmup_cosine(args.lr, 20, args.steps),
                weight_decay=0.1, max_grad_norm=1.0)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    data = packed_batches(dcfg)
    t0, seen = time.perf_counter(), 0
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        seen += args.batch * args.seq
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"tok/s {seen/(time.perf_counter()-t0):,.0f}")


if __name__ == "__main__":
    main()
