"""Batched serving example: prefill a batch of prompts, then decode tokens
with the KV/recurrent-state cache (greedy), for any assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b \
        --batch 4 --prompt-len 32 --gen 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import init_cache, init_params, serve_step
from repro.models.model import fill_enc_cache
from repro.models.sampling import sample_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b",
                    choices=[a for a in list_archs() if a != "speed-tig"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples (with --top-k/--top-p)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = args.batch
    total = args.prompt_len + args.gen
    cache = init_cache(cfg, 1, b, total, enc_len=16)
    if cfg.enc_dec:
        frames = jnp.asarray(rng.normal(size=(b, 16, cfg.d_model)),
                             jnp.float32)
        cache = fill_enc_cache(params, cache, frames, cfg)

    step = jax.jit(lambda p, c, bt: serve_step(p, c, bt, cfg))
    prompts = rng.integers(0, cfg.vocab, (b, args.prompt_len))

    # prefill: feed prompt tokens through the decode path (cache fills up)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache,
                             {"token": jnp.asarray(prompts[:, t]),
                              "pos": jnp.full((b,), t, jnp.int32)})
    prefill_s = time.perf_counter() - t0

    # decode (greedy or sampled)
    sample_key = jax.random.PRNGKey(1)

    def pick(key, lg):
        return sample_tokens(key, lg[:, :cfg.vocab],
                             temperature=args.temperature,
                             top_k=args.top_k, top_p=args.top_p)

    out_tokens = []
    tok = pick(sample_key, logits)
    t0 = time.perf_counter()
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = step(
            params, cache,
            {"token": tok,
             "pos": jnp.full((b,), args.prompt_len + i, jnp.int32)})
        sample_key, sub = jax.random.split(sample_key)
        tok = pick(sub, logits)
    decode_s = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"{args.arch}: prefill {args.prompt_len} toks x{b} in "
          f"{prefill_s:.2f}s; decoded {args.gen} toks x{b} in {decode_s:.2f}s"
          f" ({b*args.gen/decode_s:.1f} tok/s)")
    print("first sequence:", gen[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()
