"""End-to-end driver (deliverable b): the full SPEED system on a
DGraphFin-shaped graph, a few hundred training steps, with all the paper's
moving parts exercised: SEP hub selection + streaming assignment, partition
shuffling every epoch, Alg.2 loop-within-epoch with memory backup/restore,
DDP gradient sync, shared-node memory synchronization (latest-timestamp),
checkpointing, and downstream evaluation through the unified protocol
driver (``repro.tig.protocol.run_protocol``).

    PYTHONPATH=src python examples/train_tig_speed.py [--big] [--shards]

(--big uses the 97k-node dgraphfin-s preset; default is a 1/4-scale variant
so the example finishes in a few minutes on one CPU core.  --shards runs
the out-of-core quality path instead: the stream is written to a
``tig-shards-v1`` directory and trained/evaluated from disk with
val-driven model selection — the same protocol code, no in-memory graph.)
"""

import argparse
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import (
    partition_stats,
    sep_partition,
    thm1_rf_bound,
    replication_factor,
)
from repro.tig.data import synthetic_tig
from repro.tig.distributed import pac_train
from repro.tig.graph import chronological_split
from repro.tig.models import TIGConfig
from repro.tig.stream import write_graph_shards
from repro.tig.train import train_sharded


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--topk", type=float, default=0.01)
    ap.add_argument("--pallas", action="store_true",
                    help="route attention/GRU inside the scanned epoch "
                         "through the Pallas kernels (TPU; on CPU set "
                         "REPRO_KERNEL_BACKEND=interpret to validate)")
    ap.add_argument("--shards", action="store_true",
                    help="out-of-core quality path: train + evaluate from "
                         "a tig-shards-v1 directory (no in-memory graph)")
    args = ap.parse_args()

    scale = 1.0 if args.big else 0.25
    g = synthetic_tig("dgraphfin-s", seed=7, scale=scale)
    print("dataset:", g.stats())

    if args.shards:
        cfg = TIGConfig(flavor="tgn", dim=64, dim_time=32,
                        dim_edge=g.dim_edge, dim_node=g.dim_node,
                        num_neighbors=10, batch_size=200,
                        use_pallas=args.pallas)
        t0 = time.perf_counter()
        with tempfile.TemporaryDirectory() as tmp:
            sh = write_graph_shards(g, os.path.join(tmp, "shards"))
            del g                       # stream lives on disk from here on
            res = train_sharded(sh, cfg, epochs=args.epochs, protocol=True,
                                patience=max(1, args.epochs - 1),
                                eval_node_class=True)
        m = res.metrics
        print(f"sharded protocol: {len(res.losses)} epochs "
              f"(best epoch {res.best_epoch}, val curve "
              f"{[round(v, 4) for v in res.val_curve]})")
        print(f"downstream: val AP {m['val_ap']:.3f}  test AP "
              f"{m['test_ap']:.3f}  inductive {m['test_ap_inductive']:.3f}"
              f"  node AUROC {m['node_auroc']:.3f}")
        print(f"total {time.perf_counter() - t0:.1f}s")
        return

    train_g, _, _, _ = chronological_split(g)

    t0 = time.perf_counter()
    part = sep_partition(train_g.src, train_g.dst, train_g.t, g.num_nodes,
                         args.parts, k=args.topk)
    stats = partition_stats(part)
    print(f"SEP in {stats.elapsed_s:.2f}s: cut {100*stats.edge_cut:.2f}%  "
          f"RF {stats.replication_factor:.3f} "
          f"(Thm.1 bound {thm1_rf_bound(args.topk, args.parts):.3f} on "
          f"RF_all={replication_factor(part, denominator='all'):.3f})  "
          f"edge std {stats.edge_std:.0f}")

    cfg = TIGConfig(flavor="tgn", dim=64, dim_time=32, dim_edge=g.dim_edge,
                    dim_node=g.dim_node, num_neighbors=10, batch_size=200,
                    use_pallas=args.pallas)
    res = pac_train(train_g, part, cfg, num_devices=args.devices,
                    epochs=args.epochs, lr=1e-3, shuffle_parts=True,
                    eval_graph=g, eval_node_class=True)
    steps = sum(l.shape[-1] for l in res.losses)
    print(f"PAC: {steps} lockstep steps x {args.devices} devices, "
          f"losses {res.mean_loss_per_epoch().round(4).tolist()}, "
          f"derived speedup {res.derived_speedup:.2f}x, "
          f"memory-module rows/device {res.plan.capacity}")

    ckpt_dir = os.path.join("experiments", "ckpt_tig")
    path = save_checkpoint(ckpt_dir, steps, res.params,
                           metadata={"arch": "speed-tig", "cfg": str(cfg)})
    print("checkpoint:", path)

    ev = res.metrics   # routed through protocol.run_protocol by pac_train
    print(f"downstream: val AP {ev['val_ap']:.3f}  test AP "
          f"{ev['test_ap']:.3f}  inductive {ev['test_ap_inductive']:.3f}  "
          f"node AUROC {ev['node_auroc']:.3f}")
    print(f"total {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
